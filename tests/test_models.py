"""Model-stack correctness: blockwise attention vs naive oracle, SSD vs
naive recurrence, MoE routing invariants, prefill→decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import ArchConfig
from repro.models import (decode_step, forward, forward_with_cache,
                          init_decode_cache, init_lm)
from repro.models.attention import attention_forward, init_attention
from repro.models.moe import capacity, init_moe, moe_forward
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(42)


def naive_attention(p, x, cfg, window=0):
    """O(S²) oracle with explicit masks."""
    from repro.models.attention import _gqa_out, _gqa_scores, _project_qkv
    s = x.shape[1]
    pos = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, x, cfg, pos, pos, rope=True)
    scores = _gqa_scores(q, k, cfg.attn_logit_softcap)
    i, j = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    mask = j <= i
    if window:
        mask &= j > i - window
    scores = jnp.where(mask[None, None, None], scores, -2.0 ** 30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = _gqa_out(probs.astype(v.dtype), v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def fp32_cfg(name):
    # capacity_factor=8 → no MoE capacity drops, so teacher-forced decode
    # (which never drops single tokens) is comparable to full-seq forward.
    import dataclasses
    return dataclasses.replace(get_config(name, smoke=True),
                               dtype="float32", capacity_factor=8.0)


class TestBlockwiseAttention:
    @pytest.mark.parametrize("seq,q_block", [(32, 8), (37, 8), (64, 64),
                                             (16, 32)])
    def test_full_causal_matches_naive(self, seq, q_block):
        cfg = fp32_cfg("qwen2-7b")
        p, _ = init_attention(KEY, cfg, dtype=jnp.float32)
        x = jax.random.normal(KEY, (2, seq, cfg.d_model), jnp.float32)
        pos = jnp.arange(seq)[None, :]
        got = attention_forward(p, x, cfg, pos, q_block=q_block)
        want = naive_attention(p, x, cfg)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("seq,window", [(64, 16), (48, 16), (64, 8)])
    def test_sliding_window_matches_naive(self, seq, window):
        import dataclasses
        cfg = dataclasses.replace(fp32_cfg("mixtral-8x22b"),
                                  sliding_window=window)
        p, _ = init_attention(KEY, cfg, dtype=jnp.float32)
        x = jax.random.normal(KEY, (2, seq, cfg.d_model), jnp.float32)
        pos = jnp.arange(seq)[None, :]
        got = attention_forward(p, x, cfg, pos, window=window, q_block=16)
        want = naive_attention(p, x, cfg, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_softcap_applied(self):
        cfg = fp32_cfg("gemma2-2b")
        assert cfg.attn_logit_softcap == 50.0
        p, _ = init_attention(KEY, cfg, dtype=jnp.float32)
        x = 100.0 * jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
        pos = jnp.arange(16)[None, :]
        out = attention_forward(p, x, cfg, pos)
        assert not jnp.isnan(out).any()


class TestSSD:
    def _naive_ssd(self, x, dt, a, b_in, c_in):
        """Token-by-token recurrence oracle."""
        bsz, l, h, p = x.shape
        n = b_in.shape[-1]
        hstate = jnp.zeros((bsz, h, n, p))
        ys = []
        for t in range(l):
            decay = jnp.exp(dt[:, t] * a[None, :])             # (B,H)
            upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, t], b_in[:, t],
                             x[:, t])
            hstate = decay[:, :, None, None] * hstate + upd
            ys.append(jnp.einsum("bn,bhnp->bhp", c_in[:, t], hstate))
        return jnp.stack(ys, axis=1), hstate

    @pytest.mark.parametrize("l,chunk", [(16, 4), (17, 4), (8, 8), (32, 16),
                                         (12, 32)])
    def test_chunked_matches_recurrence(self, l, chunk):
        bsz, h, p, n = 2, 3, 4, 5
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (bsz, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        b_in = jax.random.normal(ks[3], (bsz, l, n))
        c_in = jax.random.normal(ks[4], (bsz, l, n))
        y, hT = ssd_chunked(x, dt, a, b_in, c_in, chunk)
        y_ref, hT_ref = self._naive_ssd(x, dt, a, b_in, c_in)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(hT, hT_ref, rtol=1e-4, atol=1e-4)

    def test_initial_state_carried(self):
        """h0 continuation == computing the longer sequence in one go."""
        bsz, l, h, p, n = 1, 16, 2, 4, 3
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (bsz, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        b_in = jax.random.normal(ks[3], (bsz, l, n))
        c_in = jax.random.normal(ks[4], (bsz, l, n))
        y_full, hT = ssd_chunked(x, dt, a, b_in, c_in, 8)
        _, h_mid = ssd_chunked(x[:, :8], dt[:, :8], a, b_in[:, :8],
                               c_in[:, :8], 8)
        y2, hT2 = ssd_chunked(x[:, 8:], dt[:, 8:], a, b_in[:, 8:],
                              c_in[:, 8:], 8, h0=h_mid)
        np.testing.assert_allclose(y2, y_full[:, 8:], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(hT2, hT, rtol=1e-4, atol=1e-4)


class TestMoE:
    def test_routing_conservation(self):
        """Every kept token's combine weights sum to ~1; dropped rows 0."""
        cfg = fp32_cfg("mixtral-8x22b")
        p, _ = init_moe(KEY, cfg, dtype=jnp.float32)
        x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
        y, aux = moe_forward(p, x, cfg)
        assert y.shape == x.shape
        assert float(aux) > 0.5  # balanced routing → aux ≈ 1

    def test_capacity_formula(self):
        cfg = get_config("mixtral-8x22b")
        assert capacity(cfg, 4096) == 1280  # 2·4096·1.25/8

    def test_identical_tokens_identical_outputs(self):
        cfg = fp32_cfg("phi3.5-moe-42b-a6.6b")
        p, _ = init_moe(KEY, cfg, dtype=jnp.float32)
        tok = jax.random.normal(KEY, (1, 1, cfg.d_model), jnp.float32)
        x = jnp.tile(tok, (1, 4, 1))
        y, _ = moe_forward(p, x, cfg)
        np.testing.assert_allclose(y[0, 0], y[0, 1], rtol=1e-5, atol=1e-5)


class TestPrefillDecodeConsistency:
    """The crown-jewel invariant: teacher-forced decode after prefill must
    reproduce full-sequence forward logits (validates KV ring buffers, SSM
    state handoff and conv history across every architecture family)."""

    @pytest.mark.parametrize("arch", list_archs())
    def test_decode_matches_forward(self, arch):
        cfg = fp32_cfg(arch)
        params, _ = init_lm(KEY, cfg)
        bsz, prefill_len, total = 2, 8, 12
        img = (jax.random.normal(KEY, (bsz, cfg.num_image_tokens,
                                       cfg.d_model))
               if cfg.num_image_tokens else None)
        tokens = jax.random.randint(KEY, (bsz, total), 0, cfg.vocab_size)
        ref_logits, _ = forward(params, tokens, cfg, image_embeds=img,
                                remat=False)
        _, cache, _ = forward_with_cache(params, tokens[:, :prefill_len],
                                         cfg, max_seq=32, image_embeds=img)
        for t in range(prefill_len, total):
            logits, cache = decode_step(params, cache, tokens[:, t - 1]
                                        if False else tokens[:, t],
                                        jnp.int32(t), cfg, image_embeds=img)
            np.testing.assert_allclose(
                logits, ref_logits[:, t], rtol=2e-3, atol=2e-3,
                err_msg=f"{arch} diverged at position {t}")
