"""Namespace edge cases the data plane's namespace-first routing
depends on (paper §3): nested prefixes, normalization, unregister,
longest-prefix ties — plus redirector unsubscribe semantics."""
import pytest

from repro.core import (Coord, Namespace, Origin, Redirector,
                        RedirectorGroup, Topology)


def _node(topo, name, site="s"):
    return topo.add_node(name, Coord(site, rack=255, host=0), 1e9)


class TestNamespaceResolution:
    def test_nested_prefixes_longest_wins(self):
        ns = Namespace()
        ns.register("/a", "o1")
        ns.register("/a/b", "o2")
        assert ns.resolve("/a/x") == "o1"
        assert ns.resolve("/a/b") == "o2"
        assert ns.resolve("/a/b/file") == "o2"
        assert ns.resolve("/a/bc") == "o1"  # /a/b must not match /a/bc
        assert ns.resolve("/a") == "o1"

    def test_root_export_is_fallback(self):
        ns = Namespace()
        ns.register("/", "root")
        ns.register("/ligo", "ligo")
        assert ns.resolve("/anything/else") == "root"
        assert ns.resolve("/ligo/frames") == "ligo"

    def test_trailing_slash_and_doubled_separators_normalize(self):
        ns = Namespace()
        ns.register("/a/b/", "o1")
        assert ns.resolve("/a/b") == "o1"
        assert ns.resolve("/a//b/c") == "o1"
        assert ns.resolve("a/b/c") == "o1"   # missing leading slash
        # the normalized form is what exports() reports
        assert ns.exports("o1") == ["/a/b"]

    def test_unregister_then_resolve(self):
        ns = Namespace()
        ns.register("/a", "o1")
        ns.register("/a/b", "o2")
        ns.unregister("/a/b")
        assert ns.resolve("/a/b/file") == "o1"  # falls back to the parent
        ns.unregister("/a")
        assert ns.resolve("/a/b/file") is None
        # unregistering accepts the unnormalized spelling too
        ns.register("/c/d", "o3")
        ns.unregister("/c/d/")
        assert ns.resolve("/c/d/x") is None

    def test_longest_prefix_tie_is_same_prefix_conflict(self):
        """Two same-length matching prefixes are necessarily the *same*
        normalized prefix — and a second owner for it must be rejected,
        not silently shadowed."""
        ns = Namespace()
        ns.register("/a/b", "o1")
        with pytest.raises(ValueError):
            ns.register("/a/b/", "o2")   # normalizes to the same prefix
        # re-registering the same owner is idempotent
        ns.register("/a/b", "o1")
        assert ns.resolve("/a/b/x") == "o1"

    def test_sibling_prefixes_do_not_tie(self):
        ns = Namespace()
        ns.register("/aa", "o1")
        ns.register("/ab", "o2")
        assert ns.resolve("/aa/x") == "o1"
        assert ns.resolve("/ab/x") == "o2"
        assert ns.resolve("/ac/x") is None


class TestRedirectorUnsubscribe:
    def _fed_pieces(self):
        topo = Topology()
        topo.add_site("s")
        r = Redirector("r1", _node(topo, "s/r1"))
        o1 = Origin("o1", _node(topo, "s/o1"), exports=("/exp1",))
        o2 = Origin("o2", _node(topo, "s/o2"), exports=("/exp1/nested",))
        return topo, r, o1, o2

    def test_unsubscribe_removes_prefixes_and_origin(self):
        _, r, o1, o2 = self._fed_pieces()
        r.subscribe(o1)
        r.subscribe(o2)
        o2.put_object("/exp1/nested/f", 100)
        o1.put_object("/exp1/g", 100)
        assert r.locate("/exp1/nested/f") is o2
        r.unsubscribe(o2)
        # no dangling prefix: resolution falls back to the parent export
        assert r.namespace.resolve("/exp1/nested/f") == "o1"
        assert r.locate("/exp1/g") is o1
        assert "o2" not in r.origins

    def test_unsubscribe_by_name_and_unknown_is_noop(self):
        _, r, o1, _ = self._fed_pieces()
        r.subscribe(o1)
        r.unsubscribe("o1")
        assert r.namespace.resolve("/exp1/x") is None
        r.unsubscribe("never-subscribed")  # must not raise

    def test_group_passthrough(self):
        topo = Topology()
        topo.add_site("s")
        r1 = Redirector("r1", _node(topo, "s/r1"))
        r2 = Redirector("r2", _node(topo, "s/r2"))
        group = RedirectorGroup([r1, r2])
        o = Origin("o1", _node(topo, "s/o1"), exports=("/exp",))
        group.subscribe(o)
        assert r1.namespace.resolve("/exp/f") == "o1"
        assert r2.namespace.resolve("/exp/f") == "o1"
        group.unsubscribe(o)
        for r in (r1, r2):
            assert r.namespace.resolve("/exp/f") is None
            assert "o1" not in r.origins

    def test_locate_no_longer_polls_dead_owner(self):
        """The motivating bug: a retired origin's dangling prefix made
        locate poll it forever.  After unsubscribe, its poll counter
        stays flat."""
        _, r, o1, o2 = self._fed_pieces()
        r.subscribe(o1)
        r.subscribe(o2)
        o1.put_object("/exp1/g", 100)
        r.unsubscribe(o2)
        before = o2.stats.locate_queries
        for _ in range(5):
            r.locate("/exp1/nested/ghost")
        assert o2.stats.locate_queries == before
