"""Differential fuzz: random traces, scalar CacheServer oracle vs kernels.

Hypothesis-free seeded fuzzing (tier-1 always runs it): a deterministic
random-trace generator sweeps capacities, chunk sizes, admission
fractions and cold restarts, replays every trace through the real
:class:`~repro.core.cache.CacheServer` state machine, and diffs the
result against all three batched kernels in
:mod:`repro.kernels.stack_distance`:

* ``cache_sim_batch``   — every trace (LRU + FIFO, admission filters);
* ``fifo_sim_batch``    — the FIFO subset;
* ``stack_distances_batch`` + ``lru_hits`` — the admit-everything LRU
  subset (the Mattson one-pass-per-column path).

All ~220 traces are batched into a handful of jitted calls, so the suite
stays cheap.  On a mismatch the failing trace is greedily shrunk to a
minimal reproducer and printed — paste it straight into a regression
test.
"""
import random

import numpy as np
import pytest

from repro.core import (CacheServer, Coord, Payload, SizeAwareAdmission,
                        Topology)
from repro.kernels.stack_distance import (cache_sim_batch, fifo_sim_batch,
                                          lru_hits, stack_distances_batch)

N_CASES = 220


# ---------------------------------------------------------------------------
# Trace generation + the CacheServer oracle


def _random_case(seed):
    """One seeded random trace + cache configuration."""
    rng = random.Random(0xD1FF ^ seed)
    n = rng.randint(40, 160)
    n_keys = rng.randint(2, 16)
    max_size = rng.randint(4, 40)
    sizes = [rng.randint(1, max_size) for _ in range(n_keys)]
    keys = [rng.randrange(n_keys) for _ in range(n)]
    reset_rate = rng.choice([0.0, 0.02, 0.1])
    resets = [i > 0 and rng.random() < reset_rate for i in range(n)]
    capacity = rng.randint(max_size, 20 * max_size)
    fraction = rng.choice([None, None, 0.15, 0.3, 0.6])
    policy = rng.choice(["lru", "fifo"])
    return {"seed": seed, "keys": keys, "sizes": sizes, "resets": resets,
            "capacity": capacity, "fraction": fraction, "policy": policy}


def _admit_bits(case):
    """The per-reference admission bit the kernels consume — mirrors
    CacheServer.admit's refusal order (admission filter, then oversize);
    the two refusal counters are mutually exclusive so their sum is the
    non-admitted miss count."""
    cap, frac = case["capacity"], case["fraction"]
    return np.asarray([
        s <= cap and (frac is None or s <= frac * cap)
        for s in (case["sizes"][k] for k in case["keys"])])


def _oracle(case):
    """Replay the trace through a real CacheServer (no reimplementation:
    the oracle IS the production state machine)."""
    admission = (SizeAwareAdmission(case["fraction"])
                 if case["fraction"] is not None else None)
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node(f"c{case['seed']}", Coord("s"), 1e10)
    c = CacheServer(node.name, node, int(case["capacity"]),
                    policy=case["policy"], admission=admission)
    hits = []
    for k, r in zip(case["keys"], case["resets"]):
        if r:
            c.clear()
        path = f"/k{k}"
        if c.lookup(path, 0) is not None:
            hits.append(True)
            continue
        hits.append(False)
        size = case["sizes"][k]
        c.admit(path, 0, Payload.synthetic(size, path, 0),
                object_size=size)
    return (np.asarray(hits), c.stats.evictions, c.stats.bytes_evicted,
            c.stats.admission_rejects + c.stats.oversize_rejects)


def _sim_problem(case):
    return (case["keys"], _admit_bits(case), case["resets"],
            np.asarray(case["sizes"], float), float(case["capacity"]),
            case["policy"] == "fifo")


def _mismatch(case, kernel_result):
    """None if kernel and oracle agree, else a description string."""
    hits, ev, evb = kernel_result
    o_hits, o_ev, o_evb, o_rej = _oracle(case)
    if (hits != o_hits).any():
        i = int(np.argmax(hits != o_hits))
        return (f"hit mask diverges at ref {i} "
                f"(kernel={bool(hits[i])}, oracle={bool(o_hits[i])})")
    if (ev, evb) != (o_ev, o_evb):
        return (f"evictions kernel=({ev}, {evb}) "
                f"oracle=({o_ev}, {o_evb})")
    admit = _admit_bits(case)
    if int((~hits & ~admit).sum()) != o_rej:
        return (f"derived rejects {int((~hits & ~admit).sum())} "
                f"!= oracle {o_rej}")
    return None


# ---------------------------------------------------------------------------
# Shrinking: greedy trace minimization for readable failure output


def _still_fails(case):
    (res,) = cache_sim_batch([_sim_problem(case)])
    return _mismatch(case, res) is not None


def _shrunk(case, fails=_still_fails):
    """Greedily minimize a failing trace: truncate the tail, then drop
    individual references, keeping every removal that still fails."""
    cur = dict(case)
    # binary-search the shortest failing prefix
    lo, hi = 1, len(cur["keys"])
    while lo < hi:
        mid = (lo + hi) // 2
        trial = dict(cur, keys=cur["keys"][:mid], resets=cur["resets"][:mid])
        if fails(trial):
            hi = mid
        else:
            lo = mid + 1
    cur["keys"], cur["resets"] = cur["keys"][:hi], cur["resets"][:hi]
    # drop interior references one at a time
    i = len(cur["keys"]) - 1
    while i >= 0:
        trial = dict(cur, keys=cur["keys"][:i] + cur["keys"][i + 1:],
                     resets=cur["resets"][:i] + cur["resets"][i + 1:])
        if fails(trial):
            cur = trial
        i -= 1
    return cur


def _repro(case, why):
    return (f"differential mismatch ({why})\nminimal reproducing trace:\n"
            f"  keys     = {case['keys']}\n"
            f"  sizes    = {case['sizes']}\n"
            f"  resets   = {case['resets']}\n"
            f"  capacity = {case['capacity']}\n"
            f"  fraction = {case['fraction']}\n"
            f"  policy   = {case['policy']!r}\n"
            f"  (seed {case['seed']})")


# ---------------------------------------------------------------------------
# The suite


class TestDifferentialFuzz:
    def test_cache_sim_matches_oracle_on_220_random_traces(self):
        """Primary differential target: every random trace through the
        vectorized cache state machine, one batched call."""
        cases = [_random_case(s) for s in range(N_CASES)]
        results = cache_sim_batch([_sim_problem(c) for c in cases])
        for case, res in zip(cases, results):
            why = _mismatch(case, res)
            if why is not None:
                small = _shrunk(case)
                pytest.fail(_repro(small, why))

    def test_fifo_kernel_agrees_on_fifo_subset(self):
        cases = [c for c in (_random_case(s) for s in range(N_CASES))
                 if c["policy"] == "fifo"]
        assert len(cases) >= 50  # the generator keeps both policies hot
        problems = [(c["keys"],
                     np.asarray([c["sizes"][k] for k in c["keys"]], float),
                     _admit_bits(c), c["resets"], len(c["sizes"]),
                     float(c["capacity"])) for c in cases]
        for case, (hits, ev, evb) in zip(cases, fifo_sim_batch(problems)):
            why = _mismatch(case, (hits, ev, evb))
            if why is not None:
                small = _shrunk(case)
                pytest.fail(_repro(small, f"fifo_sim_batch: {why}"))

    def test_stack_distances_agree_on_admit_all_lru_subset(self):
        """The Mattson path (distances once, hits per capacity) against
        the same oracle — only valid with no admission filter."""
        cases = [c for c in (_random_case(s) for s in range(N_CASES))
                 if c["policy"] == "lru" and c["fraction"] is None
                 and max(c["sizes"]) <= c["capacity"]]
        assert len(cases) >= 40

        def prev_indices(keys, resets):
            prev, last = [], {}
            for i, (k, r) in enumerate(zip(keys, resets)):
                if r:
                    last = {}
                prev.append(last.get(k, -1))
                last[k] = i
            return prev

        problems = []
        for c in cases:
            ref_sizes = np.asarray([c["sizes"][k] for k in c["keys"]],
                                   float)
            problems.append((prev_indices(c["keys"], c["resets"]),
                             ref_sizes))
        dists = stack_distances_batch(problems)
        for case, dist, (_, ref_sizes) in zip(cases, dists, problems):
            hits = lru_hits(dist, ref_sizes, case["capacity"])
            o_hits, *_ = _oracle(case)
            if (hits != o_hits).any():
                i = int(np.argmax(hits != o_hits))
                small = _shrunk(case)
                pytest.fail(_repro(
                    small, f"lru_hits diverges at ref {i}"))

    def test_shrinker_minimizes(self):
        """The shrinker itself: given a predicate, the surviving trace
        is 1-minimal (no single reference can be dropped)."""
        case = _random_case(0)

        def fails(c):
            # synthetic "bug": key 1 referenced at least twice
            return c["keys"].count(1) >= 2

        assert fails(case)
        small = _shrunk(case, fails=fails)
        assert fails(small)
        assert small["keys"].count(1) == 2
        assert all(k == 1 for k in small["keys"])
