"""Consistent-hash ring + HA cache group tests (failover, rebalance)."""
import pytest

from repro.core import (CacheGroup, CacheServer, Coord, HashRing,
                        RedirectorGroup, Redirector, Topology,
                        build_fleet_federation)


def _cache(name, capacity=1000):
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node(name, Coord("s"), 1e10)
    return CacheServer(name, node, capacity)


KEYS = [f"/exp/data/file_{i:04d}" for i in range(400)]


class TestHashRing:
    def test_balanced_ownership(self):
        ring = HashRing([f"c{i}" for i in range(5)])
        counts = {}
        for k in KEYS:
            counts[ring.owner(k)] = counts.get(ring.owner(k), 0) + 1
        assert len(counts) == 5
        # virtual nodes keep the split roughly even (no member > 2x fair)
        assert max(counts.values()) < 2 * len(KEYS) / 5

    def test_removal_remaps_only_dead_members_share(self):
        ring = HashRing([f"c{i}" for i in range(5)])
        before = {k: ring.owner(k) for k in KEYS}
        ring.remove("c2")
        after = {k: ring.owner(k) for k in KEYS}
        moved = [k for k in KEYS if before[k] != after[k]]
        # only keys owned by c2 move, and they all move
        assert set(moved) == {k for k, o in before.items() if o == "c2"}
        # surviving keys keep their owner (the consistent-hash property)
        assert all(after[k] == before[k] for k in KEYS if before[k] != "c2")

    def test_successor_chain_distinct_and_stable(self):
        ring = HashRing(["a", "b", "c"])
        chain = ring.successors("/some/key")
        assert sorted(chain) == ["a", "b", "c"]
        assert chain == ring.successors("/some/key")


class TestCacheGroup:
    def test_route_is_deterministic_per_path(self):
        group = CacheGroup("g", [_cache(f"c{i}") for i in range(4)])
        first = group.route("/exp/f")[0]
        for _ in range(5):
            assert group.route("/exp/f")[0] is first

    def test_dead_primary_fails_over_to_ring_successor(self):
        group = CacheGroup("g", [_cache(f"c{i}") for i in range(4)])
        chain = group.route("/exp/f")
        primary, successor = chain[0], chain[1]
        primary.available = False
        live = group.route("/exp/f", live_only=True)
        assert live[0] is successor
        assert group.stats.failovers >= 1
        assert group.stats.remapped_keys >= 1

    def test_rebalance_on_cache_death(self):
        """Kill one member: only its keyspace share changes owner."""
        caches = [_cache(f"c{i}") for i in range(5)]
        group = CacheGroup("g", caches)
        before = {k: group.route(k)[0].name for k in KEYS}
        dead = caches[1]
        dead.available = False
        after = {k: group.route(k, live_only=True)[0].name for k in KEYS}
        moved = [k for k in KEYS if before[k] != after[k]]
        assert moved  # the dead member's share really remaps
        assert all(before[k] == dead.name for k in moved)
        assert len(moved) < len(KEYS) / 2

    def test_membership_change_via_add_remove(self):
        group = CacheGroup("g", [_cache("c0")])
        group.add(_cache("c1"))
        assert len(group.ring) == 2
        group.remove("c0")
        assert group.route("/f")[0].name == "c1"


class TestFederationRingRouting:
    def test_replicas_partition_working_set(self):
        """With 3-way HA groups, different objects land on different
        replicas of the nearest pod group."""
        fed = build_fleet_federation(num_pods=2, hosts_per_pod=2,
                                     cache_replicas=3)
        assert len(fed.caches) == 6
        assert len(fed.groups["pod0"].members) == 3
        origin = fed.origins[0]
        owners = set()
        for i in range(12):
            path = f"/data/shard_{i:03d}"
            origin.put_object(path, b"x" * 1000)
            client = fed.client("pod0", 0)
            got, st = client.read(path)
            assert got == b"x" * 1000
            owners.add(st.source)
        pod0_names = {c.name for c in fed.groups["pod0"].members}
        assert owners <= pod0_names    # nearest group serves everything
        assert len(owners) > 1         # ...partitioned across replicas

    def test_cache_death_degrades_to_ring_member_not_origin(self):
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=2,
                                     cache_replicas=3)
        origin = fed.origins[0]
        data = b"y" * 2000
        origin.put_object("/data/a", data)
        client = fed.client("pod0", 0)
        client.read("/data/a")                      # warm the owner
        owner = fed.groups["pod0"].route("/data/a")[0]
        owner.available = False
        client2 = fed.client("pod0", 1)
        got, st = client2.read("/data/a")
        assert got == data
        assert client2.stats.cache_failovers > 0    # skipped the dead owner
        assert st.source != owner.name
        assert st.source in {c.name for c in fed.groups["pod0"].members}

    def test_single_replica_groups_match_geo_ranking(self):
        """Default deployments (1 replica/site) keep the seed semantics:
        nearest site's cache serves, dead cache fails over outward."""
        fed = build_fleet_federation(num_pods=2, hosts_per_pod=1)
        origin = fed.origins[0]
        origin.put_object("/d/f", b"z" * 500)
        client = fed.client("pod1", 0)
        got, st = client.read("/d/f")
        assert got == b"z" * 500
        assert st.source == "pod1/cache"


class TestRedirectorGroup:
    def test_n_way_round_robin_and_failover(self):
        topo = Topology()
        topo.add_site("s")
        members = [Redirector(f"r{i}", topo.add_node(f"r{i}", Coord("s", 0, i),
                                                     1e10))
                   for i in range(3)]
        group = RedirectorGroup(members)
        from repro.core import Origin
        origin = Origin("o", topo.add_node("o", Coord("s", 1, 0), 1e10),
                        exports=["/exp"])
        origin.put_object("/exp/f", b"d")
        group.subscribe(origin)
        for _ in range(3):
            assert group.locate("/exp/f") is origin
        assert all(r.stats.locate_requests == 1 for r in members)
        members[0].available = False
        members[1].available = False
        for _ in range(4):
            assert group.locate("/exp/f") is origin
        assert group.failovers > 0
        members[2].available = False
        with pytest.raises(ConnectionError):
            group.locate("/exp/f")

    def test_empty_group_rejected(self):
        with pytest.raises(ValueError):
            RedirectorGroup([])
