"""Stack-distance / cache state-machine kernels vs a scalar
``CacheServer`` oracle replay.

The sweep executor's cell-exact parity rests on these kernels answering
hit/miss/eviction questions byte-identically to the real cache state
machine, so the oracle here is the :class:`~repro.core.cache.CacheServer`
itself (``lookup``/``admit``/``clear``), not a reimplementation.
"""
import random

import numpy as np
import pytest

from repro.core import (CacheServer, Coord, Payload, SizeAwareAdmission,
                        Topology)
from repro.kernels.stack_distance import (cache_sim_batch, lru_hits,
                                          stack_distances_batch)


def _cache(capacity, policy="lru", admission=None):
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node(f"c-{policy}-{capacity}", Coord("s"), 1e10)
    return CacheServer(node.name, node, int(capacity), policy=policy,
                       admission=admission)


def _trace(seed, n=300, n_keys=14, max_size=20, reset_rate=0.02):
    """A random keyed reference stream with sizes and cold restarts."""
    rng = random.Random(seed)
    sizes = [rng.randint(1, max_size) for _ in range(n_keys)]
    keys = [rng.randrange(n_keys) for _ in range(n)]
    resets = [i > 0 and rng.random() < reset_rate for i in range(n)]
    return keys, sizes, resets


def _oracle(keys, sizes, resets, capacity, policy="lru", fraction=None):
    """Replay the stream through a real CacheServer."""
    admission = SizeAwareAdmission(fraction) if fraction is not None else None
    c = _cache(capacity, policy=policy, admission=admission)
    hits = []
    for k, r in zip(keys, resets):
        if r:
            c.clear()
        path = f"/k{k}"
        if c.lookup(path, 0) is not None:
            hits.append(True)
            continue
        hits.append(False)
        c.admit(path, 0, Payload.synthetic(sizes[k], path, 0),
                object_size=sizes[k])
    return (np.asarray(hits), c.stats.evictions, c.stats.bytes_evicted,
            c.stats.admission_rejects, c.stats.oversize_rejects)


def _prev_indices(keys, resets):
    prev, last = [], {}
    for i, (k, r) in enumerate(zip(keys, resets)):
        if r:
            last = {}
        prev.append(last.get(k, -1))
        last[k] = i
    return prev


class TestStackDistances:
    def test_lru_hits_match_cache_server_at_every_capacity(self):
        """One distance pass answers every capacity in a sweep column —
        the Mattson inclusion property with byte-granular evict_until."""
        keys, sizes, resets = _trace(seed=1)
        ref_sizes = np.asarray([sizes[k] for k in keys], float)
        dist = stack_distances_batch([(_prev_indices(keys, resets),
                                       ref_sizes)])[0]
        for capacity in (20, 25, 33, 47, 64, 100, 10_000):
            hits = lru_hits(dist, ref_sizes, capacity)
            oracle_hits, *_ = _oracle(keys, sizes, resets, capacity)
            assert (hits == oracle_hits).all(), capacity

    def test_compulsory_misses_are_inf(self):
        dist = stack_distances_batch([([-1, -1, 0, -1], [3.0] * 4)])[0]
        assert np.isinf(dist[[0, 1, 3]]).all()
        assert dist[2] == 3.0  # one distinct key (ref 1) in between

    def test_distance_counts_distinct_key_bytes(self):
        # stream A B C B A: A's reuse distance = |B| + |C| (B once)
        keys = [0, 1, 2, 1, 0]
        sizes = {0: 5.0, 1: 7.0, 2: 11.0}
        prev = _prev_indices(keys, [False] * 5)
        dist = stack_distances_batch(
            [(prev, [sizes[k] for k in keys])])[0]
        assert dist[4] == 7.0 + 11.0
        assert dist[3] == 11.0

    def test_bucketing_telemetry(self):
        """Same-bucket streams share one jitted call; ragged lengths
        land in O(log) buckets (floored so short streams coalesce),
        batch padded to a power of two."""
        problems = [(_prev_indices(*t), [1.0] * len(t[0]))
                    for t in (([0] * 5, [False] * 5),
                              ([1] * 7, [False] * 7),
                              ([2] * 300, [False] * 300))]
        stats = {}
        stack_distances_batch(problems, stats=stats)
        assert stats["problems"] == 3
        assert stats["solve_calls"] == 2          # {256-floor ×2, 512 ×1}
        assert sorted(stats["buckets"]) == [(1, 512), (2, 256)]
        assert stats["padded_problems"] == 0      # both batches pow2 already


class TestCacheStateMachine:
    @pytest.mark.parametrize("policy", ["lru", "fifo"])
    @pytest.mark.parametrize("capacity", [25, 40, 77, 1000])
    def test_hits_and_evictions_match_cache_server(self, policy, capacity):
        keys, sizes, resets = _trace(seed=2)
        admit = np.asarray([sizes[k] <= capacity for k in keys])
        (hits, ev, evb), = cache_sim_batch(
            [(keys, admit, resets, np.asarray(sizes, float),
              float(capacity), policy == "fifo")])
        o_hits, o_ev, o_evb, *_ = _oracle(keys, sizes, resets, capacity,
                                          policy=policy)
        assert (hits == o_hits).all()
        assert (ev, evb) == (o_ev, o_evb)

    def test_admission_filter_respects_resident_copies(self):
        """The size-aware filter applies on *miss*, not on lookup: a
        copy admitted while the filter allowed it keeps hitting."""
        keys, sizes, resets = _trace(seed=3, max_size=40)
        capacity, fraction = 120, 0.2
        admit = np.asarray([sizes[k] <= fraction * capacity for k in keys])
        (hits, ev, evb), = cache_sim_batch(
            [(keys, admit, resets, np.asarray(sizes, float),
              float(capacity), False)])
        o_hits, o_ev, o_evb, o_rej, _ = _oracle(
            keys, sizes, resets, capacity, fraction=fraction)
        assert (hits == o_hits).all()
        assert (ev, evb) == (o_ev, o_evb)
        # policy rejects derive from the hit mask outside the kernel
        assert int((~hits & ~admit).sum()) == o_rej

    def test_oversize_chunks_never_insert(self):
        """Chunks larger than the cache: always a miss, never perturb
        the stack — mirrors the CacheServer.admit oversize refusal."""
        keys, sizes, resets = _trace(seed=4, max_size=60)
        capacity = 50
        admit = np.asarray([sizes[k] <= capacity for k in keys])
        (hits, ev, evb), = cache_sim_batch(
            [(keys, admit, resets, np.asarray(sizes, float),
              float(capacity), False)])
        o_hits, o_ev, o_evb, _, o_over = _oracle(keys, sizes, resets,
                                                 capacity)
        assert (hits == o_hits).all()
        assert (ev, evb) == (o_ev, o_evb)
        assert int((~hits & ~admit).sum()) == o_over

    def test_capacity_policy_column_shares_one_call(self):
        """A capacity × policy sweep column over one stream is vmapped
        data, not separate compiles — one bucket, one device call."""
        keys, sizes, resets = _trace(seed=5)
        ksz = np.asarray(sizes, float)
        problems = []
        for capacity in (30, 50, 90, 200):
            for fifo in (False, True):
                admit = np.asarray([sizes[k] <= capacity for k in keys])
                problems.append((keys, admit, resets, ksz,
                                 float(capacity), fifo))
        stats = {}
        results = cache_sim_batch(problems, stats=stats)
        assert stats["solve_calls"] == 1
        assert stats["problems"] == 8
        for (hits, ev, evb), (capacity, fifo) in zip(
                results, [(c, f) for c in (30, 50, 90, 200)
                          for f in (False, True)]):
            o_hits, o_ev, o_evb, *_ = _oracle(
                keys, sizes, resets, capacity,
                policy="fifo" if fifo else "lru")
            assert (hits == o_hits).all() and (ev, evb) == (o_ev, o_evb)
