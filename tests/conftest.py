"""Test bootstrap: make the repo root importable (for ``benchmarks``).

Note: no XLA device-count flags here — smoke tests and benches must see
the single real CPU device; only ``repro.launch.dryrun`` (never imported
at module scope by tests) forces 512 host devices.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
