"""Integration: federated loader → trainer → checkpoint/restart → serve."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AnalyticPlane, build_fleet_federation
from repro.data import DatasetSpec, FederatedDataLoader, SyntheticTokens
from repro.models import init_lm
from repro.serve import Request, ServeEngine
from repro.train import (AdamWConfig, FailureInjector, FederatedCheckpointer,
                         Trainer)


def small_cfg():
    return dataclasses.replace(get_config("qwen2-7b", smoke=True),
                               dtype="float32")


def make_stack(vocab, batch=4, seq=16, shards=8):
    fed = build_fleet_federation(num_pods=2, hosts_per_pod=4)
    spec = DatasetSpec("toy", vocab_size=vocab, tokens_per_shard=1 << 12,
                       num_shards=shards)
    SyntheticTokens(spec).publish(fed.origins[0])
    plane = AnalyticPlane(fed)
    loader = FederatedDataLoader(plane, spec, global_batch=batch,
                                 seq_len=seq, site="pod0", worker=0)
    return fed, spec, loader


class TestLoader:
    def test_deterministic_and_restart_safe(self):
        _, spec, loader = make_stack(vocab=256)
        b3 = loader.batch(3)
        # a fresh loader (fresh caches warm) reproduces step 3 exactly
        _, _, loader2 = make_stack(vocab=256)
        b3b = loader2.batch(3)
        np.testing.assert_array_equal(b3["tokens"], b3b["tokens"])

    def test_labels_are_shifted_tokens(self):
        _, _, loader = make_stack(vocab=256)
        b = loader.batch(0)
        assert b["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_cache_warms_up(self):
        _, _, loader = make_stack(vocab=256)
        for s in range(4):
            loader.batch(s)
        assert loader.stats.hit_rate > 0.3  # prefetch + reuse → hits

    def test_rank_partitioning_disjoint(self):
        fed, spec, loader = make_stack(vocab=256)
        plane = loader.plane
        l0 = FederatedDataLoader(plane, spec, 4, 16, rank=0, world=2,
                                 site="pod0", worker=1)
        l1 = FederatedDataLoader(plane, spec, 4, 16, rank=1, world=2,
                                 site="pod1", worker=1)
        b0, b1 = l0.batch(0), l1.batch(0)
        assert b0["tokens"].shape == (2, 16)
        assert not np.array_equal(b0["tokens"], b1["tokens"])


class TestTrainerFaultTolerance:
    def _trainer(self, fed, loader, cfg, every=4):
        ck = FederatedCheckpointer("run1", loader.plane,
                                   site="pod0", worker=2)
        return Trainer(cfg, loader,
                       AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
                       checkpointer=ck, checkpoint_every=every)

    def test_loss_decreases(self):
        cfg = small_cfg()
        fed, _, loader = make_stack(vocab=cfg.vocab_size, batch=8, seq=32)
        tr = Trainer(cfg, loader, AdamWConfig(lr=3e-3, warmup_steps=2,
                                              total_steps=100))
        report = tr.run(30)
        assert report.steps_run == 30
        first = np.mean(report.losses[:3])
        last = np.mean(report.losses[-3:])
        assert last < first - 0.05, report.losses

    def test_checkpoint_restart_replays_exactly(self):
        """Failure at step 6 → restore from step-4 checkpoint → final state
        must equal an uninterrupted run (determinism end-to-end)."""
        cfg = small_cfg()
        fed, spec, loader = make_stack(vocab=cfg.vocab_size)
        tr = self._trainer(fed, loader, cfg, every=4)
        report = tr.run(10, failure=FailureInjector(fail_at=[6]))
        assert report.restarts == 1
        assert report.restored_from, "restore path must actually run"
        assert tr.step == 10
        # uninterrupted reference
        fed2, _, loader2 = make_stack(vocab=cfg.vocab_size)
        tr2 = self._trainer(fed2, loader2, cfg, every=4)
        report2 = tr2.run(10)
        leaves = jax.tree.leaves(tr.state["params"])
        leaves2 = jax.tree.leaves(tr2.state["params"])
        for a, b in zip(leaves, leaves2):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_restart_storm_hits_pod_cache(self):
        """After one host restores, sibling hosts restore from cache."""
        cfg = small_cfg()
        fed, _, loader = make_stack(vocab=cfg.vocab_size)
        tr = self._trainer(fed, loader, cfg, every=2)
        tr.run(2)
        origin_before = fed.origins[0].stats.egress_bytes
        ck1 = FederatedCheckpointer("run1", AnalyticPlane(fed),
                                    site="pod0", worker=5)
        ck1.restore(2, like=tr.state)
        egress_first = fed.origins[0].stats.egress_bytes - origin_before
        mid = fed.origins[0].stats.egress_bytes
        ck2 = FederatedCheckpointer("run1", AnalyticPlane(fed),
                                    site="pod0", worker=6)
        _, st = ck2.restore(2, like=tr.state)
        egress_second = fed.origins[0].stats.egress_bytes - mid
        assert st.cache_misses == 0          # all from pod cache
        assert egress_second == 0            # origin untouched
        assert egress_first >= 0

    def test_elastic_rescale(self):
        cfg = small_cfg()
        fed, _, loader = make_stack(vocab=cfg.vocab_size)
        tr = Trainer(cfg, loader, AdamWConfig(warmup_steps=2,
                                              total_steps=100))
        tr.run(2)
        tr.rescale(world=2, rank=0)
        report = tr.run(2)
        assert report.steps_run == 2
        assert tr.loader.world == 2


class TestServeEngine:
    def test_generate_batch(self):
        cfg = small_cfg()
        params, _ = init_lm(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, batch_size=2, max_seq=64)
        reqs = [Request(rid=i,
                        prompt=np.arange(4 + i) % cfg.vocab_size,
                        max_new_tokens=5) for i in range(3)]
        out = eng.generate(reqs)
        assert all(r.done for r in out)
        assert all(1 <= len(r.output) <= 5 for r in out)
        assert eng.stats.prefills >= 3

    def test_greedy_deterministic(self):
        cfg = small_cfg()
        params, _ = init_lm(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, batch_size=1, max_seq=64)
        r1 = eng.generate([Request(0, np.arange(6), max_new_tokens=4)])[0]
        r2 = eng.generate([Request(1, np.arange(6), max_new_tokens=4)])[0]
        assert r1.output == r2.output


class TestGradCompression:
    def test_int8_codec_roundtrip_error_bounded(self):
        from repro.sharding.compression import dequantize, quantize
        x = np.random.default_rng(0).normal(size=(1000,)).astype(np.float32)
        import jax.numpy as _jnp
        enc = quantize(_jnp.asarray(x))
        back = np.asarray(dequantize(enc, x.shape))
        # blockwise absmax int8: error ≤ scale/2 per element
        scale = np.abs(x).max() / 127
        assert np.max(np.abs(back - x)) <= scale * 1.01

    def test_error_feedback_carries_residual(self):
        from repro.sharding.compression import ErrorFeedback
        import jax.numpy as _jnp
        g = {"w": _jnp.full((512,), 1e-6, _jnp.float32)}   # tiny gradients
        r = {"w": _jnp.zeros((512,), _jnp.float32)}
        total_sent = np.zeros(512, np.float32)
        for _ in range(200):
            sent, r = ErrorFeedback.compress(g, r)
            total_sent += np.asarray(sent["w"])
        # without EF tiny grads quantise to 0 forever; with EF the sum of
        # transmitted updates approaches the true accumulated gradient
        true = 200 * 1e-6
        assert abs(total_sent.mean() - true) / true < 0.05

    def test_trainer_converges_with_compression(self):
        cfg = small_cfg()
        fed, _, loader = make_stack(vocab=cfg.vocab_size, batch=8, seq=32)
        tr = Trainer(cfg, loader, AdamWConfig(lr=3e-3, warmup_steps=2,
                                              total_steps=100),
                     grad_compression="int8_ef")
        report = tr.run(20)
        assert np.mean(report.losses[-3:]) < np.mean(report.losses[:3])

    def test_wire_bytes_4x(self):
        from repro.sharding.compression import wire_bytes
        raw, comp = wire_bytes((4096, 4096))
        assert raw / comp > 3.9
