"""Unit tests for the StashCache federation core (paper §3)."""
import pytest

from repro.core import (
    CacheServer, Coord, DEFAULT_CHUNK_SIZE, GeoIPService, Namespace,
    NetworkModel, Origin, Payload, Redirector, RedirectorPair, Topology,
    build_osg_federation, chunk_object, fnv1a64,
)


# ---------------------------------------------------------------------------
# Chunking & checksums
# ---------------------------------------------------------------------------
class TestChunking:
    def test_chunk_boundaries(self):
        data = bytes(range(256)) * 1000  # 256 KB
        meta, payloads = chunk_object("/exp/f", data, chunk_size=100_000)
        assert meta.num_chunks == 3 == len(payloads)
        assert [p.size for p in payloads] == [100_000, 100_000, 56_000]
        assert b"".join(p.data for p in payloads) == data

    def test_checksums_along_chunk_boundaries(self):
        meta, payloads = chunk_object("/exp/f", b"x" * 50, chunk_size=16)
        assert meta.chunk_digests == [p.digest for p in payloads]
        assert all(p.verify() for p in payloads)

    def test_corruption_detected(self):
        p = Payload.from_bytes(b"hello world")
        assert p.verify()
        assert not p.corrupted().verify()

    def test_fnv1a_reference_vector(self):
        # Known FNV-1a 64-bit test vectors.
        assert fnv1a64(b"") == 0xCBF29CE484222325
        assert fnv1a64(b"a") == 0xAF63DC4C8601EC8C

    def test_partial_read_covers_only_needed_chunks(self):
        meta, _ = chunk_object("/exp/f", b"z" * 100, chunk_size=10)
        refs = meta.chunks_for_range(25, 30)  # bytes 25..54 → chunks 2..5
        assert [r.index for r in refs] == [2, 3, 4, 5]


# ---------------------------------------------------------------------------
# Namespace & redirector
# ---------------------------------------------------------------------------
class TestNamespace:
    def test_longest_prefix_resolution(self):
        ns = Namespace()
        ns.register("/ligo", "o1")
        ns.register("/ligo/frames", "o2")
        assert ns.resolve("/ligo/frames/f1") == "o2"
        assert ns.resolve("/ligo/other") == "o1"
        assert ns.resolve("/nova/x") is None

    def test_conflicting_export_rejected(self):
        ns = Namespace()
        ns.register("/ligo", "o1")
        with pytest.raises(ValueError):
            ns.register("/ligo", "o2")


def _mini_topo():
    topo = Topology()
    topo.add_site("site")
    n_o = topo.add_node("origin", Coord("site", 1, 0), 1e10)
    n_r1 = topo.add_node("r1", Coord("site", 2, 0), 1e10)
    n_r2 = topo.add_node("r2", Coord("site", 2, 1), 1e10)
    n_c = topo.add_node("cache", Coord("site", 3, 0), 1e10)
    return topo, n_o, n_r1, n_r2, n_c


class TestRedirector:
    def test_locate_queries_origin(self):
        topo, n_o, n_r1, n_r2, _ = _mini_topo()
        origin = Origin("o1", n_o, exports=["/exp"])
        origin.put_object("/exp/f", b"data")
        r = Redirector("r1", n_r1)
        r.subscribe(origin)
        assert r.locate("/exp/f") is origin
        assert r.locate("/exp/missing") is None
        assert r.stats.origin_polls >= 1

    def test_ha_round_robin_failover(self):
        """Two redirectors in round-robin HA configuration (§3)."""
        topo, n_o, n_r1, n_r2, _ = _mini_topo()
        origin = Origin("o1", n_o, exports=["/exp"])
        origin.put_object("/exp/f", b"data")
        pair = RedirectorPair(Redirector("r1", n_r1), Redirector("r2", n_r2))
        pair.subscribe(origin)
        # round robin alternates members
        pair.locate("/exp/f")
        pair.locate("/exp/f")
        assert pair.members[0].stats.locate_requests == 1
        assert pair.members[1].stats.locate_requests == 1
        # kill one → transparent failover
        pair.members[0].available = False
        for _ in range(4):
            assert pair.locate("/exp/f") is origin
        assert pair.failovers > 0
        # both dead → hard error
        pair.members[1].available = False
        with pytest.raises(ConnectionError):
            pair.locate("/exp/f")


# ---------------------------------------------------------------------------
# Cache server
# ---------------------------------------------------------------------------
class TestCacheLRU:
    def _cache(self, capacity):
        topo, n_o, n_r1, n_r2, n_c = _mini_topo()
        return CacheServer("cache", n_c, capacity)

    def test_lru_eviction_order(self):
        c = self._cache(capacity=30)
        for i in range(3):
            c.admit("/f", i, Payload.from_bytes(bytes([i]) * 10))
        c.lookup("/f", 0)  # touch chunk 0 → chunk 1 is now coldest
        c.admit("/f", 3, Payload.from_bytes(b"x" * 10))
        assert c.resident("/f", 0)
        assert not c.resident("/f", 1)
        assert c.stats.evictions == 1

    def test_pinned_chunks_survive_eviction(self):
        c = self._cache(capacity=25)
        c.admit("/f", 0, Payload.from_bytes(b"a" * 10))
        c.pin("/f", 0)
        c.admit("/f", 1, Payload.from_bytes(b"b" * 10))
        c.admit("/f", 2, Payload.from_bytes(b"c" * 10))
        assert c.resident("/f", 0)       # pinned → not evicted
        assert not c.resident("/f", 1)   # LRU victim instead

    def test_space_reclamation_is_safe(self):
        """Resource owner reclaims space; next access refetches (§1)."""
        c = self._cache(capacity=100)
        c.admit("/f", 0, Payload.from_bytes(b"a" * 10))
        c.drop("/f", 0)
        assert c.lookup("/f", 0) is None
        assert c.stats.misses == 1


# ---------------------------------------------------------------------------
# End-to-end functional federation
# ---------------------------------------------------------------------------
class TestFederationEndToEnd:
    def setup_method(self):
        self.fed = build_osg_federation()
        self.origin = self.fed.origins[0]
        self.data = b"\xAB" * 200_000
        self.origin.put_object("/ligo/frames/f1", self.data, mtime=1.0)

    def test_cold_then_warm_read(self):
        client = self.fed.client("nebraska", 0)
        got, st1 = client.read("/ligo/frames/f1")
        assert got == self.data
        assert st1.cache_misses > 0
        # second client at same site: cache hit, faster
        client2 = self.fed.client("nebraska", 1)
        got2, st2 = client2.read("/ligo/frames/f1")
        assert got2 == self.data
        assert st2.cache_misses == 0 and st2.cache_hits > 0
        assert st2.seconds < st1.seconds

    def test_nearest_cache_selected(self):
        client = self.fed.client("syracuse", 0)
        client.read("/ligo/frames/f1")
        assert self.fed.caches["syracuse/cache"].stats.bytes_served > 0
        assert self.fed.caches["colorado/cache"].stats.bytes_served == 0

    def test_cache_failure_fails_over_to_next_nearest(self):
        client = self.fed.client("syracuse", 0)
        self.fed.caches["syracuse/cache"].available = False
        got, _ = client.read("/ligo/frames/f1")
        assert got == self.data
        assert client.stats.cache_failovers > 0

    def test_stashcp_fallback_chain(self):
        # No CVMFS, no XRootD → curl/HTTP path still succeeds.
        client = self.fed.client("chicago", 0, cvmfs=False, xrootd=False)
        got, st = client.copy("/ligo/frames/f1")
        assert got == self.data
        assert st.method == "stashcp/http"
        # XRootD preferred over HTTP when present.
        client2 = self.fed.client("chicago", 1, cvmfs=False, xrootd=True)
        _, st2 = client2.copy("/ligo/frames/f1")
        assert st2.method == "stashcp/xrootd"

    def test_checksum_corruption_detected_and_refetched(self):
        """CVMFS consistency guarantee vs silent proxy corruption (§6)."""
        client = self.fed.client("nebraska", 0)
        client.read("/ligo/frames/f1")
        cache = self.fed.caches["nebraska/cache"]
        cache.corrupt("/ligo/frames/f1", 0)
        client2 = self.fed.client("nebraska", 1)
        got, _ = client2.read("/ligo/frames/f1")
        assert got == self.data                      # healed
        assert client2.stats.checksum_failures == 1

    def test_proxy_serves_corruption_silently(self):
        proxy = self.fed.proxies["nebraska"]
        meta = self.origin.meta("/ligo/frames/f1")
        client_node = self.fed.client("nebraska", 0).node.name
        proxy.get_object(client_node, meta, now=0.0)
        proxy.corrupt("/ligo/frames/f1")
        corrupt, _ = proxy.get_object(client_node, meta, now=1.0)
        assert corrupt  # no checksums in the HTTP path

    def test_cvmfs_partial_read(self):
        """Partial reads only fetch covering chunks (§3.1)."""
        big = bytes(1024) * 3000  # ~3 MB
        self.origin.put_object("/des/big", big, mtime=2.0)
        client = self.fed.client("colorado", 0)
        got, st = client.read("/des/big", offset=100, length=50)
        assert got == big[100:150]
        assert st.chunks <= 1 or st.bytes < len(big)

    def test_geoip_lookup_cost_charged_to_stashcp(self):
        client = self.fed.client("chicago", 0, cvmfs=False)
        _, st = client.copy("/ligo/frames/f1")
        assert st.seconds >= self.fed.geoip.lookup_latency
