"""The unified data plane (`repro.core.api`): protocol conformance,
namespace-first routing, declarative scenarios, and the acceptance
criterion — engine parity on an uncontended single-flow workload."""
import dataclasses

import pytest

from repro.core import (AnalyticPlane, DataPlane, FederationSpec,
                        FetchRequest, FetchResult, OutageEvent,
                        OutageSchedule, ScenarioSpec, SimulatedPlane,
                        StatResult, WorkloadSpec, run_scenario)


def fleet_spec(**kw):
    kw.setdefault("num_pods", 1)
    kw.setdefault("hosts_per_pod", 2)
    return FederationSpec.fleet(**kw)


class TestDataPlaneProtocol:
    def test_both_engines_satisfy_the_protocol(self):
        fed = fleet_spec().build()
        assert isinstance(AnalyticPlane(fed), DataPlane)
        assert isinstance(SimulatedPlane(fleet_spec().build()), DataPlane)

    @pytest.mark.parametrize("plane_cls", [AnalyticPlane, SimulatedPlane])
    def test_publish_stat_fetch_by_path(self, plane_cls):
        plane = plane_cls(fleet_spec().build())
        st = plane.publish("/data/obj", int(5e7))
        assert isinstance(st, StatResult) and st.found
        assert st.size == int(5e7) and st.num_chunks == 2
        assert plane.stat("/data/obj").origin == st.origin
        res = plane.fetch("/data/obj")
        assert isinstance(res, FetchResult)
        assert res.ok and res.seconds > 0
        assert res.bytes == int(5e7)
        assert res.plane == plane.name
        assert not plane.stat("/nope").found

    def test_unknown_method_and_engine_rejected(self):
        with pytest.raises(ValueError):
            FetchRequest("/x", method="carrier-pigeon")
        with pytest.raises(ValueError):
            ScenarioSpec(name="x", federation=fleet_spec(),
                         workload=[], engine="quantum")
        with pytest.raises(ValueError):
            WorkloadSpec(kind="mystery")

    def test_analytic_missing_path_is_reported_not_raised(self):
        plane = AnalyticPlane(fleet_spec().build())
        res = plane.fetch("/not/published")
        assert not res.ok and "FileNotFoundError" in res.error

    def test_fetch_result_unifies_both_shapes(self):
        """The one schema both engines fill — the field set the CI smoke
        asserts in the benchmark artifact."""
        fields = {f.name for f in dataclasses.fields(FetchResult)}
        # TransferStats side (analytic)
        assert {"bytes", "seconds", "chunks", "cache_hits",
                "cache_misses", "method", "source"} <= fields
        # DownloadResult side (simulated)
        assert {"path", "size", "cache_hit", "start", "failovers",
                "hedged", "waited"} <= fields


class TestNamespaceFirstRouting:
    def test_multi_origin_longest_prefix(self):
        fed = fleet_spec().build()
        nested = fed.add_origin("storage", exports=("/deep/nested",))
        plane = AnalyticPlane(fed)
        plane.publish("/deep/nested/obj", int(3e7))
        plane.publish("/deep/other", int(3e7))
        assert plane.stat("/deep/nested/obj").origin == nested.name
        assert plane.stat("/deep/other").origin == fed.origins[0].name
        r1 = plane.fetch("/deep/nested/obj")
        assert r1.ok and r1.bytes == int(3e7)
        assert nested.stats.egress_bytes >= int(3e7)

    def test_remove_origin_unregisters_prefixes(self):
        fed = fleet_spec().build()
        nested = fed.add_origin("storage", exports=("/deep/nested",))
        plane = AnalyticPlane(fed)
        fed.remove_origin(nested)
        # publish now routes to the root exporter, not the retired origin
        st = plane.publish("/deep/nested/obj", 1000)
        assert st.origin == fed.origins[0].name

    def test_add_origin_after_remove_never_reuses_a_name(self):
        fed = fleet_spec().build()
        o1 = fed.add_origin("storage", exports=("/ea",))
        o2 = fed.add_origin("storage", exports=("/eb",))
        fed.remove_origin(o1)
        o3 = fed.add_origin("storage", exports=("/ec",))
        assert o3.name != o2.name
        # o2's namespace claim survives o3's subscription
        assert fed.resolve_origin("/eb/x") is o2
        assert fed.resolve_origin("/ec/x") is o3
        with pytest.raises(ValueError):
            fed.add_origin("storage", exports=("/ed",), name=o2.name)

    def test_sim_plane_pulls_from_namespace_resolved_origin(self):
        fed = fleet_spec().build()
        nested = fed.add_origin("storage", exports=("/deep/nested",))
        plane = SimulatedPlane(fed)
        plane.publish("/deep/nested/obj", int(3e7))
        res = plane.fetch(FetchRequest("/deep/nested/obj", site="pod0"))
        assert res.ok and res.seconds > 0
        assert nested.stats.egress_bytes == int(3e7)
        assert fed.origins[0].stats.egress_bytes == 0


class TestScenarioSpec:
    def test_workload_spec_storm_targets_worker_sites(self):
        fed = fleet_spec(num_pods=2, hosts_per_pod=3).build()
        reqs = WorkloadSpec(kind="storm", workers_per_site=3).build(fed)
        assert len(reqs) == 6  # 2 pods x 3 workers; storage has none
        assert {r.site for r in reqs} == {"pod0", "pod1"}
        assert all(r.method == "stash" for r in reqs)

    def test_run_scenario_publishes_and_reports(self):
        spec = ScenarioSpec(
            name="t", federation=fleet_spec(),
            workload=WorkloadSpec(kind="storm", path="/ckpt/p",
                                  size=int(1e8), workers_per_site=2))
        rep = run_scenario(spec)
        assert rep.engine == "sim"
        assert len(rep.results) == 2
        assert all(r.ok and r.seconds > 0 for r in rep.results)
        assert rep.bytes_moved == 2 * int(1e8)
        # collapsed forwarding: the origin served the object once
        assert rep.origin_egress_bytes == int(1e8)
        s = rep.summary()
        assert s["requests"] == 2 and s["engine"] == "sim"

    def test_sizeless_unpublished_path_fails_visibly(self):
        """run_scenario must not mint 0-byte objects for typo'd paths."""
        for engine in ("analytic", "sim"):
            spec = ScenarioSpec(
                name="typo", federation=fleet_spec(), engine=engine,
                workload=[FetchRequest("/typo/none", site="pod0")])
            rep = run_scenario(spec)
            assert not rep.results[0].ok
            assert "FileNotFoundError" in rep.results[0].error

    def test_reused_federation_reports_deltas_not_totals(self):
        fed = fleet_spec().build()
        spec = ScenarioSpec(
            name="a", federation=fleet_spec(),
            workload=[FetchRequest("/r/a", site="pod0", size=int(4e7))],
            sequential=True)
        rep1 = run_scenario(spec, federation=fed)
        rep2 = run_scenario(dataclasses.replace(spec, name="b"),
                            federation=fed)
        # run 1: cold miss; run 2: warm hit on the same federation —
        # its report must not carry run 1's misses or egress.
        assert rep1.cache_misses > 0 and rep1.origin_egress_bytes == int(4e7)
        assert rep2.cache_hits > 0 and rep2.cache_misses == 0
        assert rep2.origin_egress_bytes == 0

    def test_reused_sim_plane_never_moves_time_backward(self):
        plane = SimulatedPlane(fleet_spec().build())
        plane.publish("/t/a", int(2e7))
        plane.fetch(FetchRequest("/t/a", site="pod0"))
        t_after_first = plane.sim.t
        assert t_after_first > 0
        res = plane.fetch_all([FetchRequest("/t/a", site="pod0", at=0.0,
                                            worker=1)])
        assert res[0].start >= t_after_first
        assert plane.sim.t >= t_after_first

    def test_outage_schedule_on_both_engines(self):
        """A dead pod cache mid-workload: both engines must fail over
        (origin fallback for the single-cache fleet) and count the
        outage + recovery."""
        sched = OutageSchedule([
            OutageEvent(5.0, "pod0/cache", "down"),
            OutageEvent(50.0, "pod0/cache", "up", cold=True)])
        reqs = [FetchRequest("/d/a", site="pod0", at=0.0, size=int(2e7)),
                FetchRequest("/d/a", site="pod0", at=10.0, size=int(2e7)),
                FetchRequest("/d/a", site="pod0", at=60.0, size=int(2e7))]
        for engine in ("analytic", "sim"):
            spec = ScenarioSpec(name="outage", federation=fleet_spec(),
                                workload=reqs, outages=sched,
                                engine=engine, sequential=True)
            rep = run_scenario(spec)
            assert rep.outages == 1 and rep.recoveries == 1, engine
            assert all(r.ok for r in rep.results), engine
            mid = rep.results[1]
            # at t=10 the only cache is down: served by origin fallback
            # (sim) / http-after-failover... both routes report no hit.
            assert not mid.cache_hit, engine
            # after the cold recovery the cache is empty again: miss.
            assert not rep.results[2].cache_hit, engine


class TestEngineParity:
    """Acceptance criterion: the same ScenarioSpec executed on
    AnalyticPlane and SimulatedPlane reports identical bytes moved and
    cache hit/miss counts on an uncontended single-flow workload."""

    def _spec(self, engine):
        return ScenarioSpec(
            name="parity",
            federation=fleet_spec(num_pods=1, hosts_per_pod=2),
            workload=[
                FetchRequest("/p/a", site="pod0", worker=0, size=int(5e7)),
                FetchRequest("/p/a", site="pod0", worker=1, size=int(5e7)),
                FetchRequest("/p/b", site="pod0", worker=0, size=int(3e7)),
                FetchRequest("/p/a", site="pod0", worker=0, size=int(5e7)),
            ],
            sequential=True,   # single-flow: one transfer at a time
            engine=engine)

    def test_identical_bytes_and_hit_miss_counts(self):
        rep_a = run_scenario(self._spec("analytic"))
        rep_s = run_scenario(self._spec("sim"))
        assert rep_a.engine == "analytic" and rep_s.engine == "sim"
        assert rep_a.bytes_moved == rep_s.bytes_moved
        assert rep_a.cache_hits == rep_s.cache_hits
        assert rep_a.cache_misses == rep_s.cache_misses
        assert rep_a.origin_egress_bytes == rep_s.origin_egress_bytes
        # per-request classification agrees too on the uncontended chain
        for ra, rs in zip(rep_a.results, rep_s.results):
            assert ra.cache_hit == rs.cache_hit
            assert ra.bytes == rs.bytes

    def test_parity_survives_a_zipf_trace(self):
        spec = ScenarioSpec(
            name="parity-zipf",
            federation=fleet_spec(num_pods=1, hosts_per_pod=2),
            workload=WorkloadSpec(kind="zipf", n_requests=30,
                                  working_set=8, seed=3, duration=100.0),
            sequential=True)
        rep_a = run_scenario(dataclasses.replace(spec, engine="analytic"))
        rep_s = run_scenario(dataclasses.replace(spec, engine="sim"))
        assert rep_a.bytes_moved == rep_s.bytes_moved
        assert rep_a.cache_hits == rep_s.cache_hits
        assert rep_a.cache_misses == rep_s.cache_misses
        assert rep_a.origin_egress_bytes == rep_s.origin_egress_bytes
        assert rep_a.hit_rate == rep_s.hit_rate
