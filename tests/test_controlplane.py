"""Unit tests for the control plane: queues, breakers, quotas, health."""
import math

import pytest

from repro.core import (CacheGroup, CacheServer, CircuitBreaker,
                        ControlPlane, ControlPlaneSpec, Coord, DecayGauge,
                        FluidFlowSim, NetworkModel, SpaceSavingTopK,
                        Topology, fair_shares)
from repro.core.controlplane import AdmissionQueue, AnalyticQueue
from repro.core.monitoring import CacheHealthMonitor


def _sim():
    topo = Topology()
    topo.add_site("s")
    topo.add_node("w", Coord("s"), 1e9)
    return FluidFlowSim(topo, NetworkModel(topo))


def _drive(sim, gen, out, key):
    def run():
        out[key] = yield from gen
    sim.spawn(run())


class TestFairShares:
    def test_under_demand_everyone_satisfied(self):
        assert fair_shares([2, 3], 10) == [2, 3]

    def test_over_demand_splits_evenly(self):
        assert fair_shares([10, 10, 10], 15) == [5, 5, 5]

    def test_small_demands_release_to_big(self):
        # max-min: the 1-demand tenant is capped by demand, the rest
        # split what remains
        assert fair_shares([1, 100, 100], 11) == [1, 5, 5]

    def test_sum_is_min_of_capacity_and_demand(self):
        alloc = fair_shares([3, 9, 2, 7], 12)
        assert sum(alloc) == pytest.approx(12)
        alloc = fair_shares([3, 1], 12)
        assert sum(alloc) == pytest.approx(4)

    def test_weights(self):
        assert fair_shares([100, 100], 30, weights=[2, 1]) == [20, 10]


class TestCircuitBreaker:
    def test_opens_after_threshold_consecutive_failures(self):
        br = CircuitBreaker(threshold=3, cooldown=10.0)
        for t in range(2):
            br.on_failure(float(t))
            assert br.state == br.CLOSED
        br.on_failure(2.0)
        assert br.state == br.OPEN
        assert br.opens == 1
        assert not br.allow(3.0)

    def test_success_resets_failure_run(self):
        br = CircuitBreaker(threshold=3)
        br.on_failure(0.0)
        br.on_failure(1.0)
        br.on_success(2.0)
        br.on_failure(3.0)
        br.on_failure(4.0)
        assert br.state == br.CLOSED  # the run was broken

    def test_half_open_probe_then_close_or_reopen(self):
        br = CircuitBreaker(threshold=1, cooldown=5.0)
        br.on_failure(0.0)
        assert br.state == br.OPEN
        assert not br.allow(4.9)
        assert br.allow(5.0)           # cooldown elapsed: one probe
        assert br.state == br.HALF_OPEN
        br.on_failure(5.1)             # probe failed
        assert br.state == br.OPEN
        assert br.opens == 2
        assert br.allow(10.2)
        br.on_success(10.3)            # probe succeeded
        assert br.state == br.CLOSED


class TestAdmissionQueue:
    def test_sheds_beyond_queue_depth(self):
        sim = _sim()
        spec = ControlPlaneSpec(max_concurrent=1, queue_depth=2)
        q = AdmissionQueue(sim, spec)
        out = {}
        for i in range(4):
            _drive(sim, q.acquire("t"), out, i)
        sim.run()
        # 1 in service, 2 queued, 1 shed
        assert out[0] is True
        assert q.in_service == 1
        assert len(q.waiting) == 2
        assert out[3] is False
        assert q.stats.sheds == 1
        assert q.stats.shed_by_tenant == {"t": 1}

    def test_release_drains_fifo(self):
        sim = _sim()
        spec = ControlPlaneSpec(max_concurrent=1, queue_depth=8)
        q = AdmissionQueue(sim, spec)
        out = {}
        for i in range(3):
            _drive(sim, q.acquire("t"), out, i)
        sim.run()
        assert out == {0: True}
        q.release("t")
        sim.run()
        assert out == {0: True, 1: True}
        q.release("t")
        sim.run()
        assert out == {0: True, 1: True, 2: True}
        assert q.stats.queue_waits == 2

    def test_tenant_quota_caps_slots(self):
        sim = _sim()
        spec = ControlPlaneSpec(max_concurrent=4, queue_depth=8,
                                tenant_quota=0.5)  # 2 slots per tenant
        q = AdmissionQueue(sim, spec)
        out = {}
        for i in range(4):
            _drive(sim, q.acquire("hog"), out, f"hog{i}")
        _drive(sim, q.acquire("small"), out, "small")
        sim.run()
        # hog holds its 2-slot quota, 2 hogs wait; small walks past them
        assert out["hog0"] and out["hog1"]
        assert "hog2" not in out and "hog3" not in out
        assert out["small"] is True
        assert q.by_tenant == {"hog": 2, "small": 1}

    def test_fair_share_dequeue_prefers_starved_tenant(self):
        sim = _sim()
        spec = ControlPlaneSpec(max_concurrent=2, queue_depth=8,
                                tenant_quota=1.0)
        q = AdmissionQueue(sim, spec)
        out = {}
        _drive(sim, q.acquire("a"), out, "a0")
        _drive(sim, q.acquire("a"), out, "a1")
        _drive(sim, q.acquire("a"), out, "a2")   # waits (queued first)
        _drive(sim, q.acquire("b"), out, "b0")   # waits
        sim.run()
        q.release("a")
        sim.run()
        # b holds 0 slots vs a's 1: fair-share grants b despite a2's
        # earlier enqueue
        assert out.get("b0") is True
        assert "a2" not in out

    def test_queue_never_exceeds_bound(self):
        sim = _sim()
        spec = ControlPlaneSpec(max_concurrent=2, queue_depth=3)
        q = AdmissionQueue(sim, spec)
        out = {}
        for i in range(10):
            _drive(sim, q.acquire(f"t{i % 3}"), out, i)
        sim.run()
        assert q.max_waiting <= spec.queue_depth
        assert q.in_service <= spec.max_concurrent
        assert q.stats.sheds == 10 - 2 - 3


class TestAnalyticQueue:
    def test_waits_accumulate_like_c_server(self):
        spec = ControlPlaneSpec(max_concurrent=2, queue_depth=10)
        q = AnalyticQueue(spec)
        # three unit-time jobs arriving together on 2 servers
        waits = []
        for _ in range(3):
            start = q.reserve(0.0)
            waits.append(q.commit(0.0, start, 1.0))
        assert waits == [0.0, 0.0, 1.0]

    def test_sheds_when_backlog_hits_depth(self):
        spec = ControlPlaneSpec(max_concurrent=1, queue_depth=1)
        q = AnalyticQueue(spec)
        s0 = q.reserve(0.0)
        q.commit(0.0, s0, 10.0)        # busy until 10
        s1 = q.reserve(1.0)
        q.commit(1.0, s1, 1.0)         # one waiter parked
        assert q.reserve(2.0) is None  # queue full: shed
        assert q.stats.sheds == 1
        # once the backlog clears, arrivals are admitted again
        assert q.reserve(12.0) == 12.0

    def test_tenant_quota_serializes_hog(self):
        spec = ControlPlaneSpec(max_concurrent=4, queue_depth=10,
                                tenant_quota=0.25)  # 1 slot per tenant
        q = AnalyticQueue(spec)
        s = q.reserve(0.0, "hog")
        q.commit(0.0, s, 5.0, "hog")
        s2 = q.reserve(0.0, "hog")
        assert s2 == 5.0               # quota, not free servers, binds
        other = q.reserve(0.0, "other")
        assert other == 0.0


class TestGauges:
    def test_decay_gauge_halves_per_tau_ln2(self):
        g = DecayGauge(tau=10.0)
        g.add(8.0, now=0.0)
        assert g.read(0.0) == 8.0
        assert g.read(10.0 * math.log(2)) == pytest.approx(4.0)

    def test_monotone_under_silence(self):
        g = DecayGauge(tau=7.0)
        g.add(5.0, now=3.0)
        prev = g.read(3.0)
        for t in (4.0, 8.0, 20.0, 100.0):
            cur = g.read(t)
            assert cur <= prev
            prev = cur

    def test_space_saving_topk_tracks_heavy_hitter(self):
        tk = SpaceSavingTopK(k=2)
        for _ in range(100):
            tk.add("whale", 10)
        for i in range(20):
            tk.add(f"minnow{i}", 1)
        top = tk.top(1)
        assert top[0][0] == "whale"
        assert top[0][1] >= 1000

    def test_health_monitor_flags_error_rate(self):
        hm = CacheHealthMonitor(tau=60.0)
        for i in range(6):
            hm.observe("c", ok=False, latency=0.0, now=float(i))
        assert hm.error_rate("c", 6.0) == pytest.approx(1.0)
        assert hm.unhealthy("c", 6.0, error_threshold=0.5)
        # too few samples: never unhealthy, whatever the rate
        hm2 = CacheHealthMonitor()
        hm2.observe("c", ok=False, latency=0.0, now=0.0)
        assert not hm2.unhealthy("c", 0.0, error_threshold=0.5)


def _group():
    topo = Topology()
    topo.add_site("s")
    caches = []
    for i in range(2):
        node = topo.add_node(f"c{i}", Coord("s"), 1e10)
        caches.append(CacheServer(f"c{i}", node, 10**9))
    return CacheGroup("g", caches)


class TestHealthDrivenDemotion:
    def _plane(self, group, **kw):
        spec = ControlPlaneSpec(min_samples=2.0, error_threshold=0.5,
                                health_cooldown=30.0, **kw)
        return ControlPlane(spec, group_of={c.name: group
                                            for c in group.members})

    def test_auto_mark_down_and_lazy_recovery(self):
        group = self._group = _group()
        cp = self._plane(group)
        for t in range(5):
            cp.on_failure("c0", float(t))
        assert not group.caches["c0"].available
        assert group.stats.outages == 1
        assert group.stats.auto_outages == 1
        assert cp.stats.auto_downs == 1
        # before cooldown: no recovery
        assert not cp.maybe_recover("c0", 10.0)
        assert not group.caches["c0"].available
        # after cooldown: probe brings it back, auto-tagged
        assert cp.maybe_recover("c0", 40.0)
        assert group.caches["c0"].available
        assert group.stats.recoveries == 1
        assert group.stats.auto_recoveries == 1
        assert cp.stats.auto_ups == 1

    def test_no_double_count_when_script_overlaps_gauge(self):
        """Regression (ISSUE 6 small fix): a scripted mark_down racing a
        gauge-driven one must count a single outage, and the control
        plane must not auto-recover a cache a schedule already
        recovered."""
        group = _group()
        cp = self._plane(group)
        group.mark_down("c0")          # scripted outage fires first
        assert group.stats.outages == 1
        for t in range(5):
            cp.on_failure("c0", float(t))  # gauges fire on the same cache
        # available-guard dedupe: still one outage, no auto counter
        assert group.stats.outages == 1
        assert group.stats.auto_outages == 0
        assert cp.stats.auto_downs == 0
        # scripted recovery beats the health cooldown…
        group.mark_up("c0")
        assert group.stats.recoveries == 1
        # …and the control plane must not claim (or re-count) it
        assert not cp.maybe_recover("c0", 100.0)
        assert group.stats.recoveries == 1
        assert group.stats.auto_recoveries == 0
        assert cp.stats.auto_ups == 0

    def test_gauge_down_then_scripted_up_drops_auto_record(self):
        group = _group()
        cp = self._plane(group)
        for t in range(5):
            cp.on_failure("c0", float(t))
        assert cp.stats.auto_downs == 1
        group.mark_up("c0")            # schedule recovers it mid-cooldown
        assert not cp.maybe_recover("c0", 100.0)
        assert cp.stats.auto_ups == 0  # never auto-up what we didn't hold
        assert group.stats.recoveries == 1

    def test_breaker_skip_counts(self):
        group = _group()
        cp = self._plane(group, breaker_threshold=2)
        cp.on_failure("c1", 0.0)
        cp.on_failure("c1", 1.0)
        assert cp.stats.breaker_opens == 1
        assert not cp.allow("c1", 2.0)
        assert cp.stats.breaker_skips == 1
        # cooldown elapses: half-open probe allowed
        assert cp.allow("c1", 100.0)

    def test_backoff_schedule(self):
        cp = ControlPlane(ControlPlaneSpec(backoff_base=0.5,
                                           backoff_multiplier=2.0,
                                           backoff_max=3.0))
        assert [cp.backoff(i) for i in range(4)] == [0.5, 1.0, 2.0, 3.0]
