"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED config
of the same family, run one forward and one train step on CPU, assert
output shapes and no NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation) — validated structurally here.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, shapes_for
from repro.models import forward, init_lm, lm_loss
from repro.models.model import init_lm_abstract
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

ARCHS = list_archs()
KEY = jax.random.PRNGKey(7)


def _inputs(cfg, batch=2, seq=16):
    tokens = jax.random.randint(KEY, (batch, seq), 0, cfg.vocab_size)
    img = None
    if cfg.num_image_tokens:
        img = jax.random.normal(KEY, (batch, cfg.num_image_tokens,
                                      cfg.d_model), jnp.float32)
    return tokens, img


class TestSmokeForward:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        params, specs = init_lm(KEY, cfg)
        tokens, img = _inputs(cfg)
        logits, aux = forward(params, tokens, cfg, image_embeds=img)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert logits.dtype == jnp.float32
        assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
        assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux"


class TestSmokeTrainStep:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_one_train_step(self, arch):
        cfg = dataclasses.replace(get_config(arch, smoke=True),
                                  dtype="float32")
        params, _ = init_lm(KEY, cfg)
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        opt = init_opt_state(params, opt_cfg)
        tokens, img = _inputs(cfg)

        def loss_fn(p):
            return lm_loss(p, tokens, tokens, cfg, image_embeds=img)

        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
        new_params, new_opt, metrics = adamw_update(grads, opt, params,
                                                    opt_cfg)
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        assert int(new_opt["step"]) == 1
        # parameters actually moved
        moved = any(
            not np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(new_params)))
        assert moved, f"{arch}: update was a no-op"


class TestFullConfigStructure:
    """FULL configs: abstract-only validation (no allocation)."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_abstract_param_count_matches_formula(self, arch):
        cfg = get_config(arch)
        abs_params = init_lm_abstract(jax.random.PRNGKey(0), cfg)
        n = sum(int(np.prod(l.shape))
                for l in jax.tree.leaves(abs_params))
        formula = cfg.param_count()
        assert abs(n - formula) / formula < 0.02, \
            f"{arch}: abstract {n} vs formula {formula}"

    @pytest.mark.parametrize("arch", ARCHS)
    def test_assigned_shape_set(self, arch):
        cfg = get_config(arch)
        names = {s.name for s in shapes_for(cfg)}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= names
        assert ("long_500k" in names) == cfg.subquadratic


class TestPaddedHeads:
    """TP head padding (§Perf): pad rows are zero and inert at init."""

    def test_padded_forward_matches_shapes_and_pads_are_zero(self):
        import numpy as np
        cfg = dataclasses.replace(get_config("deepseek-coder-33b",
                                             smoke=True),
                                  dtype="float32", num_heads=6,
                                  num_kv_heads=2, padded_heads=8)
        params, _ = init_lm(KEY, cfg)
        wq = params["blocks"][0]["mixer"]["wq"]
        wo = params["blocks"][0]["mixer"]["wo"]
        assert wq.shape[2] == 8 and wo.shape[1] == 8
        assert np.allclose(np.asarray(wq[:, :, 6:]), 0.0)
        assert np.allclose(np.asarray(wo[:, 6:]), 0.0)
        tokens, img = _inputs(cfg)
        logits, _ = forward(params, tokens, cfg)
        assert bool(jnp.isfinite(logits).all())

    def test_pad_heads_are_inert(self):
        """Pad heads cannot influence the output: garbage in their wq rows
        changes nothing because their wo rows are zero.  (Note: padding
        changes the GQA head→kv *grouping* relative to the unpadded arch —
        a documented layout choice, not a numerical identity; see
        EXPERIMENTS.md §Perf.)"""
        import numpy as np
        cfg = dataclasses.replace(get_config("qwen2-7b", smoke=True),
                                  dtype="float32", num_heads=6,
                                  num_kv_heads=2, padded_heads=8)
        params, _ = init_lm(KEY, cfg)
        tokens, _ = _inputs(cfg)
        logits_ref, _ = forward(params, tokens, cfg)
        poisoned = jax.tree.map(lambda x: x, params)
        for blk in poisoned["blocks"]:
            m = blk["mixer"]
            # stacked layout (layers, d, heads, hd): poison pad heads
            m["wq"] = m["wq"].at[:, :, 6:, :].set(37.0)
        logits_poisoned, _ = forward(poisoned, tokens, cfg)
        np.testing.assert_allclose(np.asarray(logits_ref),
                                   np.asarray(logits_poisoned),
                                   rtol=1e-5, atol=1e-5)
