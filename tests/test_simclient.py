"""Simulator-native federation clients: ring routing under contention,
outage storms, hedged fetches, and the sim-accounting regression fixes."""
import pytest

from repro.core import (
    CacheServer, ControlPlaneSpec, Coord, DownloadResult, FluidFlowSim,
    LocalCache, Origin, OutageEvent, OutageSchedule, Payload, ScenarioEngine,
    SizeAwareAdmission, Topology, abusive_workload, build_fleet_federation,
    build_osg_federation, first_of, generate_workload, herd_workload,
    stash_download, storm_workload,
)


def _mini_world(admission=None, capacity=int(1e12)):
    """One site: cache + origin + redirector + two workers, one sim."""
    topo = Topology()
    topo.add_site("s")
    cnode = topo.add_node("s/cache", Coord("s", 253, 0), 1e10)
    onode = topo.add_node("s/origin", Coord("s", 255, 0), 1e10)
    topo.add_node("s/rd", Coord("s", 254, 0), 1e10)
    topo.add_node("s/w0", Coord("s", 0, 0), 1e10)
    topo.add_node("s/w1", Coord("s", 0, 1), 1e10)
    cache = CacheServer("s/cache", cnode, capacity, admission=admission)
    origin = Origin("s/origin", onode)
    sim = FluidFlowSim(topo)
    return sim, cache, origin


class TestSimClientRouting:
    def test_object_lands_on_ring_owner_of_nearest_group(self):
        fed = build_fleet_federation(num_pods=2, hosts_per_pod=2,
                                     cache_replicas=3)
        eng = ScenarioEngine(fed)
        reqs = [r for r in generate_workload(["pod0"], 12, working_set=12,
                                             seed=3)]
        rep = eng.replay(reqs)
        assert all(r.seconds > 0 for r in rep.results)
        pod0 = {c.name for c in fed.groups["pod0"].members}
        group = fed.groups["pod0"]
        for r in rep.results:
            assert r.source in pod0                      # nearest group
            assert r.source == group.route(
                r.path, count_stats=False)[0].name       # ...ring owner
        assert group.stats.routes > 0

    def test_outage_fails_over_to_ring_successor(self):
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=2,
                                     cache_replicas=3)
        group = fed.groups["pod0"]
        eng = ScenarioEngine(fed)
        path = "/exp/data/f0"
        fed.origins[0].put_object(path, int(5e7))
        chain = group.route(path, count_stats=False)
        owner, successor = chain[0], chain[1]
        owner.available = False
        res = DownloadResult(path, int(5e7), "simclient")
        eng.sim.spawn(eng.client("pod0", 0).download(path, result=res))
        eng.sim.run()
        assert res.seconds > 0
        assert res.source == successor.name
        assert res.failovers >= 1
        assert group.stats.failovers >= 1

    def test_blackout_falls_back_to_origin_direct(self):
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=1,
                                     cache_replicas=2)
        for c in fed.caches.values():
            c.available = False
        eng = ScenarioEngine(fed)
        path = "/exp/data/dark"
        fed.origins[0].put_object(path, int(5e7))
        res = DownloadResult(path, int(5e7), "simclient")
        eng.sim.spawn(eng.client("pod0", 0).download(path, result=res))
        eng.sim.run()
        assert res.seconds > 0 and not res.cache_hit
        assert res.method == "origin-direct"
        assert res.source == fed.origins[0].name
        assert eng.client("pod0", 0).stats.origin_fallbacks == 1

    def test_ranked_caches_limit_truncates_multi_member_groups(self):
        """The failover tail stops at `limit` even when a group boundary
        lands mid-budget (groups contribute whole ring chains)."""
        fed = build_fleet_federation(num_pods=3, hosts_per_pod=1,
                                     cache_replicas=6)
        client = fed.client("pod0", 0)
        ranked = client._ranked_caches(path="/some/object", limit=8)
        assert len(ranked) == 8
        assert len(client._ranked_caches(path="/some/object")) == 18

    def test_modulo_router_reshuffles_more_than_ring_on_death(self):
        """Ring vs modulo *under contention*: killing one of four
        replicas mid-trace remaps ~1/4 of the keyspace for the ring but
        reshuffles nearly everything for hash-mod-alive."""
        origin_bytes = {}
        for router in ("ring", "modulo"):
            fed = build_fleet_federation(num_pods=1, hosts_per_pod=4,
                                         cache_replicas=4)
            eng = ScenarioEngine(fed, router=router)
            reqs = generate_workload(["pod0"], 220, working_set=24, seed=5,
                                     duration=600.0)
            victim = fed.groups["pod0"].members[1].name
            sched = OutageSchedule([OutageEvent(300.0, victim, "down")])
            rep = eng.replay(reqs, schedule=sched)
            assert all(r.seconds > 0 for r in rep.results)
            origin_bytes[router] = rep.origin_egress_bytes
        assert origin_bytes["ring"] <= origin_bytes["modulo"]


class TestCollapsedForwarding:
    def test_one_pull_many_waiters_single_origin_read(self):
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=4)
        eng = ScenarioEngine(fed)
        reqs = storm_workload(["pod0"], path="/ckpt/params", size=int(2e8),
                              workers_per_site=4)
        rep = eng.replay(reqs)
        assert all(r.seconds > 0 for r in rep.results)
        # one origin pull feeds all four workers
        assert rep.origin_egress_bytes == int(2e8)
        # the puller is a plain miss; waiters paid miss latency too and
        # must not be recorded as cache hits
        assert all(not r.cache_hit for r in rep.results)
        assert sum(1 for r in rep.results if r.waited) == 3

    def test_waiters_counted_misses_when_admission_rejects(self):
        sim, cache, origin = _mini_world(
            admission=SizeAwareAdmission(max_object_fraction=1e-6))
        meta = origin.put_object("/d/big", int(6e7))
        r1 = DownloadResult(meta.path, meta.size, "s")
        r2 = DownloadResult(meta.path, meta.size, "s")
        sim.spawn(stash_download(sim, "s/w0", cache, "s/origin", "s/rd",
                                 meta, 0.01, result=r1))
        sim.spawn(stash_download(sim, "s/w1", cache, "s/origin", "s/rd",
                                 meta, 0.01, result=r2))
        sim.run()
        assert cache.stats.admission_rejects == meta.num_chunks
        # nothing ever became resident: no hit may be recorded anywhere
        assert cache.stats.hits == 0
        assert not r1.cache_hit and not r2.cache_hit
        assert r2.waited or r1.waited

    def test_waiters_counted_hits_when_pull_lands(self):
        sim, cache, origin = _mini_world()
        meta = origin.put_object("/d/ok", int(6e7))
        r1 = DownloadResult(meta.path, meta.size, "s")
        r2 = DownloadResult(meta.path, meta.size, "s")
        sim.spawn(stash_download(sim, "s/w0", cache, "s/origin", "s/rd",
                                 meta, 0.01, result=r1))
        sim.spawn(stash_download(sim, "s/w1", cache, "s/origin", "s/rd",
                                 meta, 0.01, result=r2))
        sim.run()
        # the waiter's chunks were served from cache once the pull landed
        assert cache.stats.hits == meta.num_chunks
        assert cache.stats.misses == meta.num_chunks
        # ...but the *request* still paid miss latency: not a cache hit
        assert not r1.cache_hit and not r2.cache_hit
        waited = r2 if r2.waited else r1
        assert waited.waited and not waited.cache_hit


class TestHedgedFetch:
    def _slow_primary_fed(self):
        fed = build_fleet_federation(num_pods=2, hosts_per_pod=1)
        slow = fed.caches["pod0/cache"]
        slow.mem_object_max = 1e6     # everything disk-bound...
        slow.disk_bw = 1e7            # ...at 10 MB/s
        return fed

    def test_hedge_races_backup_and_wins(self):
        fed = self._slow_primary_fed()
        eng = ScenarioEngine(fed, hedge_after=1.0)
        path = "/d/ckpt"
        fed.origins[0].put_object(path, int(2e9))
        res = DownloadResult(path, int(2e9), "simclient")
        eng.sim.spawn(eng.client("pod0", 0).download(path, result=res))
        eng.sim.run()
        assert res.hedged
        assert res.source == "pod1/cache"    # backup outran the primary
        assert res.seconds < 50              # primary alone needs ~200 s
        assert eng.client("pod0", 0).stats.hedged_fetches == 1

    def test_no_hedge_when_primary_beats_deadline(self):
        fed = build_fleet_federation(num_pods=2, hosts_per_pod=1)
        eng = ScenarioEngine(fed, hedge_after=30.0)
        path = "/d/small"
        fed.origins[0].put_object(path, int(1e8))
        res = DownloadResult(path, int(1e8), "simclient")
        eng.sim.spawn(eng.client("pod0", 0).download(path, result=res))
        eng.sim.run()
        assert not res.hedged
        assert res.source == "pod0/cache"
        assert eng.client("pod0", 0).stats.hedged_fetches == 0

    def test_first_of_already_set_event_fires_immediately(self):
        topo = Topology()
        topo.add_site("s")
        sim = FluidFlowSim(topo)
        ev = sim.event()
        ev.set()
        seen = []

        def proc():
            yield first_of(sim, ev, sim.event())
            seen.append(sim.t)

        sim.spawn(proc())
        sim.run()
        assert seen == [0.0]


class TestOutageSchedules:
    def test_constructors_are_time_ordered(self):
        storm = OutageSchedule.restart_storm(["a", "b"], at=5.0,
                                             downtime=2.0, stagger=1.0)
        times = [e.time for e in storm]
        assert times == sorted(times)
        assert sum(1 for e in storm if e.action == "down") == 2
        roll = OutageSchedule.rolling_upgrade(["a", "b"], start=0.0,
                                              downtime=3.0, gap=1.0)
        downs = [e.time for e in roll if e.action == "down"]
        assert downs == [0.0, 4.0]
        black = OutageSchedule.regional_blackout(["a", "b"], at=2.0,
                                                 duration=8.0)
        assert all(not e.cold for e in black)

    def test_invalid_action_rejected(self):
        with pytest.raises(ValueError):
            OutageEvent(0.0, "c", "sideways")

    def test_cold_restart_loses_disk_warm_keeps_it(self):
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=1,
                                     cache_replicas=2)
        eng = ScenarioEngine(fed)
        group = fed.groups["pod0"]
        path = "/d/f"
        fed.origins[0].put_object(path, int(5e7))
        owner = group.route(path, count_stats=False)[0]
        res = DownloadResult(path, int(5e7), "simclient")
        eng.sim.spawn(eng.client("pod0", 0).download(path, result=res))
        eng.sim.run()
        assert owner.usage_bytes > 0
        eng.apply_outage(OutageEvent(0.0, owner.name, "down"))
        eng.apply_outage(OutageEvent(0.0, owner.name, "up", cold=False))
        assert owner.usage_bytes > 0           # warm recovery keeps data
        eng.apply_outage(OutageEvent(0.0, owner.name, "down"))
        eng.apply_outage(OutageEvent(0.0, owner.name, "up", cold=True))
        assert owner.usage_bytes == 0          # cold restart lost it
        assert group.stats.outages == 2
        assert group.stats.recoveries == 2
        assert group.stats.cold_restarts == 1
        # duplicate "up" on an already-available member is a no-op: it
        # must neither count a recovery nor wipe freshly admitted data
        res2 = DownloadResult(path, int(5e7), "simclient")
        eng.sim.spawn(eng.client("pod0", 1).download(path, result=res2))
        eng.sim.run()
        assert owner.usage_bytes > 0
        eng.apply_outage(OutageEvent(0.0, owner.name, "up", cold=True))
        assert owner.usage_bytes > 0
        assert group.stats.recoveries == 2

    def test_restart_storm_mid_run_completes_with_failovers(self):
        fed = build_fleet_federation(num_pods=4, hosts_per_pod=2,
                                     cache_replicas=2)
        eng = ScenarioEngine(fed)
        reqs = generate_workload([f"pod{p}" for p in range(4)], 120,
                                 working_set=16, seed=11, duration=60.0)
        victims = [c.name for c in fed.groups["pod1"].members]
        sched = OutageSchedule.restart_storm(victims, at=20.0,
                                             downtime=15.0, stagger=2.0)
        rep = eng.replay(reqs, schedule=sched)
        assert all(r.seconds > 0 for r in rep.results)
        assert rep.outages == 2 and rep.recoveries == 2
        # requests to pod1 during the window had to route around
        assert rep.cache_failovers + rep.group_failovers + \
            rep.origin_fallbacks > 0


class TestScenarioCoalescing:
    def test_storm_solves_coalesce_per_event_time(self):
        fed = build_fleet_federation(num_pods=40, hosts_per_pod=1)
        eng = ScenarioEngine(fed)
        reqs = storm_workload([f"pod{p}" for p in range(40)],
                              size=int(1e9), workers_per_site=1)
        rep = eng.replay(reqs)
        assert all(r.seconds > 0 for r in rep.results)
        assert rep.coalescing_ratio >= 10.0


class TestSimAccountingFixes:
    def test_local_cache_refuses_oversize_payload(self):
        lc = LocalCache(capacity_bytes=100)
        lc.put("/a", 0, Payload.synthetic(60, "/a", 0))
        assert lc.usage_bytes == 60
        lc.put("/big", 0, Payload.synthetic(500, "/big", 0))
        # oversize payload refused outright: nothing evicted, no overcommit
        assert lc.get("/big", 0) is None
        assert lc.get("/a", 0) is not None
        assert lc.usage_bytes == 60
        assert lc.usage_bytes <= lc.capacity_bytes

    def test_local_cache_put_replaces_stale_payload(self):
        """A re-fetched chunk with different content must replace the
        resident payload and account the size delta — the old code only
        move_to_end'd the stale entry and returned."""
        lc = LocalCache(capacity_bytes=100)
        lc.put("/a", 0, Payload.synthetic(40, "/a", 0))
        fresh = Payload.from_bytes(b"\x01" * 60)
        lc.put("/a", 0, fresh)
        assert lc.get("/a", 0) is fresh
        assert lc.usage_bytes == 60
        # shrinking replacement adjusts usage downward too
        lc.put("/a", 0, Payload.synthetic(10, "/a", 0))
        assert lc.usage_bytes == 10

    def test_local_cache_replacement_evicts_to_fit(self):
        lc = LocalCache(capacity_bytes=100)
        lc.put("/a", 0, Payload.synthetic(50, "/a", 0))
        lc.put("/b", 0, Payload.synthetic(40, "/b", 0))
        # replacing /a with a bigger payload must evict /b (LRU), not
        # double-count /a's old size.
        lc.put("/a", 0, Payload.synthetic(90, "/a", 0))
        assert lc.get("/b", 0) is None
        assert lc.usage_bytes == 90
        assert lc.usage_bytes <= lc.capacity_bytes

    def test_local_cache_oversize_replacement_drops_stale(self):
        """If the replacement itself can never fit, the superseded stale
        payload must not survive either."""
        lc = LocalCache(capacity_bytes=100)
        lc.put("/a", 0, Payload.synthetic(40, "/a", 0))
        lc.put("/a", 0, Payload.synthetic(500, "/a", 0))
        assert lc.get("/a", 0) is None
        assert lc.usage_bytes == 0

    def test_proxy_miss_counts_origin_egress(self):
        fed = build_osg_federation()
        origin = fed.origins[0]
        proxy = fed.proxies["nebraska"]
        meta = origin.put_object("/t/small", int(4e7))
        before = origin.stats.egress_bytes
        proxy.get_object(fed.client("nebraska", 0).node.name, meta, now=0.0)
        assert origin.stats.egress_bytes - before == meta.size
        # a hit must not touch the origin again
        mid = origin.stats.egress_bytes
        proxy.get_object(fed.client("nebraska", 0).node.name, meta, now=1.0)
        assert origin.stats.egress_bytes == mid

    def test_sim_proxy_download_counts_origin_egress(self):
        from repro.core import proxy_download
        fed = build_osg_federation()
        origin = fed.origins[0]
        proxy = fed.proxies["nebraska"]
        meta = origin.put_object("/t/sim_small", int(4e7))
        sim = FluidFlowSim(fed.topology, fed.net)
        before = origin.stats.egress_bytes
        sim.spawn(proxy_download(sim, fed.client("nebraska", 0).node.name,
                                 proxy, origin.node.name, meta))
        sim.run()
        assert origin.stats.egress_bytes - before == meta.size

class TestControlPlaneFaults:
    """Fault injection at the control-plane seams: hedges racing
    outages, breakers opening mid-storm, quota exhaustion during a
    cold-restart wave.  The common invariant is *exact accounting* —
    no double-counted loser bytes, no lost shed requests."""

    def test_hedge_races_mid_transfer_mark_down(self):
        """The slow primary is marked down while its (losing) hedge arm
        is mid-transfer: the backup must still win, and the completed
        request's bytes must be counted exactly once."""
        fed = build_fleet_federation(num_pods=2, hosts_per_pod=1)
        slow = fed.caches["pod0/cache"]
        slow.mem_object_max = 1e6
        slow.disk_bw = 1e7                    # primary alone needs ~200 s
        eng = ScenarioEngine(fed, hedge_after=1.0,
                             control=ControlPlaneSpec())
        path = "/d/ckpt"
        fed.origins[0].put_object(path, int(2e9))
        res = DownloadResult(path, int(2e9), "simclient")
        eng.sim.spawn(eng.client("pod0", 0).download(path, result=res))

        def killer():
            yield eng.sim.delay(5.0)
            eng.apply_outage(OutageEvent(5.0, "pod0/cache", "down"))

        eng.sim.spawn(killer())
        eng.sim.run()
        assert res.seconds > 0 and not res.shed
        assert res.hedged
        assert res.source == "pod1/cache"
        assert fed.groups["pod0"].stats.outages == 1
        rep = eng.report([res])
        # the loser arm's abandoned transfer must not inflate the row
        assert rep.bytes_moved == int(2e9)
        assert rep.sheds == 0

    def test_breaker_opens_during_flap_and_skips_after_recovery(self):
        """A cache flaps down/up mid-storm.  Failures while it is dark
        open its breaker; once it returns, the still-open breaker keeps
        skipping it (no burned attempt) until the cooldown elapses —
        and every request still completes elsewhere."""
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=2,
                                     cache_replicas=2)
        victim = fed.groups["pod0"].members[0]
        spec = ControlPlaneSpec(breaker_threshold=2, breaker_cooldown=250.0,
                                health_enabled=False, backoff_base=0.0)
        eng = ScenarioEngine(fed, control=spec)
        reqs = generate_workload(["pod0"], 90, working_set=12, seed=7,
                                 duration=300.0)

        def flapper():
            # silent death: the ring keeps routing to it (no mark_down),
            # so only the client-side breaker can learn it is gone
            yield eng.sim.delay(50.0)
            victim.available = False
            yield eng.sim.delay(100.0)
            victim.available = True

        eng.sim.spawn(flapper())
        rep = eng.replay(reqs)
        assert all(r.seconds > 0 for r in rep.results)
        assert rep.sheds == 0
        assert rep.breaker_opens >= 1
        # available again but breaker still open: requests skipped it
        assert rep.breaker_skips >= 1

    def test_quota_exhaustion_during_cold_restart_wave(self):
        """Thundering herd through a 1-slot/1-waiter queue while the
        ring cold-restarts underneath: every request is either completed
        or explicitly shed — none lost, none double-counted."""
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=2,
                                     cache_replicas=2)
        spec = ControlPlaneSpec(max_concurrent=1, queue_depth=1,
                                breaker_enabled=False, health_enabled=False,
                                backoff_base=0.0)
        eng = ScenarioEngine(fed, control=spec)
        reqs = herd_workload(["pod0"], size=int(5e8), workers_per_site=6,
                             waves=2, wave_gap=10.0)
        victims = [c.name for c in fed.groups["pod0"].members]
        sched = OutageSchedule.restart_storm(victims, at=5.0, downtime=8.0,
                                             stagger=2.0)
        rep = eng.replay(reqs, schedule=sched)
        assert len(rep.results) == 12
        completed = [r for r in rep.results if r.seconds > 0]
        shed = [r for r in rep.results if r.shed]
        # disjoint and exhaustive: a shed request never completed, a
        # completed one was never shed, and nothing fell through
        assert not set(map(id, completed)) & set(map(id, shed))
        assert len(completed) + len(shed) == len(rep.results)
        assert all(r.method == "shed" and r.seconds == 0 for r in shed)
        assert len(shed) >= 1              # the 6-deep wave must shed
        # report-level counters agree with both the rows and the
        # control plane's own ledger
        assert rep.sheds == len(shed) == eng.control.stats.sheds
        assert rep.bytes_moved == sum(r.size for r in completed)

    def test_abusive_tenant_sheds_first_under_quota(self):
        """Per-tenant quotas make load-shedding discriminate: the
        cache-busting tenant absorbs the sheds while the background
        experiment keeps a higher completion rate."""
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=2,
                                     cache_replicas=2)
        spec = ControlPlaneSpec(max_concurrent=2, queue_depth=2,
                                tenant_quota=0.5, breaker_enabled=False,
                                health_enabled=False, backoff_base=0.0)
        eng = ScenarioEngine(fed, control=spec)
        reqs = abusive_workload(["pod0"], 40, duration=400.0, seed=3,
                                tenants={"phys": 1.0},
                                abusive_tenant="abuser", abuse_factor=2.0,
                                abuse_at=50.0, abuse_duration=20.0,
                                abuse_size=int(8e8))
        rep = eng.replay(reqs)
        by_tenant = eng.control.stats.shed_by_tenant
        assert by_tenant.get("abuser", 0) >= 1
        assert by_tenant.get("abuser", 0) > by_tenant.get("phys", 0)

        def rate(tenant):
            rows = [r for r in rep.results
                    if (tenant == "abuser") == r.path.startswith("/abuse/")]
            return sum(1 for r in rows if r.seconds > 0) / len(rows)

        assert rate("phys") > rate("abuser")


class TestSolverEdgeCases:
    @pytest.mark.parametrize("solver", ["scalar", "vector"])
    def test_same_node_flow_completes_under_both_solvers(self, solver):
        """Loopback flows cross no capacity link; the vector solver used
        to retire their all-dummy rows at rate 0 and livelock run()."""
        topo = Topology()
        topo.add_site("s")
        topo.add_node("s/n", Coord("s", 0, 0), 1e9)
        sim = FluidFlowSim(topo, solver=solver)
        done = []

        def proc():
            yield sim.flow("s/n", "s/n", 1e8, streams=4)
            done.append(sim.t)

        sim.spawn(proc())
        sim.run()
        assert done and done[0] < 1.0  # TCP-cap bound, near-instant
