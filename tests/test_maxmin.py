"""Vectorized max-min solver: parity with the scalar oracle + simulator,
plus the pow2-bucketed batch solver (``repro.kernels.batched_maxmin``)
that prices whole sweep columns in one vmapped call."""
import numpy as np
import pytest

from repro.core import (BandwidthProfile, Coord, FluidFlowSim, Topology)
from repro.kernels.batched_maxmin import maxmin_rates_batch
from repro.kernels.maxmin import (maxmin_rates, maxmin_rates_sparse,
                                  pad_problem, solve_waterfill)
from repro.kernels.ref import maxmin_ref


def _random_instance(rng, F, L):
    mem = rng.random((F, L)) < 0.3
    for f in range(F):
        if not mem[f].any():
            mem[f, rng.integers(0, L)] = True
    caps = rng.uniform(1e8, 1e10, L)
    fcaps = rng.uniform(1e7, 5e9, F)
    return mem, caps, fcaps


class TestSolverParity:
    def test_matches_scalar_oracle_on_random_instances(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            F, L = int(rng.integers(1, 60)), int(rng.integers(2, 30))
            mem, caps, fcaps = _random_instance(rng, F, L)
            ref = maxmin_ref(caps, mem, fcaps)
            vec = maxmin_rates(caps, mem, fcaps)
            np.testing.assert_allclose(vec, ref, rtol=2e-3, atol=1e3)

    def test_single_flow_gets_bottleneck(self):
        rates = maxmin_rates(np.array([1e9, 5e8]),
                             np.array([[1, 1]]), np.array([1e12]))
        assert rates[0] == pytest.approx(5e8, rel=1e-3)

    def test_flow_cap_binds_below_fair_share(self):
        # two flows share a 1e9 link; one is TCP-capped at 1e8 → the
        # other takes the leftover 9e8 (max-min, not equal split).
        rates = maxmin_rates(np.array([1e9]),
                             np.array([[1], [1]]), np.array([1e8, 1e12]))
        assert rates[0] == pytest.approx(1e8, rel=1e-3)
        assert rates[1] == pytest.approx(9e8, rel=1e-3)

    def test_equal_split_on_shared_bottleneck(self):
        rates = maxmin_rates(np.array([1e9]),
                             np.array([[1]] * 4), np.array([1e12] * 4))
        np.testing.assert_allclose(rates, 2.5e8, rtol=1e-3)

    def test_sparse_api_matches_dense(self):
        rng = np.random.default_rng(3)
        mem, caps, fcaps = _random_instance(rng, 24, 12)
        dense = maxmin_rates(caps, mem, fcaps)
        sparse = maxmin_rates_sparse(
            caps, [list(np.nonzero(row)[0]) for row in mem], fcaps)
        np.testing.assert_allclose(sparse, dense, rtol=1e-5)

    def test_zero_link_flows_get_their_cap(self):
        """Flows crossing no capacity-bearing link (loopback transfers)
        look like padding to the batched solver; they must still get
        their TCP cap, as the scalar oracle assigns."""
        rates = maxmin_rates_sparse([1e9], [[0], [], [0], []],
                                    [1e12, 3e8, 1e12, 7e8])
        assert rates[1] == pytest.approx(3e8, rel=1e-4)
        assert rates[3] == pytest.approx(7e8, rel=1e-4)
        # linked flows still split the shared link, unaffected
        assert rates[0] == pytest.approx(5e8, rel=1e-3)
        assert rates[2] == pytest.approx(5e8, rel=1e-3)

    def test_zero_link_rows_match_scalar_oracle(self):
        rng = np.random.default_rng(7)
        mem, caps, fcaps = _random_instance(rng, 30, 10)
        mem[::4] = False            # every 4th flow crosses no link
        ref = maxmin_ref(caps, mem, fcaps)
        vec = maxmin_rates(caps, mem, fcaps)
        np.testing.assert_allclose(vec, ref, rtol=2e-3, atol=1e3)

    def test_conservation_no_link_oversubscribed(self):
        rng = np.random.default_rng(5)
        mem, caps, fcaps = _random_instance(rng, 80, 20)
        rates = maxmin_rates(caps, mem, fcaps)
        per_link = mem.T @ rates
        assert (per_link <= caps * (1 + 1e-3)).all()
        assert (rates <= fcaps * (1 + 1e-3)).all()


def _sparse_instance(rng, F, L, max_width=5):
    flow_links = [list(rng.choice(L, size=rng.integers(0, min(L, max_width)
                                                       + 1), replace=False))
                  for _ in range(F)]
    caps = list(rng.uniform(1e8, 1e10, L))
    fcaps = list(rng.uniform(1e7, 5e9, F))
    return caps, flow_links, fcaps


class TestBatchedSolver:
    """``maxmin_rates_batch``: heterogeneous problems, one vmapped call
    per pow2 bucket, element-wise parity with the single-problem path."""

    def test_matches_single_problem_solver(self):
        rng = np.random.default_rng(11)
        problems = [_sparse_instance(rng, int(rng.integers(1, 50)),
                                     int(rng.integers(1, 14)))
                    for _ in range(12)]
        stats = {}
        batch = maxmin_rates_batch(problems, stats=stats)
        assert stats["solve_calls"] >= 1
        assert stats["problems"] == 12
        assert sum(b for b, *_ in stats["buckets"]) \
            == 12 + stats["padded_problems"]
        for p, r in zip(problems, batch):
            single = maxmin_rates_sparse(*p)
            np.testing.assert_allclose(r, single, rtol=1e-4, atol=1e3)

    def test_batch_of_one(self):
        """The pow2-padding edge case the sweep hits on a 1-cell sweep."""
        p = ([1e9], [[0], [0]], [1e12, 1e12])
        stats = {}
        (rates,) = maxmin_rates_batch([p], stats=stats)
        np.testing.assert_allclose(rates, [5e8, 5e8], rtol=1e-3)
        assert stats["solve_calls"] == 1
        assert stats["buckets"][0][0] == 1  # batch padded to pow2 >= 1

    def test_ragged_link_counts_share_a_bucket(self):
        """Problems with different real (flows, links) that pad to the
        same bucket must solve in ONE call — and each get its own
        dummy-slot layout right."""
        rng = np.random.default_rng(13)
        problems = [_sparse_instance(rng, 5, 3, max_width=4),
                    _sparse_instance(rng, 7, 6, max_width=4),  # ragged L
                    _sparse_instance(rng, 8, 7, max_width=4)]
        stats = {}
        batch = maxmin_rates_batch(problems, stats=stats)
        assert stats["solve_calls"] == 1, stats["buckets"]
        for p, r in zip(problems, batch):
            np.testing.assert_allclose(r, maxmin_rates_sparse(*p),
                                       rtol=1e-4, atol=1e3)

    def test_loopback_rows_get_their_cap(self):
        p = ([1e9], [[0], [], [0]], [1e12, 3e8, 1e12])
        (rates,) = maxmin_rates_batch([p])
        assert rates[1] == pytest.approx(3e8, rel=1e-4)
        np.testing.assert_allclose(rates[[0, 2]], 5e8, rtol=1e-3)

    def test_matches_scalar_oracle(self):
        rng = np.random.default_rng(17)
        mems, problems = [], []
        for _ in range(6):
            F, L = int(rng.integers(2, 40)), int(rng.integers(2, 12))
            mem = rng.random((F, L)) < 0.4
            caps = rng.uniform(1e8, 1e10, L)
            fcaps = rng.uniform(1e7, 5e9, F)
            mems.append((caps, mem, fcaps))
            problems.append((list(caps),
                             [list(np.nonzero(row)[0]) for row in mem],
                             list(fcaps)))
        for (caps, mem, fcaps), rates in zip(mems,
                                             maxmin_rates_batch(problems)):
            ref = maxmin_ref(caps, mem, fcaps)
            np.testing.assert_allclose(rates, ref, rtol=2e-3, atol=1e3)

    def test_pad_problem_rejects_overflow(self):
        with pytest.raises(ValueError):
            pad_problem([1e9] * 9, [[0]], [1e8], Fp=8, Lp=8, width=4)
        with pytest.raises(ValueError):
            pad_problem([1e9], [[0] * 5], [1e8], Fp=8, Lp=8, width=4)

    def test_solve_waterfill_is_the_jitted_core(self):
        """The exposed core solves the same problem the wrapped path
        does (the batched module vmaps exactly this function)."""
        import jax.numpy as jnp
        caps, ids, fcaps = pad_problem([1e9], [[0], [0]], [1e12, 1e12],
                                       Fp=8, Lp=8, width=4)
        rates = np.asarray(solve_waterfill(jnp.asarray(caps),
                                           jnp.asarray(ids),
                                           jnp.asarray(fcaps)))
        np.testing.assert_allclose(rates[:2], 5e8, rtol=1e-3)
        assert (rates[2:] == 0).all()


def _topo(n_sites, uplink=1e9):
    topo = Topology()
    prof = BandwidthProfile(site_uplink=uplink)
    for s in range(n_sites):
        topo.add_site(f"s{s}", prof)
        topo.add_node(f"s{s}/w", Coord(f"s{s}", 0, 0), 1e9)
    return topo


class TestSimulatorSolverEquivalence:
    @pytest.mark.parametrize("solver", ["scalar", "vector"])
    def test_two_flow_fair_share(self, solver):
        topo = _topo(3)
        sim = FluidFlowSim(topo, solver=solver)
        finish = []

        def proc(src):
            yield sim.flow(src, "s2/w", 1e9, streams=16)
            finish.append(sim.t)

        sim.spawn(proc("s0/w"))
        sim.spawn(proc("s1/w"))
        sim.run()
        assert finish[-1] == pytest.approx(2.0, rel=0.05)

    def test_same_completion_times_across_solvers(self):
        rng = np.random.default_rng(9)
        times = {}
        for solver in ("scalar", "vector"):
            topo = _topo(12)
            sim = FluidFlowSim(topo, solver=solver)
            done = []

            def proc(src, dst, nbytes, streams):
                yield sim.flow(src, dst, nbytes, streams=streams)
                done.append(sim.t)

            r = np.random.default_rng(9)   # identical workload per solver
            for i in range(40):
                a, b = r.choice(12, 2, replace=False)
                sim.spawn(proc(f"s{a}/w", f"s{b}/w",
                               float(r.uniform(1e8, 2e9)),
                               int(r.integers(1, 16))))
            sim.run()
            times[solver] = sorted(done)
        np.testing.assert_allclose(times["vector"], times["scalar"],
                                   rtol=1e-4)

    def test_unknown_solver_rejected(self):
        with pytest.raises(ValueError):
            FluidFlowSim(_topo(2), solver="quantum")
