"""Monitoring pipeline (§3.2), indexer (§3.1), write-back (§6) tests."""
import pytest

from repro.core import (
    Coord, FileClose, FileOpen, MessageBus, MonitorCollector, Origin,
    Topology, UsageAggregator, UserLogin, build_osg_federation,
    experiment_of,
)


class TestMonitoring:
    def _collector(self, drop=0.0):
        bus = MessageBus()
        agg = UsageAggregator(bucket_seconds=60.0)
        bus.subscribe(agg)
        return MonitorCollector(bus, drop_rate=drop), agg

    def test_join_on_file_close(self):
        col, agg = self._collector()
        col.user_login(UserLogin("cacheA", 7, "host1", "xrootd", False, 0.0))
        col.file_open(FileOpen("cacheA", 42, 7, "/ligo/f", 1000, 1.0))
        col.file_close(FileClose("cacheA", 42, 900, 0, 3, 5.0))
        assert agg.records == 1
        assert agg.by_experiment["ligo"] == 900

    def test_lost_open_packet_tolerated(self):
        """UDP is lossy; a close without its open must not crash the join."""
        col, agg = self._collector()
        col.file_close(FileClose("cacheA", 99, 100, 0, 1, 1.0))
        assert col.unjoined == 1
        assert agg.records == 0

    def test_usage_table_ordering(self):
        col, agg = self._collector()
        for i, (exp, nbytes) in enumerate([("ligo", 100), ("des", 500)]):
            col.user_login(UserLogin("c", i, "h", "http", True, 0.0))
            col.file_open(FileOpen("c", i, i, f"/{exp}/f", nbytes, 0.0))
            col.file_close(FileClose("c", i, nbytes, 0, 1, 2.0))
        table = agg.usage_table()
        assert table[0] == ("des", 500) and table[1] == ("ligo", 100)

    def test_experiment_from_path(self):
        assert experiment_of("/ligo/frames/f1") == "ligo"
        assert experiment_of("weird") == "weird"

    def test_federation_emits_monitoring_records(self):
        fed = build_osg_federation()
        fed.origins[0].put_object("/nova/f", b"x" * 50_000)
        fed.client("nebraska", 0).read("/nova/f")
        assert fed.aggregator.records >= 1
        assert fed.aggregator.by_experiment["nova"] >= 50_000


class TestIndexer:
    def _origin(self):
        topo = Topology()
        topo.add_site("s")
        node = topo.add_node("o", Coord("s"), 1e10)
        return Origin("o", node, exports=["/"])

    def test_scan_builds_catalog_with_chunk_checksums(self):
        o = self._origin()
        o.put_object("/exp/a", b"a" * 100, mtime=1.0)
        o.put_object("/exp/b", b"b" * 100, mtime=1.0)
        from repro.core import Indexer
        idx = Indexer(o)
        st = idx.scan()
        assert st.files_scanned == 2 and st.files_reindexed == 2
        assert "/exp/a" in idx.catalog
        assert idx.catalog.lookup("/exp/a").chunk_digests

    def test_reindex_only_on_mtime_or_size_change(self):
        o = self._origin()
        o.put_object("/exp/a", b"a" * 100, mtime=1.0)
        from repro.core import Indexer
        idx = Indexer(o)
        idx.scan()
        st = idx.scan()                       # unchanged → no reindex
        assert st.files_reindexed == 0
        o.touch("/exp/a", mtime=2.0)          # changed mtime → reindex
        st = idx.scan()
        assert st.files_reindexed == 1

    def test_scan_cost_proportional_to_file_count(self):
        """Paper: delay proportional to the number of files."""
        o = self._origin()
        from repro.core import Indexer
        for i in range(10):
            o.put_object(f"/exp/f{i}", b"z", mtime=1.0)
        t10 = Indexer(o).scan().scan_seconds
        for i in range(10, 100):
            o.put_object(f"/exp/f{i}", b"z", mtime=1.0)
        t100 = Indexer(o).scan().scan_seconds
        assert t100 > 5 * t10

    def test_deleted_files_removed_from_catalog(self):
        o = self._origin()
        o.put_object("/exp/a", b"a", mtime=1.0)
        from repro.core import Indexer
        idx = Indexer(o)
        idx.scan()
        o.delete_object("/exp/a")
        st = idx.scan()
        assert st.files_removed == 1 and "/exp/a" not in idx.catalog


class TestProxyBehaviour:
    def test_large_files_never_cached(self):
        """§5: the 2.3 GB and 10 GB files were never cached by proxies."""
        fed = build_osg_federation()
        origin = fed.origins[0]
        origin.put_object("/t/big", 3 * 10**9)     # synthetic 3 GB
        proxy = fed.proxies["nebraska"]
        meta = origin.meta("/t/big")
        wnode = fed.client("nebraska", 0).node.name
        proxy.get_object(wnode, meta, now=0.0)
        assert not proxy.resident("/t/big", now=0.0)
        assert proxy.stats.uncacheable == 1
        # ... but StashCache caches it fine.
        client = fed.client("nebraska", 0, cvmfs=False)
        client.copy("/t/big")
        assert fed.caches["nebraska/cache"].usage_bytes >= 3 * 10**9

    def test_rapid_expiry_causes_redownload(self):
        """§5: files expired within one pass over the evaluation set."""
        fed = build_osg_federation()
        origin = fed.origins[0]
        origin.put_object("/t/small", 10**6)
        proxy = fed.proxies["chicago"]
        proxy.ttl_seconds = 10.0
        meta = origin.meta("/t/small")
        wnode = fed.client("chicago", 0).node.name
        proxy.get_object(wnode, meta, now=0.0)
        assert proxy.resident("/t/small", now=5.0)
        _, st = proxy.get_object(wnode, meta, now=20.0)  # expired
        assert st.cache_misses == 1
        assert proxy.stats.expirations == 1


class TestWriteback:
    def test_write_then_drain(self):
        fed = build_osg_federation()
        wb = fed.writeback("nebraska/cache")
        data = b"R" * 70_000
        meta, st = wb.write(fed.client("nebraska", 0).node.name, "/nova/out/res.h5", data)
        assert wb.is_dirty("/nova/out/res.h5")
        assert st.bytes == len(data)
        # read-your-writes from the cache before drain
        cache = fed.caches["nebraska/cache"]
        assert cache.resident("/nova/out/res.h5", 0)
        drain = wb.drain()
        assert not wb.is_dirty("/nova/out/res.h5")
        assert fed.origins[0].has("/nova/out/res.h5")
        got, _ = fed.client("chicago", 0).read("/nova/out/res.h5")
        assert got == data

    def test_drain_rate_limit_protects_origin(self):
        """§6: writing to the origin is scheduled, not a thundering herd."""
        fed = build_osg_federation()
        wb = fed.writeback("nebraska/cache", drain_rate=1e6)  # 1 MB/s
        wb.write(fed.client("nebraska", 0).node.name, "/nova/out/a", 10**7)
        st = wb.drain()
        assert st.seconds >= 10**7 / 1e6 * 0.99  # rate-limited

    def test_dirty_chunks_not_evictable(self):
        fed = build_osg_federation()
        cache = fed.caches["nebraska/cache"]
        cache.capacity_bytes = 200_000
        wb = fed.writeback("nebraska/cache")
        wb.write(fed.client("nebraska", 0).node.name, "/nova/out/a", b"a" * 100_000)
        # Fill with other data → dirty object must survive.
        for i in range(5):
            cache.admit("/x", i, __import__(
                "repro.core.chunk", fromlist=["Payload"]
            ).Payload.from_bytes(b"b" * 50_000))
        assert cache.resident("/nova/out/a", 0)
        wb.drain()
        assert fed.origins[0].has("/nova/out/a")
