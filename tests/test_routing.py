"""Pluggable cache ranking: static-vs-probe policy behaviour, the
deterministic GeoIP tie-break, ranked-caches edge cases (limit with
strays, excluding a whole group), Federation.nearest_cache routing
through the ranked/alive ordering, and ranking on both client surfaces."""
import pytest

from repro.core import (Coord, FederationSpec, GeoIPService,
                        ProbeRankingPolicy, RANKING_POLICIES, ScenarioSpec,
                        StaticRankingPolicy, Topology, WorkloadSpec,
                        build_osg_federation, make_ranking_policy,
                        ranked_caches, run_scenario)


def tie_topology():
    """Three caches: two equidistant from the client, one remote."""
    topo = Topology()
    topo.add_node("client", Coord("site-a", rack=0, host=0), 1e9)
    topo.add_node("cache-b", Coord("site-a", rack=1, host=0), 1e9)
    topo.add_node("cache-a", Coord("site-a", rack=2, host=0), 1e9)
    topo.add_node("cache-z", Coord("site-far", rack=0, host=0), 1e9)
    return topo


class TestGeoIPTieBreak:
    def test_equidistant_caches_order_by_name(self):
        geo = GeoIPService(tie_topology())
        order = geo.nearest("client", ["cache-z", "cache-b", "cache-a"])
        # a and b tie on distance; the name tie-break is deterministic
        # regardless of the order the candidates were offered in
        assert order == ["cache-a", "cache-b", "cache-z"]
        assert order == geo.nearest("client",
                                    ["cache-a", "cache-z", "cache-b"])

    def test_exclude_respected(self):
        geo = GeoIPService(tie_topology())
        assert geo.nearest("client", ["cache-a", "cache-b", "cache-z"],
                           exclude=("cache-a",)) == ["cache-b", "cache-z"]


class TestPolicyRegistry:
    def test_make_ranking_policy(self):
        assert isinstance(make_ranking_policy(None), StaticRankingPolicy)
        assert isinstance(make_ranking_policy("probe"), ProbeRankingPolicy)
        probe = ProbeRankingPolicy()
        assert make_ranking_policy(probe) is probe
        with pytest.raises(ValueError):
            make_ranking_policy("nope")
        assert set(RANKING_POLICIES) == {"static", "probe"}


class TestProbeRanking:
    def test_unprobed_caches_keep_static_rank(self):
        geo = GeoIPService(tie_topology())
        names = ["cache-a", "cache-b", "cache-z"]
        assert ProbeRankingPolicy().order("client", names, geo) == \
            StaticRankingPolicy().order("client", names, geo)

    def test_failures_sink_a_cache_and_successes_restore_it(self):
        geo = GeoIPService(tie_topology())
        names = ["cache-a", "cache-b", "cache-z"]
        probe = ProbeRankingPolicy()
        # the nearest cache starts failing: after a couple of failures it
        # ranks below the healthy remote cache
        probe.on_failure("cache-a")
        probe.on_failure("cache-a")
        assert probe.order("client", names, geo)[0] == "cache-b"
        assert probe.order("client", names, geo)[-1] == "cache-a"
        # sustained successful probes decay the penalty back to 1.0
        for _ in range(12):
            probe.observe("cache-a", 0.05)
        assert probe.order("client", names, geo)[0] == "cache-a"

    def test_slowdown_reranks_without_failures(self):
        geo = GeoIPService(tie_topology())
        names = ["cache-a", "cache-b"]
        probe = ProbeRankingPolicy()
        probe.observe("cache-a", 0.05)
        probe.observe("cache-b", 0.05)
        # cache-a degrades to 10x its own baseline; scores are relative
        # slowdowns so it sinks below b even though both were probed
        for _ in range(20):
            probe.observe("cache-a", 0.5)
        assert probe.order("client", names, geo) == ["cache-b", "cache-a"]

    def test_scores_are_relative_to_own_baseline(self):
        # a cache that is *consistently* slow keeps score 1.0 — only
        # getting slower than it used to be counts against it
        probe = ProbeRankingPolicy()
        for _ in range(5):
            probe.observe("slow-but-steady", 2.0)
        assert probe.score("slow-but-steady") == pytest.approx(1.0)


class TestRankedCachesEdgeCases:
    @pytest.fixture()
    def fed(self):
        return build_osg_federation(cache_replicas=2)

    def test_limit_truncates_before_strays(self, fed):
        client = fed.client("chicago", worker=0)
        full = client._ranked_caches(path="/ligo/f1")
        limited = client._ranked_caches(path="/ligo/f1", limit=3)
        assert [c.name for c in limited] == [c.name for c in full[:3]]

    def test_limit_with_stray_caches(self, fed):
        # a registered cache that belongs to no HA group participates
        # policy-ranked at the tail — and the limit still caps the total
        donor = next(iter(fed.caches.values()))
        node = fed.topology.add_node("stray/cache", Coord("stray"), 1e9)
        extra = type(donor)("stray/cache", node, donor.capacity_bytes,
                            redirectors=donor.redirectors, net=donor.net)
        fed.caches["stray/cache"] = extra
        client = fed.client("chicago", worker=0)
        full = client._ranked_caches(path="/ligo/f1")
        assert full[-1].name == "stray/cache"  # remote stray ranks last
        n = len(full)
        assert len(client._ranked_caches(path="/ligo/f1", limit=n - 1)) \
            == n - 1
        assert "stray/cache" not in \
            [c.name for c in client._ranked_caches(path="/ligo/f1",
                                                   limit=n - 1)]

    def test_excluding_entire_nearest_group_falls_through(self, fed):
        client = fed.client("chicago", worker=0)
        full = client._ranked_caches(path="/ligo/f1")
        nearest_group = {c.name for c in full
                         if c.name.startswith("chicago/")}
        assert nearest_group  # chicago hosts a 2-replica group
        ranked = client._ranked_caches(path="/ligo/f1",
                                       exclude=tuple(nearest_group))
        # the whole nearest group is gone; the ranking falls through to
        # the next group's ring order, preserving the remaining order
        assert [c.name for c in ranked] == \
            [c.name for c in full if c.name not in nearest_group]


class TestNearestCache:
    def test_nearest_cache_matches_client_ranking(self):
        fed = build_osg_federation(cache_replicas=2)
        client = fed.client("nebraska", worker=0)
        ranked = client._ranked_caches(path="/des/f7")
        assert fed.nearest_cache("nebraska/worker0", "/des/f7").name == \
            ranked[0].name

    def test_nearest_cache_skips_dead_ring_owner(self):
        fed = build_osg_federation(cache_replicas=2)
        client = fed.client("nebraska", worker=0)
        ranked = client._ranked_caches(path="/des/f7")
        owner = ranked[0]
        for group in fed.groups.values():
            if any(c.name == owner.name for c in group.members):
                group.mark_down(owner.name)
        got = fed.nearest_cache("nebraska/worker0", "/des/f7")
        assert got.available
        assert got.name == ranked[1].name

    def test_nearest_cache_is_stats_neutral(self):
        fed = build_osg_federation(cache_replicas=2)
        fed.client("syracuse", worker=0)  # registers the worker node
        before = {n: (g.stats.routes, g.stats.failovers)
                  for n, g in fed.groups.items()}
        fed.nearest_cache("syracuse/worker0", "/nova/f2")
        after = {n: (g.stats.routes, g.stats.failovers)
                 for n, g in fed.groups.items()}
        assert after == before


class TestScenarioRanking:
    def _spec(self, ranking, engine):
        return ScenarioSpec(
            name=f"rank-{ranking}", engine=engine, ranking=ranking,
            federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=2),
            workload=WorkloadSpec(kind="zipf", n_requests=24,
                                  working_set=8, duration=300.0, seed=5))

    @pytest.mark.parametrize("engine", ["analytic", "sim"])
    def test_static_spec_equals_default(self, engine):
        # ranking="static" must be byte-identical to the historical
        # inline ranking (ranking=None) on both engines
        by_static = run_scenario(self._spec("static", engine)).summary()
        by_none = run_scenario(self._spec(None, engine)).summary()
        for k in ("bytes_moved", "cache_hits", "cache_misses",
                  "origin_egress_bytes", "hit_rate"):
            assert by_static[k] == by_none[k], k

    @pytest.mark.parametrize("engine", ["analytic", "sim"])
    def test_probe_spec_runs(self, engine):
        rep = run_scenario(self._spec("probe", engine)).summary()
        assert rep["completed"] == rep["requests"] == 24

    def test_unknown_ranking_rejected(self):
        with pytest.raises(ValueError):
            run_scenario(self._spec("nope", "analytic"))


class TestRankedCachesFunction:
    def test_groupless_ranking_is_pure_policy_order(self):
        topo = tie_topology()
        geo = GeoIPService(topo)

        class FakeCache:
            def __init__(self, name):
                self.name = name
                self.available = True

        caches = {n: FakeCache(n) for n in ("cache-z", "cache-a", "cache-b")}
        out = ranked_caches("client", caches, [], geo, path="/x")
        assert [c.name for c in out] == ["cache-a", "cache-b", "cache-z"]
        out = ranked_caches("client", caches, [], geo,
                            exclude=("cache-a",), limit=1)
        assert [c.name for c in out] == ["cache-b"]
