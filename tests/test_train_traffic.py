"""LM training/serving traffic through the federation DataPlane.

The api_redesign's test surface: model-derived WorkloadSpecs hold
engine parity, checkpoints round-trip byte-exactly through the plane,
the loader's unified FetchRollup reconciles against the raw
FetchResults it folded, and the pre-redesign call sites keep working
behind DeprecationWarnings.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AnalyticPlane, ClientPlane, FederationSpec,
                        FetchRollup, ScenarioSpec, WorkloadSpec,
                        build_fleet_federation, consumer_table,
                        run_scenario, split_bytes)
from repro.data import DatasetSpec, FederatedDataLoader, SyntheticTokens
from repro.train import FederatedCheckpointer

GB = 1 << 30
PARITY_FIELDS = ("bytes_moved", "cache_hits", "cache_misses",
                 "origin_egress_bytes")


def _fleet(pods=2, hosts=4):
    return FederationSpec.fleet(num_pods=pods, hosts_per_pod=hosts)


class TestWorkloadGeneration:
    def test_split_bytes_sums_exactly(self):
        for total, n in ((10, 3), (1, 1), (0, 4), (68_506_296_320, 64)):
            sizes = split_bytes(total, n)
            assert len(sizes) == n
            assert sum(sizes) == total
            assert max(sizes) - min(sizes) <= 1

    def test_from_model_config_restart_byte_total(self):
        cfg = get_config("deepseek-coder-33b", smoke=False)
        ws = WorkloadSpec.from_model_config(cfg, kind="restart",
                                            shard_bytes=GB)
        assert ws.total_bytes == cfg.param_count() * 2   # bf16
        assert ws.n_objects == -(-ws.total_bytes // GB)
        shards = {p: b for p, b in ws.object_bytes().items()
                  if not p.endswith("manifest.json")}
        assert sum(shards.values()) == ws.total_bytes
        assert ws.model == cfg.name

    def test_from_model_config_rejects_other_kinds(self):
        cfg = get_config("qwen2-7b", smoke=True)
        with pytest.raises(ValueError, match="restart/serve/dataloader"):
            WorkloadSpec.from_model_config(cfg, kind="storm")

    def test_restart_covers_full_checkpoint_per_site(self):
        """With workers >= tp_degree every site pulls every shard."""
        cfg = get_config("qwen2-7b", smoke=True)
        ws = WorkloadSpec.from_model_config(
            cfg, kind="restart", shard_bytes=1 << 20,
            workers_per_site=8, tp_degree=4)
        fed = _fleet(pods=1, hosts=8).build()
        reqs = ws.build(fed)
        fetched = {r.path: r.size for r in reqs
                   if not r.path.endswith("manifest.json")}
        assert sum(fetched.values()) == ws.total_bytes

    def test_thousand_pod_restart_spec(self):
        """The acceptance-scenario spec: 8 sites x 125 workers, tp=25,
        from the real 33B byte total."""
        cfg = get_config("deepseek-coder-33b", smoke=False)
        ws = WorkloadSpec.from_model_config(
            cfg, kind="restart", shard_bytes=GB,
            workers_per_site=125, tp_degree=25)
        fed = _fleet(pods=8, hosts=125).build()
        reqs = ws.build(fed)
        # every one of the 1000 workers fetches the manifest once
        manifests = [r for r in reqs if r.path.endswith("manifest.json")]
        assert len(manifests) == 8 * 125
        # shard i is pulled by the 125/25 = 5 rank-sharers per site
        by_path: dict = {}
        for r in reqs:
            if not r.path.endswith("manifest.json"):
                by_path[r.path] = by_path.get(r.path, 0) + 1
        assert set(by_path.values()) == {8 * (125 // 25)}
        assert len(by_path) == ws.n_objects

    def test_dataloader_kind_is_deterministic(self):
        ws = WorkloadSpec(kind="dataloader", path="/datasets/d",
                          n_objects=8, total_bytes=8 << 20,
                          workers_per_site=4)
        fed = _fleet(pods=1, hosts=4).build()
        a = [(r.path, r.at, r.worker) for r in ws.build(fed)]
        b = [(r.path, r.at, r.worker) for r in ws.build(fed)]
        assert a == b


class TestEngineParity:
    """One workload, two interchangeable engines — the redesign's core
    invariant, held for all three model-traffic kinds."""

    def _both(self, ws):
        reps = {}
        for engine in ("analytic", "sim"):
            reps[engine] = run_scenario(ScenarioSpec(
                name=f"parity/{ws.kind}/{engine}", federation=_fleet(),
                workload=ws, engine=engine))
        return reps

    @pytest.mark.parametrize("kind", ["restart", "serve"])
    def test_model_kinds_parity(self, kind):
        cfg = get_config("qwen2-7b", smoke=True)
        ws = WorkloadSpec.from_model_config(
            cfg, kind=kind, shard_bytes=1 << 20, workers_per_site=4,
            tp_degree=2, n_requests=64)
        reps = self._both(ws)
        for f in PARITY_FIELDS:
            assert getattr(reps["analytic"], f) == \
                getattr(reps["sim"], f), (kind, f)
        assert reps["sim"].bytes_moved > 0

    def test_dataloader_parity(self):
        ws = WorkloadSpec(kind="dataloader", path="/datasets/d",
                          n_objects=16, total_bytes=16 << 20,
                          workers_per_site=4, step_gap=1.0)
        reps = self._both(ws)
        for f in PARITY_FIELDS:
            assert getattr(reps["analytic"], f) == \
                getattr(reps["sim"], f), f

    def test_restart_cache_collapses_egress(self):
        """tp rank-sharers per shard -> cached egress is 1/sharers of
        direct (plus the shared manifest), deterministically."""
        cfg = get_config("qwen2-7b", smoke=True)
        ws = WorkloadSpec.from_model_config(
            cfg, kind="restart", shard_bytes=1 << 20,
            workers_per_site=8, tp_degree=4)
        cached = run_scenario(ScenarioSpec(
            name="e/c", federation=_fleet(pods=1, hosts=8), workload=ws,
            method="stash", engine="analytic"))
        direct = run_scenario(ScenarioSpec(
            name="e/d", federation=_fleet(pods=1, hosts=8), workload=ws,
            method="direct", engine="analytic"))
        assert direct.origin_egress_bytes > \
            1.5 * cached.origin_egress_bytes


class TestCheckpointRoundtrip:
    def _plane(self):
        return AnalyticPlane(_fleet().build())

    def test_save_restore_byte_exact(self):
        plane = self._plane()
        ck = FederatedCheckpointer("rt", plane, site="pod0", worker=0)
        rng = np.random.default_rng(0)
        state = {"params": {"w": rng.normal(size=(33, 7))
                            .astype(np.float32),
                            "b": rng.integers(0, 99, size=(11,))
                            .astype(np.int32)},
                 "step": np.asarray(3, np.int64)}
        ck.save(3, state)
        ck2 = FederatedCheckpointer("rt", plane, site="pod1", worker=0)
        tree, res = ck2.restore(3, like=state)
        assert res.ok
        np.testing.assert_array_equal(tree["params"]["w"],
                                      state["params"]["w"])
        np.testing.assert_array_equal(tree["params"]["b"],
                                      state["params"]["b"])
        assert tree["params"]["w"].dtype == np.float32
        assert tree["params"]["b"].dtype == np.int32

    def test_latest_step_scans_plane_paths(self):
        plane = self._plane()
        ck = FederatedCheckpointer("rt", plane, site="pod0", worker=0)
        assert ck.latest_step() is None
        st = {"w": np.zeros((4,), np.float32)}
        ck.save(2, st)
        ck.save(8, st)
        assert ck.latest_step() == 8

    def test_stats_split_store_and_fetch_lanes(self):
        plane = self._plane()
        ck = FederatedCheckpointer("rt", plane, site="pod0", worker=0)
        st = {"w": np.ones((128,), np.float32)}
        ck.save(1, st)
        assert ck.stats.stores > 0
        assert ck.stats.fetches == 0
        assert ck.stats.bytes_stored >= st["w"].nbytes
        ck.restore(1, like=st)
        assert ck.stats.fetches > 0
        rows = consumer_table([ck.stats])
        assert rows[0]["consumer"] == "checkpointer"
        assert rows[0]["bytes_fetched"] > 0


class TestLoaderRollup:
    def _stack(self):
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=4)
        spec = DatasetSpec("toy", vocab_size=128,
                           tokens_per_shard=1 << 12, num_shards=4)
        SyntheticTokens(spec).publish(fed.origins[0])
        return AnalyticPlane(fed), spec

    def test_rollup_matches_fetch_results(self):
        """loader.stats must be exactly the fold of every FetchResult
        the plane returned — no private accounting on the side."""
        plane, spec = self._stack()
        captured = []
        inner = plane.fetch

        def spy(req):
            res = inner(req)
            captured.append(res)
            return res

        plane.fetch = spy
        loader = FederatedDataLoader(plane, spec, global_batch=4,
                                     seq_len=16, site="pod0", worker=0)
        for s in range(4):
            loader.batch(s)
        st = loader.stats
        assert st.fetches == len(captured)
        assert st.bytes_fetched == sum(r.bytes for r in captured)
        assert st.cache_hits == sum(r.cache_hits for r in captured)
        assert st.cache_misses == sum(r.cache_misses for r in captured)
        assert st.local_hits == sum(r.local_hits for r in captured)
        assert st.steps == 4
        want_hits = st.cache_hits + st.local_hits
        want_total = want_hits + st.cache_misses
        assert st.hit_rate == pytest.approx(want_hits / want_total)

    def test_by_method_breakdown(self):
        plane, spec = self._stack()
        loader = FederatedDataLoader(plane, spec, global_batch=4,
                                     seq_len=16, site="pod0", worker=0)
        loader.batch(0)
        assert set(loader.stats.by_method) == {"cvmfs"}


class TestDeprecationShims:
    def _fed(self):
        fed = build_fleet_federation(num_pods=1, hosts_per_pod=4)
        spec = DatasetSpec("toy", vocab_size=128,
                           tokens_per_shard=1 << 12, num_shards=4)
        SyntheticTokens(spec).publish(fed.origins[0])
        return fed, spec

    def test_loader_accepts_bare_client_with_warning(self):
        fed, spec = self._fed()
        with pytest.warns(DeprecationWarning, match="DataPlane"):
            loader = FederatedDataLoader(fed.client("pod0", 0), spec,
                                         global_batch=4, seq_len=16)
        assert isinstance(loader.plane, ClientPlane)
        b = loader.batch(0)
        assert b["tokens"].shape == (4, 16)
        assert loader.stats.fetches > 0

    def test_checkpointer_accepts_writeback_with_warning(self):
        fed, _ = self._fed()
        st = {"w": np.arange(64, dtype=np.float32)}
        with pytest.warns(DeprecationWarning, match="DataPlane"):
            ck = FederatedCheckpointer("legacy", fed.writeback("pod0/cache"),
                                       fed.client("pod0", 0))
        ck.save(1, st)
        tree, res = ck.restore(1, like=st)
        assert res.ok
        np.testing.assert_array_equal(tree["w"], st["w"])

    def test_legacy_and_plane_paths_agree(self):
        """Same dataset, same step: the shim must produce the same batch
        as the first-class plane path."""
        fed, spec = self._fed()
        plane_loader = FederatedDataLoader(AnalyticPlane(fed), spec,
                                           global_batch=4, seq_len=16,
                                           site="pod0", worker=1)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy_loader = FederatedDataLoader(fed.client("pod0", 2),
                                                spec, global_batch=4,
                                                seq_len=16)
        np.testing.assert_array_equal(plane_loader.batch(5)["tokens"],
                                      legacy_loader.batch(5)["tokens"])


class TestNoDirectClientRefs:
    """Acceptance: the consumers hold no concrete transport types —
    only the DataPlane protocol."""

    @pytest.mark.parametrize("modname", ["repro.data.loader",
                                         "repro.train.checkpoint",
                                         "repro.serve.engine"])
    def test_no_stash_client_or_writeback_imports(self, modname):
        import importlib
        mod = importlib.import_module(modname)
        names = set(vars(mod))
        assert "StashClient" not in names, modname
        assert "WritebackCache" not in names, modname


class TestServeEngineFetchPath:
    def test_from_federation_restores_and_serves(self):
        import dataclasses as dc

        import jax

        from repro.serve import Request, ServeEngine
        cfg = dc.replace(get_config("qwen2-7b", smoke=True),
                         dtype="float32")
        from repro.models import init_lm
        params, _ = init_lm(jax.random.PRNGKey(0), cfg)
        fed = _fleet(pods=1, hosts=4).build()
        plane = AnalyticPlane(fed)
        ck = FederatedCheckpointer("srv", plane, site="pod0", worker=0)
        ck.save(0, params)
        eng = ServeEngine.from_federation(cfg, plane, "srv", step=0,
                                          site="pod0", worker=1,
                                          like=params,
                                          batch_size=1, max_seq=64)
        assert eng.data_stats.fetches > 0
        out = eng.generate([Request(0, np.arange(6), max_new_tokens=3)])
        assert out[0].done

    def test_fetch_shard_folds_into_data_stats(self):
        import dataclasses as dc

        import jax

        from repro.models import init_lm
        from repro.serve import ServeEngine
        cfg = dc.replace(get_config("qwen2-7b", smoke=True),
                         dtype="float32")
        params, _ = init_lm(jax.random.PRNGKey(0), cfg)
        fed = _fleet(pods=1, hosts=4).build()
        plane = AnalyticPlane(fed)
        ck = FederatedCheckpointer("srv", plane, site="pod0", worker=0)
        ck.save(0, {"params": params})
        eng = ServeEngine(cfg, params, batch_size=1, max_seq=64,
                          plane=plane, site="pod0", worker=2)
        res = eng.fetch_shard(ck.prefix(0) + "/manifest.json",
                              method="cvmfs")
        assert res.ok
        assert eng.data_stats.fetches == 1
        assert eng.data_stats.by_method.get("cvmfs")


def test_fetch_rollup_merge_is_total():
    a, b = FetchRollup("x"), FetchRollup("x")
    r = dataclasses.replace  # noqa: F841  (kept for symmetry with api)
    a.fetches, a.bytes_fetched, a.cache_hits = 2, 100, 1
    b.fetches, b.bytes_fetched, b.cache_misses = 3, 50, 2
    a.merge(b)
    assert (a.fetches, a.bytes_fetched) == (5, 150)
    assert (a.cache_hits, a.cache_misses) == (1, 2)
