"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import (CacheServer, CircuitBreaker, ControlPlaneSpec, Coord,
                        DecayGauge, Namespace, NetworkModel, Payload,
                        Topology, chunk_object, fair_shares, fnv1a64)
from repro.core.chunk import synthetic_object
from repro.core.controlplane import AdmissionQueue
from repro.core.simulator import FluidFlowSim


def _cache(capacity):
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node(f"c{capacity}", Coord("s"), 1e9)
    return CacheServer(f"c{capacity}", node, capacity)


class TestCacheInvariants:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 30), st.integers(1, 50)),
                    min_size=1, max_size=200),
           st.integers(50, 500))
    def test_usage_never_exceeds_capacity(self, ops, capacity):
        """LRU invariant: usage ≤ capacity (absent pinning), and usage
        always equals the sum of resident chunk sizes."""
        c = _cache(capacity)
        for idx, size in ops:
            c.admit("/f", idx, Payload.synthetic(size, "/f", idx))
            assert c.usage_bytes <= max(capacity, size)
            assert c.usage_bytes == sum(p.size for p in c._lru.values())

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, 10), min_size=1, max_size=60))
    def test_hit_after_admit_unless_evicted(self, accesses):
        c = _cache(10_000)
        seen = set()
        for idx in accesses:
            if idx in seen:
                assert c.lookup("/f", idx) is not None
            else:
                c.admit("/f", idx, Payload.synthetic(10, "/f", idx))
                seen.add(idx)


class TestChunkingInvariants:
    @settings(max_examples=50, deadline=None)
    @given(st.binary(min_size=0, max_size=5000),
           st.integers(1, 1024))
    def test_chunk_roundtrip(self, data, chunk_size):
        """Chunking is lossless and digests verify."""
        meta, payloads = chunk_object("/x", data, chunk_size=chunk_size)
        assert b"".join(p.data for p in payloads) == data
        assert all(p.verify() for p in payloads)
        assert meta.size == len(data)
        assert len(payloads) == meta.num_chunks

    @settings(max_examples=50, deadline=None)
    @given(st.binary(min_size=1, max_size=2000), st.integers(1, 256),
           st.integers(0, 1999), st.integers(0, 2000))
    def test_partial_range_covered(self, data, chunk_size, off, length):
        """chunks_for_range always covers the requested byte range."""
        meta, payloads = chunk_object("/x", data, chunk_size=chunk_size)
        off = min(off, len(data) - 1)
        length = min(length, len(data) - off)
        refs = meta.chunks_for_range(off, length)
        if length == 0:
            return
        got = b"".join(payloads[r.index].data for r in refs)
        lo = off - refs[0].offset
        assert got[lo:lo + length] == data[off:off + length]

    @settings(max_examples=30, deadline=None)
    @given(st.binary(min_size=1, max_size=500))
    def test_fnv_sensitivity(self, data):
        flipped = bytes([data[0] ^ 1]) + data[1:]
        assert fnv1a64(data) != fnv1a64(flipped)


class TestNamespaceInvariants:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.from_regex(r"/[a-c]{1,3}(/[a-c]{1,3}){0,2}",
                                  fullmatch=True),
                    min_size=1, max_size=10, unique=True))
    def test_longest_prefix_wins(self, prefixes):
        ns = Namespace()
        for i, p in enumerate(prefixes):
            ns.register(p, f"o{i}")
        for i, p in enumerate(prefixes):
            owner = ns.resolve(p + "/leaf")
            # the resolved owner's prefix must be ≥ as long as p
            owned_by = prefixes[int(owner[1:])]
            assert (p + "/leaf").startswith(owned_by)
            assert len(owned_by) >= len(p) or not p.startswith(owned_by)


class TestControlPlaneInvariants:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(0.0, 1e6), min_size=0, max_size=20),
           st.floats(0.0, 1e6))
    def test_fair_shares_sum_to_feasible_total(self, demands, capacity):
        """Allocations never exceed their demand and always sum to
        min(capacity, total demand) — water-filling wastes nothing."""
        alloc = fair_shares(demands, capacity)
        assert len(alloc) == len(demands)
        for a, d in zip(alloc, demands):
            assert a <= d + 1e-6
            assert a >= 0.0
        assert sum(alloc) == pytest.approx(
            min(capacity, sum(demands)), rel=1e-6, abs=1e-6)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["allow", "ok", "fail"]),
                              st.floats(0.0, 10.0)),
                    min_size=1, max_size=60),
           st.integers(1, 5), st.floats(0.1, 20.0))
    def test_breaker_only_takes_legal_edges(self, ops, threshold, cooldown):
        """FSM invariant: the only reachable transitions are closed→open,
        open→half-open, half-open→{open, closed}."""
        legal = {("closed", "open"), ("open", "half-open"),
                 ("half-open", "open"), ("half-open", "closed")}
        br = CircuitBreaker(threshold=threshold, cooldown=cooldown)
        now, prev = 0.0, br.state
        for op, dt in ops:
            now += dt
            if op == "allow":
                br.allow(now)
            elif op == "ok":
                br.on_success(now)
            else:
                br.on_failure(now)
            if br.state != prev:
                assert (prev, br.state) in legal
            prev = br.state

    @settings(max_examples=30, deadline=None)
    @given(st.floats(0.0, 100.0), st.floats(0.1, 100.0),
           st.lists(st.floats(0.001, 1000.0), min_size=1, max_size=20))
    def test_decay_gauge_monotone_under_silence(self, value, tau, gaps):
        """With no adds, successive reads never increase."""
        g = DecayGauge(tau=tau)
        g.add(value, now=0.0)
        now, prev = 0.0, g.read(0.0)
        for gap in gaps:
            now += gap
            cur = g.read(now)
            assert cur <= prev + 1e-12
            prev = cur

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 6), st.integers(0, 8),
           st.lists(st.tuples(st.booleans(), st.integers(0, 3)),
                    min_size=1, max_size=40))
    def test_queue_never_exceeds_bounds(self, max_concurrent, depth, ops):
        """Under any acquire/release interleaving: in-service count stays
        within max_concurrent, the wait queue within queue_depth, and no
        request is lost (admitted + waiting + shed == arrivals)."""
        topo = Topology()
        topo.add_site("s")
        topo.add_node("w", Coord("s"), 1e9)
        sim = FluidFlowSim(topo, NetworkModel(topo))
        spec = ControlPlaneSpec(max_concurrent=max_concurrent,
                                queue_depth=depth)
        q = AdmissionQueue(sim, spec)
        granted, arrivals, released = [], 0, 0

        def req(tenant):
            admitted = yield from q.acquire(tenant)
            if admitted:
                granted.append(tenant)

        for is_acquire, tenant_i in ops:
            tenant = f"t{tenant_i}"
            if is_acquire:
                arrivals += 1
                sim.spawn(req(tenant))
                sim.run()
            elif granted:
                q.release(granted.pop(0))
                released += 1
                sim.run()
            assert q.in_service <= max_concurrent
            assert len(q.waiting) <= depth
            assert q.in_service == sum(q.by_tenant.values())
            assert q.in_service == len(granted)
            # conservation: every arrival is in service, parked, shed,
            # or already released — none vanish
            assert (q.in_service + len(q.waiting) + q.stats.sheds
                    + released) == arrivals
        assert q.max_in_service <= max_concurrent
        assert q.max_waiting <= depth


class TestLoaderMapping:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 500), st.integers(1, 4))
    def test_rank_slices_partition_the_step(self, step, log_world):
        """Across ranks, slices are disjoint and cover the step exactly."""
        from repro.core import build_fleet_federation
        from repro.data import DatasetSpec, FederatedDataLoader
        world = 2 ** log_world
        spec = DatasetSpec("p", vocab_size=64, tokens_per_shard=1 << 10,
                           num_shards=8)
        total = []
        for rank in range(world):
            loader = FederatedDataLoader.__new__(FederatedDataLoader)
            loader.spec = spec
            loader.global_batch = 16
            loader.seq_len = 8
            loader.rank = rank
            loader.world = world
            for shard, off, count in loader.slices_for_step(step):
                total.append((shard, off, count))
        need = 16 * 9  # global_batch × (seq+1)
        assert sum(c for _, _, c in total) == need
        # disjointness within the step (mod wrap-around)
        seen = set()
        for shard, off, count in total:
            for t in range(off, off + count):
                key = (shard, t)
                assert key not in seen
                seen.add(key)


class TestCacheModelInvariants:
    """The planner's differentiable curves must stay physical for
    *every* reuse profile, not just the swept ones."""

    @staticmethod
    def _histogram(thresholds, sizes, compulsory):
        from repro.kernels.cache_model import reuse_histogram
        dist = np.asarray(thresholds, float)
        dist[:compulsory] = np.inf
        return reuse_histogram(dist, np.asarray(sizes, float))

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(1e3, 1e13), min_size=4, max_size=120),
           st.integers(0, 3), st.data())
    def test_hist_curve_monotone_and_bounded(self, thresholds, compulsory,
                                             data):
        from repro.kernels.cache_model import (fit_histogram_model,
                                               predict_hit_rate)
        sizes = [data.draw(st.floats(1.0, t)) for t in thresholds]
        hist = self._histogram(thresholds, sizes,
                               min(compulsory, len(thresholds)))
        model = fit_histogram_model(hist)
        caps = np.geomspace(1.0, 1e15, 40)
        h = np.array([float(predict_hit_rate(model, c)) for c in caps])
        assert (h >= 0.0).all() and (h <= 1.0).all()
        assert (np.diff(h) >= -1e-9).all()

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.floats(1e3, 1e13), min_size=4, max_size=60),
           st.data())
    def test_mixture_curve_monotone_and_bounded(self, thresholds, data):
        from repro.kernels.cache_model import (fit_lognormal_mixture,
                                               predict_hit_rate)
        sizes = [data.draw(st.floats(1.0, t)) for t in thresholds]
        hist = self._histogram(thresholds, sizes, 0)
        model = fit_lognormal_mixture(hist, steps=120)
        caps = np.geomspace(1.0, 1e15, 30)
        h = np.array([float(predict_hit_rate(model, c)) for c in caps])
        assert (h >= 0.0).all() and (h <= 1.0).all()
        assert (np.diff(h) >= -1e-9).all()

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.floats(1e6, 1e12), st.floats(0.0, 1.0)),
                    min_size=1, max_size=20))
    def test_interp_curve_monotone_and_bounded(self, points):
        from repro.kernels.cache_model import (fit_interp_model,
                                               predict_hit_rate)
        model = fit_interp_model([p[0] for p in points],
                                 [p[1] for p in points])
        caps = np.geomspace(1.0, 1e15, 30)
        h = np.array([float(predict_hit_rate(model, c)) for c in caps])
        assert (h >= 0.0).all() and (h <= 1.0).all()
        assert (np.diff(h) >= -1e-9).all()


class TestPlannerFeasibility:
    """Whatever the workload, a plan the verifier returns is feasible
    against the *exact* batched kernels whenever the target is
    reachable at all — the model may smooth, the verification replay
    may scale up, but the report never claims an infeasible point."""

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 3), st.integers(4, 24),
           st.floats(0.3, 0.9))
    def test_verified_plan_is_replay_feasible(self, seed, working_set,
                                              target_frac):
        from repro.core import (FederationSpec, PlannerSpec, ScenarioSpec,
                                SweepSpec, generate_workload,
                                groups_for_federation, plan_capacity,
                                predict, run_sweep, verify_plan)
        fed = FederationSpec.fleet(num_pods=2, hosts_per_pod=2,
                                   cache_capacity=1e9)
        wl = (generate_workload([fed.sites[0].name], 120, seed=seed,
                                working_set=working_set)
              + generate_workload([fed.sites[1].name], 80, seed=seed + 7,
                                  working_set=working_set * 2))
        wl.sort(key=lambda r: r.time)
        base = ScenarioSpec(name="prop", engine="analytic",
                            federation=fed, workload=wl)
        rep = run_sweep(SweepSpec(name="p", base=base, axes={}), fit=True)
        models = rep.fitted_models()
        if not models:
            return
        # aim inside the model's own ceiling so the target is reachable
        ceiling = predict(models, 1e15)["hit_rate"]
        target = max(ceiling * target_frac, 0.01)
        groups = groups_for_federation(fed.build(), models)
        plan = plan_capacity(PlannerSpec(models=models,
                                         target_hit_rate=target,
                                         groups=groups, steps=200))
        ver = verify_plan(plan, base)
        assert ver.verification["feasible"]
        assert ver.verification["achieved_hit_rate"] >= target
        # totals stay consistent after any verification scale-up
        assert ver.total_capacity == pytest.approx(
            sum(ver.per_cache.values()), rel=1e-9)
