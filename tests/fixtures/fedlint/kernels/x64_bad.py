"""Bad fixture: x64-scoping — unscoped JAX float64 + a global flip."""
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)  # process-wide precision flip


def exact_distances(refs):
    xs = jnp.asarray(refs, jnp.float64)  # outside any enable_x64 scope
    return jnp.cumsum(xs)


def stringly(refs):
    return jnp.zeros(len(refs), dtype="float64")
