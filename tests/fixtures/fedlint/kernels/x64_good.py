"""Good fixture: x64-scoping — JAX float64 only under enable_x64."""
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64


def exact_distances(refs):
    with enable_x64():
        xs = jnp.asarray(refs, jnp.float64)
        return jnp.cumsum(xs)


def host_side(refs):
    # host numpy float64 never needs the JAX x64 switch
    return np.asarray(refs, dtype=np.float64).sum()
