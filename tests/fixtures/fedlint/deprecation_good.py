"""Good fixture: deprecation-hygiene — a proper compat shim."""
import warnings


class ClientPlane:
    pass


def modern_path(plane, path):
    return plane.fetch(path)


def compat_fallback(fed):
    # a shim is allowed to construct the deprecated surface because it
    # warns, with stacklevel pointing at the caller
    warnings.warn("use DataPlane.for_federation instead",
                  DeprecationWarning, stacklevel=2)
    return ClientPlane()
