"""Bad fixture: spec-hygiene — every way a sharing key goes wrong."""
from dataclasses import dataclass


class EvictionPolicy:
    pass


@dataclass
class MutableSpec:  # non-frozen dataclass: __eq__ yes, __hash__ = None
    capacity: float = 1.0


class LopsidedSchedule:  # __eq__ without __hash__ (Python sets it None)
    def __init__(self, events=()):
        self.events = list(events)

    def __eq__(self, other):
        return self.events == other.events


class IdentitySpec:  # no eq machinery at all: identity comparison
    def __init__(self, capacity):
        self.capacity = capacity


@dataclass(frozen=True)
class SharedDefaultSpec:
    # frozen, but the default policy instance is shared by every spec
    policy: EvictionPolicy = EvictionPolicy()


class LiteralDefaultSpec:
    tags = []  # class-level mutable literal shared by every instance

    def __eq__(self, other):
        return self.tags == other.tags

    def __hash__(self):
        return 0
