"""Bad fixture: deprecation-hygiene — silent shim use + lazy warning."""
import warnings


class ClientPlane:
    pass


def sneaky_internal_caller(fed):
    # constructs the deprecated shim without any DeprecationWarning
    return ClientPlane()


def lazy_warner():
    # stacklevel=1 (the default): the warning points at the shim itself
    warnings.warn("old API", DeprecationWarning)
