"""Good fixture (analytic side): every counter written by this engine."""
from dataclasses import dataclass


@dataclass
class ScenarioReport:
    name: str = ""
    bytes_moved: int = 0
    cache_hits: int = 0


def report(hits, total):
    return ScenarioReport(name="analytic", bytes_moved=total,
                          cache_hits=hits)
