"""Good fixture (sim side): the same counters written here too."""


def report(rep, flows):
    rep.bytes_moved = sum(f.bytes for f in flows)
    rep.cache_hits += len([f for f in flows if f.hit])
    return rep
