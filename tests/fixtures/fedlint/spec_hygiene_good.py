"""Good fixture: spec-hygiene — value types that behave like values."""
import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class FrozenSpec:
    capacity: float = 1.0
    policies: Tuple[str, ...] = ("lru",)
    tags: list = field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class DottedFrozenSpec:
    ttl: float = 3600.0


class HandRolledSchedule:
    """Explicit __eq__ with a consistent __hash__ is fine."""

    def __init__(self, events=()):
        self.events = tuple(events)

    def __eq__(self, other):
        if not isinstance(other, HandRolledSchedule):
            return NotImplemented
        return self.events == other.events

    def __hash__(self):
        return hash(self.events)


class NotASpecHolder:
    """Not *Spec/*Schedule-named: out of the rule's scope entirely."""

    def __eq__(self, other):
        return True
