"""Good fixture: jit-purity — pure traced functions, effects outside."""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def pure_kernel(x):
    return jnp.cumsum(x) * 2.0


@functools.partial(jax.jit, static_argnames=("n",))
def pure_partial(x, n):
    return x.reshape(n, -1).sum(axis=0)


def seeded_helper(seed):
    # seeded constructors are deterministic factories, not draws
    rng = np.random.default_rng(seed)
    return rng.normal(size=4)


def scan_body(carry, x):
    return carry + x, carry


def run(xs):
    t0 = time.time()  # host timing OUTSIDE the traced function is fine
    total, _ = jax.lax.scan(scan_body, 0.0, xs)
    print("elapsed", time.time() - t0)  # ditto printing
    return total
