"""Bad fixture: jit-purity — host side effects frozen into traces."""
import random
import time

import jax
import numpy as np

COUNTER = 0


@jax.jit
def stamped(x):
    return x * time.time()  # clock read at trace time only


def noisy(x):
    print("tracing", x)  # prints once, at trace time
    return x + np.random.rand()  # unseeded global draw


def run(xs):
    return jax.vmap(noisy)(xs)


def helper(x):
    return x * random.random()  # unseeded draw, one call level deep


@jax.jit
def indirect(x):
    return helper(x)


@jax.jit
def mutator(x):
    global COUNTER
    COUNTER += 1  # mutation runs at trace time only
    return x


def scanned(xs):
    def body(carry, x):
        rng = np.random.default_rng()  # constructed without a seed
        return carry + rng.standard_normal(), carry

    return jax.lax.scan(body, 0.0, xs)
