"""Fixture: inline suppressions silence named rules, same or prior line."""
from dataclasses import dataclass


@dataclass
class QuietSpec:  # fedlint: disable=spec-hygiene
    capacity: float = 1.0


# fedlint: disable=spec-hygiene
@dataclass
class AboveLineSpec:
    capacity: float = 2.0


@dataclass
class LoudSpec:  # fedlint: disable=some-other-rule
    capacity: float = 3.0
