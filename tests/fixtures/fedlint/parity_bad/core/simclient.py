"""Bad fixture (sim side): writes the counter the analytic side lacks."""


def report(rep, flows):
    rep.bytes_moved = sum(f.bytes for f in flows)
    rep.sim_only_counter += len(flows)
    return rep
