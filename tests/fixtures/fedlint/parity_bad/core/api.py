"""Bad fixture (analytic side): two counters this engine never writes."""
from dataclasses import dataclass


@dataclass
class ScenarioReport:
    name: str = ""
    bytes_moved: int = 0
    sim_only_counter: int = 0      # only the sim side writes this
    never_written: float = 0.0     # nobody writes this at all


def report(total):
    return ScenarioReport(name="analytic", bytes_moved=total)
