"""fedlint: every rule fires on its bad fixture, stays silent on the
good one, respects suppressions — plus the repo itself stays clean and
the determinism sanitizer holds on both engines."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import run_analysis
from repro.analysis.core import load_baseline

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "fedlint"


def lint(targets, root, rules=None, baseline=None):
    violations, _ = run_analysis(
        [Path(t) for t in targets], root=Path(root), rules=rules,
        baseline=baseline)
    return violations


def active(violations):
    return [v for v in violations if not v.suppressed]


# ---------------------------------------------------------------- rules
def test_spec_hygiene_fires_on_bad_fixture():
    vs = active(lint([FIXTURES / "spec_hygiene_bad.py"], FIXTURES,
                     rules=["spec-hygiene"]))
    symbols = {v.symbol for v in vs}
    assert "MutableSpec" in symbols          # non-frozen dataclass
    assert "LopsidedSchedule" in symbols     # __eq__ without __hash__
    assert "IdentitySpec" in symbols         # no eq machinery at all
    assert "SharedDefaultSpec" in symbols    # shared default instance
    assert "LiteralDefaultSpec" in symbols   # class-level [] default
    assert len(vs) >= 5


def test_spec_hygiene_silent_on_good_fixture():
    assert active(lint([FIXTURES / "spec_hygiene_good.py"], FIXTURES,
                       rules=["spec-hygiene"])) == []


def test_jit_purity_fires_on_bad_fixture():
    vs = active(lint([FIXTURES / "jit_purity_bad.py"], FIXTURES,
                     rules=["jit-purity"]))
    msgs = " | ".join(v.message for v in vs)
    assert "time.time" in msgs               # clock in @jax.jit
    assert "print" in msgs                   # print in vmapped fn
    assert "np.random.rand" in msgs          # unseeded draw
    assert "helper" in msgs                  # one call level deep
    assert "global" in msgs                  # global mutation
    assert "without a seed" in msgs          # unseeded default_rng in scan
    assert len(vs) >= 6


def test_jit_purity_silent_on_good_fixture():
    assert active(lint([FIXTURES / "jit_purity_good.py"], FIXTURES,
                       rules=["jit-purity"])) == []


def test_parity_surface_fires_on_bad_fixture():
    vs = active(lint([FIXTURES / "parity_bad"], FIXTURES / "parity_bad",
                     rules=["parity-surface"]))
    by_symbol = {v.symbol: v for v in vs}
    assert "ScenarioReport.sim_only_counter" in by_symbol
    assert "sim engine path" in \
        by_symbol["ScenarioReport.sim_only_counter"].message
    assert "ScenarioReport.never_written" in by_symbol
    # bytes_moved is written on both sides: no violation for it
    assert "ScenarioReport.bytes_moved" not in by_symbol


def test_parity_surface_silent_on_good_fixture():
    assert active(lint([FIXTURES / "parity_good"],
                       FIXTURES / "parity_good",
                       rules=["parity-surface"])) == []


def test_x64_scoping_fires_on_bad_fixture():
    vs = active(lint([FIXTURES / "kernels" / "x64_bad.py"], FIXTURES,
                     rules=["x64-scoping"]))
    msgs = " | ".join(v.message for v in vs)
    assert "global jax_enable_x64" in msgs
    assert "jnp.float64" in msgs
    assert 'dtype="float64"' in msgs
    assert len(vs) >= 3


def test_x64_scoping_silent_on_good_fixture():
    assert active(lint([FIXTURES / "kernels" / "x64_good.py"], FIXTURES,
                       rules=["x64-scoping"])) == []


def test_x64_scoping_only_applies_to_kernels(tmp_path):
    # same bad source outside kernels/ is out of the rule's scope
    src = (FIXTURES / "kernels" / "x64_bad.py").read_text()
    other = tmp_path / "host_code.py"
    other.write_text(src)
    assert active(lint([other], tmp_path, rules=["x64-scoping"])) == []


def test_deprecation_hygiene_fires_on_bad_fixture():
    vs = active(lint([FIXTURES / "deprecation_bad.py"], FIXTURES,
                     rules=["deprecation-hygiene"]))
    msgs = " | ".join(v.message for v in vs)
    assert "ClientPlane" in msgs and "sneaky_internal_caller" in msgs
    assert "stacklevel" in msgs
    assert len(vs) == 2


def test_deprecation_hygiene_silent_on_good_fixture():
    assert active(lint([FIXTURES / "deprecation_good.py"], FIXTURES,
                       rules=["deprecation-hygiene"])) == []


# --------------------------------------------------------- suppressions
def test_inline_suppressions_same_line_and_above():
    vs = lint([FIXTURES / "suppressed.py"], FIXTURES,
              rules=["spec-hygiene"])
    by_symbol = {v.symbol: v for v in vs}
    assert by_symbol["QuietSpec"].suppressed_by == "inline"
    assert by_symbol["AboveLineSpec"].suppressed_by == "inline"
    # naming a different rule does not silence this one
    assert by_symbol["LoudSpec"].suppressed_by is None


def test_baseline_suppression_requires_reason(tmp_path):
    good = tmp_path / "fedlint.toml"
    good.write_text(textwrap.dedent('''\
        [[suppress]]
        rule = "spec-hygiene"
        file = "spec_hygiene_bad.py"
        symbol = "MutableSpec"
        reason = "fixture: demonstrates the failure mode"
    '''))
    vs = lint([FIXTURES / "spec_hygiene_bad.py"], FIXTURES,
              rules=["spec-hygiene"], baseline=good)
    by_symbol = {v.symbol: v for v in vs}
    assert by_symbol["MutableSpec"].suppressed_by == "baseline"
    assert by_symbol["LopsidedSchedule"].suppressed_by is None

    bad = tmp_path / "bad.toml"
    bad.write_text('[[suppress]]\nrule = "spec-hygiene"\n'
                   'file = "x.py"\nreason = ""\n')
    with pytest.raises(ValueError, match="justified"):
        load_baseline(bad)

    incomplete = tmp_path / "incomplete.toml"
    incomplete.write_text('[[suppress]]\nrule = "spec-hygiene"\n')
    with pytest.raises(ValueError, match="missing"):
        load_baseline(incomplete)


# ------------------------------------------------------------- CLI + repo
def test_cli_strict_exit_codes(tmp_path):
    env_path = str(REPO / "src")
    bad = FIXTURES / "spec_hygiene_bad.py"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict", str(bad)],
        capture_output=True, text=True, cwd=tmp_path,
        env={"PYTHONPATH": env_path, "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "spec-hygiene" in proc.stdout

    good = FIXTURES / "spec_hygiene_good.py"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict", str(good)],
        capture_output=True, text=True, cwd=tmp_path,
        env={"PYTHONPATH": env_path, "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_repo_is_clean_under_strict():
    """The acceptance bar: zero unsuppressed violations in src/repro."""
    vs = lint([REPO / "src" / "repro"], REPO,
              baseline=REPO / "fedlint.toml")
    assert active(vs) == [], "\n".join(v.render() for v in active(vs))
    # and the baseline file itself stays reviewed: every entry justified
    entries = load_baseline(REPO / "fedlint.toml")
    assert all(e.reason.strip() for e in entries)
    # every baseline entry still matches a real (suppressed) violation —
    # stale entries are creep in the other direction
    suppressed = [v for v in vs if v.suppressed_by == "baseline"]
    for e in entries:
        assert any(e.matches(v) for v in suppressed), \
            f"stale fedlint.toml entry: {e}"


# ------------------------------------------------------------- sanitizer
def test_sanitizer_double_replay_and_shuffle():
    from repro.analysis.sanitize import run_sanitizer
    rows = run_sanitizer(quick=True)
    checks = {(c, s) for c, s, _ in rows}
    # both engines double-replayed
    assert ("double-replay", "sanitize-storm/analytic") in checks
    assert ("double-replay", "sanitize-storm/sim") in checks
    # shuffled same-timestamp insertion proven order-independent
    assert any(c == "shuffled-insertion" for c, _, _ in rows)


def test_sanitizer_catches_order_dependence():
    """The shuffle check must actually be able to fail: feed it a
    workload with distinct timestamps and it refuses (nothing to
    prove); feed it divergent reports and it raises."""
    import dataclasses as dc

    from repro.analysis.sanitize import (SanitizeFailure,
                                         check_shuffled_insertion,
                                         default_specs)
    from repro.core import WorkloadSpec

    spec = next(s for s in default_specs(quick=True)
                if s.engine == "sim" and s.outages is None
                and isinstance(s.workload, WorkloadSpec)
                and s.workload.kind == "storm")
    spread = dc.replace(
        spec, workload=dc.replace(spec.workload, jitter=1e6, seed=3))
    with pytest.raises(ValueError, match="same-timestamp"):
        check_shuffled_insertion(spread)
    with pytest.raises(ValueError, match="simulator"):
        check_shuffled_insertion(dc.replace(spec, engine="analytic"))
    assert isinstance(SanitizeFailure(), AssertionError)
