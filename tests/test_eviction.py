"""Eviction/admission policy tests: hit-rate ordering, TTL, admission."""
import pytest

from repro.core import (CacheServer, Coord, MonitorCollector, Payload,
                        SizeAwareAdmission, Topology, generate_workload,
                        make_eviction_policy)


def _cache(capacity, policy="lru", ttl_seconds=3600.0, admission=None,
           monitor=None):
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node(f"c-{policy}-{capacity}", Coord("s"), 1e10)
    return CacheServer(node.name, node, int(capacity), monitor=monitor,
                       policy=policy, ttl_seconds=ttl_seconds,
                       admission=admission)


def _replay(cache, path, size, now=0.0):
    cache.tick(now)
    if cache.lookup(path, 0) is not None:
        return True
    cache.admit(path, 0, Payload.synthetic(size, path, 0), object_size=size)
    return False


class TestPolicySelection:
    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            make_eviction_policy("clock")

    def test_policy_instance_is_copied_not_shared(self):
        """One policy instance handed to several caches must not be
        shared: victim order on cache A would otherwise be perturbed by
        accesses on cache B (the cache_replicas > 1 contamination bug)."""
        p = make_eviction_policy("lfu")
        built = make_eviction_policy(p)
        assert built is not p
        assert type(built) is type(p)
        # mutating the copy leaves the original untouched
        built.on_admit(("/f", 0), 10, 0.0)
        assert built.victim(set()) == ("/f", 0)
        assert p.victim(set()) is None

    def test_replica_caches_get_distinct_policy_objects(self):
        """A 2-replica site built from one SiteSpec: each CacheServer
        owns its own policy; touching one replica's keys must not
        reorder the other's LRU stack."""
        from repro.core import FederationSpec, SiteSpec
        spec = FederationSpec(
            sites=[SiteSpec(name="s", cache_replicas=2, cache_capacity=30)],
            origin_site="s")
        fed = spec.build()
        a, b = [fed.caches[n] for n in spec.cache_names()]
        assert a.policy is not b.policy
        for i in range(3):
            a.admit("/f", i, Payload.synthetic(10, "/f", i))
            b.admit("/f", i, Payload.synthetic(10, "/f", i))
        a.lookup("/f", 0)                 # touch only replica A
        a.admit("/g", 0, Payload.synthetic(10, "/g", 0))
        b.admit("/g", 0, Payload.synthetic(10, "/g", 0))
        assert a.resident("/f", 0) and not a.resident("/f", 1)
        # replica B's own LRU order was not contaminated by A's touch
        assert not b.resident("/f", 0) and b.resident("/f", 1)

    @pytest.mark.parametrize("name", ["lru", "lfu", "ttl", "fifo"])
    def test_all_policies_respect_capacity(self, name):
        c = _cache(100, policy=name)
        for i in range(50):
            c.admit("/f", i, Payload.synthetic(10, "/f", i))
        assert c.usage_bytes <= 100
        assert c.stats.evictions == 40


class TestLRUvsLFU:
    def test_lfu_keeps_hot_key_lru_does_not(self):
        """A scan evicts the hot key under LRU but not under LFU."""
        for policy, survives in (("lru", False), ("lfu", True)):
            c = _cache(30, policy=policy)
            c.admit("/hot", 0, Payload.synthetic(10, "/hot", 0))
            for _ in range(5):
                c.lookup("/hot", 0)          # make it hot
            for i in range(4):               # one-touch scan fills the cache
                c.admit("/scan", i, Payload.synthetic(10, "/scan", i))
            assert c.resident("/hot", 0) is survives, policy

    def test_lfu_beats_lru_under_zipf(self):
        """Zipf-popular working set larger than the cache: LFU protects
        the head, LRU churns it (the classic hit-rate ordering)."""
        reqs = generate_workload(["s"], 4000, working_set=256, seed=3)
        sizes = {r.path: r.size for r in reqs}
        capacity = 0.03 * sum(sizes.values())
        rates = {}
        for policy in ("lru", "lfu"):
            c = _cache(capacity, policy=policy)
            hits = 0
            for r in reqs:
                hits += _replay(c, r.path, r.size, r.time)
            rates[policy] = hits / len(reqs)
        assert rates["lfu"] > rates["lru"]


class TestTTL:
    def test_fresh_entry_hits_stale_entry_expires(self):
        c = _cache(1000, policy="ttl", ttl_seconds=10.0)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        c.tick(5.0)
        assert c.lookup("/f", 0) is not None
        c.tick(16.0)
        assert c.lookup("/f", 0) is None
        assert c.stats.ttl_expired == 1
        assert not c.resident("/f", 0)
        assert c.usage_bytes == 0

    def test_stale_entry_readmitted_with_fresh_clock(self):
        c = _cache(1000, policy="ttl", ttl_seconds=10.0)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        c.tick(20.0)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        assert c.resident("/f", 0)
        assert c.usage_bytes == 10


class TestAdmission:
    def test_size_aware_rejects_giant_object(self):
        c = _cache(1000, admission=SizeAwareAdmission(0.1))
        ok = c.admit("/small", 0, Payload.synthetic(50, "/small", 0),
                     object_size=50)
        assert ok and c.resident("/small", 0)
        ok = c.admit("/giant", 0, Payload.synthetic(90, "/giant", 0),
                     object_size=900)  # whole object > 10% of capacity
        assert not ok
        assert not c.resident("/giant", 0)
        assert c.stats.admission_rejects == 1
        assert c.resident("/small", 0)   # hot set untouched

    def test_admission_protects_hit_rate_from_scans(self):
        """A stream of one-touch giant objects must not flush the hot set."""
        hot = [(f"/hot/{i}", 10) for i in range(5)]
        for admission, hot_survives in ((None, False),
                                        (SizeAwareAdmission(0.2), True)):
            c = _cache(100, admission=admission)
            for path, size in hot:
                c.admit(path, 0, Payload.synthetic(size, path, 0),
                        object_size=size)
            for i in range(10):
                c.admit(f"/scan/{i}", 0,
                        Payload.synthetic(50, f"/scan/{i}", 0),
                        object_size=50)
            assert all(c.resident(p, 0) for p, _ in hot) is hot_survives


class TestAdmitOversize:
    def test_oversize_payload_is_refused_not_overcommitted(self):
        """A payload larger than the whole cache can never fit;
        admitting it used to drain the cache via evict_until and then
        insert anyway, leaving usage_bytes > capacity_bytes forever."""
        c = _cache(100)
        for i in range(5):
            assert c.admit("/hot", i, Payload.synthetic(10, "/hot", i))
        ok = c.admit("/giant", 0, Payload.synthetic(150, "/giant", 0),
                     object_size=150)
        assert not ok
        assert not c.resident("/giant", 0)
        assert c.stats.oversize_rejects == 1
        # the hot set was NOT drained to make room for the impossible
        assert all(c.resident("/hot", i) for i in range(5))
        assert c.usage_bytes == 50
        assert c.stats.evictions == 0

    def test_force_still_lands_oversize_dirty_data(self):
        """Write-back dirty data must land even over-committed — the
        documented force-path exception."""
        c = _cache(100)
        assert c.admit("/dirty", 0, Payload.synthetic(150, "/dirty", 0),
                       force=True)
        assert c.resident("/dirty", 0)
        assert c.usage_bytes == 150  # over-commit, by contract

    def test_oversize_refusal_still_serves_through(self):
        """The networked path keeps serving a refused chunk (it just is
        not cached): every access is a miss + origin re-pull."""
        from repro.core import (Coord, Origin, Redirector, RedirectorPair,
                                Topology)
        from repro.core.transfer import NetworkModel
        topo = Topology()
        topo.add_site("s")
        n_o = topo.add_node("o", Coord("s", rack=255), 1e10)
        n_r = topo.add_node("r", Coord("s", rack=254), 1e10)
        n_c = topo.add_node("c", Coord("s", rack=253), 1e10)
        origin = Origin("o", n_o)
        pair = RedirectorPair(Redirector("r1", n_r), Redirector("r2", n_r))
        pair.subscribe(origin)
        net = NetworkModel(topo)
        cache = CacheServer("c", n_c, 100, pair, net)
        origin.put_object("/big", 150)
        for _ in range(2):
            payload, stats = cache.get_chunk("o", "/big", 0)
            assert payload is not None and stats.cache_misses == 1
        assert cache.stats.oversize_rejects == 2
        assert origin.stats.egress_bytes == 300  # re-pulled every time


class TestAdmitReplacement:
    def test_republished_chunk_replaces_stale_payload(self):
        """admit() on a resident key with *different* content must not
        touch-and-return the stale bytes (the LocalCache.put fix,
        mirrored): the new payload replaces it, size delta accounted."""
        c = _cache(100)
        old = Payload.from_bytes(b"a" * 10)
        new = Payload.from_bytes(b"b" * 30)
        assert c.admit("/f", 0, old)
        assert c.admit("/f", 0, new)
        assert c.lookup("/f", 0).data == new.data
        assert c.usage_bytes == 30
        assert c.stats.replacements == 1
        assert c.stats.evictions == 0    # replacement is not an eviction

    def test_identical_payload_readmit_is_a_touch(self):
        """Collapsed-forwarding races re-admit the same bytes; that must
        stay a pure LRU touch (no churn, no accounting drift)."""
        c = _cache(30)
        for i in range(3):
            c.admit("/f", i, Payload.synthetic(10, "/f", i))
        assert c.admit("/f", 0, Payload.synthetic(10, "/f", 0))  # touch
        assert c.stats.replacements == 0
        c.admit("/g", 0, Payload.synthetic(10, "/g", 0))
        assert c.resident("/f", 0)       # touched → survived
        assert not c.resident("/f", 1)   # LRU victim instead
        assert c.usage_bytes == 30

    def test_replacement_that_no_longer_fits_drops_the_key(self):
        """If the replacement payload is refused (oversize), the stale
        copy must already be gone — never keep serving old bytes."""
        c = _cache(100)
        c.admit("/f", 0, Payload.from_bytes(b"a" * 10))
        assert not c.admit("/f", 0, Payload.from_bytes(b"x" * 150))
        assert not c.resident("/f", 0)
        assert c.usage_bytes == 0


class TestMonitoringSurface:
    def test_policy_counters_in_monitoring(self):
        monitor = MonitorCollector()
        for policy in ("lru", "lfu"):
            c = _cache(100, policy=policy, monitor=monitor)
            c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
            c.lookup("/f", 0)
            c.lookup("/miss", 0)
            c.report_usage(now=1.0)
        table = monitor.policy_table()
        assert [row[0] for row in table] == ["lfu", "lru"]
        for _, caches, hit_rate, *_ in table:
            assert caches == 1
            assert hit_rate == pytest.approx(0.5)

    def test_latest_gauge_wins(self):
        monitor = MonitorCollector()
        c = _cache(100, monitor=monitor)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        c.report_usage(now=1.0)
        c.lookup("/f", 0)
        c.report_usage(now=2.0)
        pkt = monitor.cache_gauges[c.name]
        assert pkt.time == 2.0 and pkt.hits == 1
