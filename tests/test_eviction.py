"""Eviction/admission policy tests: hit-rate ordering, TTL, admission."""
import pytest

from repro.core import (CacheServer, Coord, MonitorCollector, Payload,
                        SizeAwareAdmission, Topology, generate_workload,
                        make_eviction_policy)


def _cache(capacity, policy="lru", ttl_seconds=3600.0, admission=None,
           monitor=None):
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node(f"c-{policy}-{capacity}", Coord("s"), 1e10)
    return CacheServer(node.name, node, int(capacity), monitor=monitor,
                       policy=policy, ttl_seconds=ttl_seconds,
                       admission=admission)


def _replay(cache, path, size, now=0.0):
    cache.tick(now)
    if cache.lookup(path, 0) is not None:
        return True
    cache.admit(path, 0, Payload.synthetic(size, path, 0), object_size=size)
    return False


class TestPolicySelection:
    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            make_eviction_policy("clock")

    def test_policy_instance_passthrough(self):
        p = make_eviction_policy("lfu")
        assert make_eviction_policy(p) is p

    @pytest.mark.parametrize("name", ["lru", "lfu", "ttl", "fifo"])
    def test_all_policies_respect_capacity(self, name):
        c = _cache(100, policy=name)
        for i in range(50):
            c.admit("/f", i, Payload.synthetic(10, "/f", i))
        assert c.usage_bytes <= 100
        assert c.stats.evictions == 40


class TestLRUvsLFU:
    def test_lfu_keeps_hot_key_lru_does_not(self):
        """A scan evicts the hot key under LRU but not under LFU."""
        for policy, survives in (("lru", False), ("lfu", True)):
            c = _cache(30, policy=policy)
            c.admit("/hot", 0, Payload.synthetic(10, "/hot", 0))
            for _ in range(5):
                c.lookup("/hot", 0)          # make it hot
            for i in range(4):               # one-touch scan fills the cache
                c.admit("/scan", i, Payload.synthetic(10, "/scan", i))
            assert c.resident("/hot", 0) is survives, policy

    def test_lfu_beats_lru_under_zipf(self):
        """Zipf-popular working set larger than the cache: LFU protects
        the head, LRU churns it (the classic hit-rate ordering)."""
        reqs = generate_workload(["s"], 4000, working_set=256, seed=3)
        sizes = {r.path: r.size for r in reqs}
        capacity = 0.03 * sum(sizes.values())
        rates = {}
        for policy in ("lru", "lfu"):
            c = _cache(capacity, policy=policy)
            hits = 0
            for r in reqs:
                hits += _replay(c, r.path, r.size, r.time)
            rates[policy] = hits / len(reqs)
        assert rates["lfu"] > rates["lru"]


class TestTTL:
    def test_fresh_entry_hits_stale_entry_expires(self):
        c = _cache(1000, policy="ttl", ttl_seconds=10.0)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        c.tick(5.0)
        assert c.lookup("/f", 0) is not None
        c.tick(16.0)
        assert c.lookup("/f", 0) is None
        assert c.stats.ttl_expired == 1
        assert not c.resident("/f", 0)
        assert c.usage_bytes == 0

    def test_stale_entry_readmitted_with_fresh_clock(self):
        c = _cache(1000, policy="ttl", ttl_seconds=10.0)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        c.tick(20.0)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        assert c.resident("/f", 0)
        assert c.usage_bytes == 10


class TestAdmission:
    def test_size_aware_rejects_giant_object(self):
        c = _cache(1000, admission=SizeAwareAdmission(0.1))
        ok = c.admit("/small", 0, Payload.synthetic(50, "/small", 0),
                     object_size=50)
        assert ok and c.resident("/small", 0)
        ok = c.admit("/giant", 0, Payload.synthetic(90, "/giant", 0),
                     object_size=900)  # whole object > 10% of capacity
        assert not ok
        assert not c.resident("/giant", 0)
        assert c.stats.admission_rejects == 1
        assert c.resident("/small", 0)   # hot set untouched

    def test_admission_protects_hit_rate_from_scans(self):
        """A stream of one-touch giant objects must not flush the hot set."""
        hot = [(f"/hot/{i}", 10) for i in range(5)]
        for admission, hot_survives in ((None, False),
                                        (SizeAwareAdmission(0.2), True)):
            c = _cache(100, admission=admission)
            for path, size in hot:
                c.admit(path, 0, Payload.synthetic(size, path, 0),
                        object_size=size)
            for i in range(10):
                c.admit(f"/scan/{i}", 0,
                        Payload.synthetic(50, f"/scan/{i}", 0),
                        object_size=50)
            assert all(c.resident(p, 0) for p, _ in hot) is hot_survives


class TestMonitoringSurface:
    def test_policy_counters_in_monitoring(self):
        monitor = MonitorCollector()
        for policy in ("lru", "lfu"):
            c = _cache(100, policy=policy, monitor=monitor)
            c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
            c.lookup("/f", 0)
            c.lookup("/miss", 0)
            c.report_usage(now=1.0)
        table = monitor.policy_table()
        assert [row[0] for row in table] == ["lfu", "lru"]
        for _, caches, hit_rate, *_ in table:
            assert caches == 1
            assert hit_rate == pytest.approx(0.5)

    def test_latest_gauge_wins(self):
        monitor = MonitorCollector()
        c = _cache(100, monitor=monitor)
        c.admit("/f", 0, Payload.synthetic(10, "/f", 0))
        c.report_usage(now=1.0)
        c.lookup("/f", 0)
        c.report_usage(now=2.0)
        pkt = monitor.cache_gauges[c.name]
        assert pkt.time == 2.0 and pkt.hits == 1
