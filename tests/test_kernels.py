"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chunk import fnv1a64
from repro.kernels import ref
from repro.kernels.chunk_checksum import (block_digests, chunk_checksum,
                                          combine_digests)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_intra

KEY = jax.random.PRNGKey(0)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (1, 128, 4, 4, 32),      # MHA
        (2, 128, 4, 2, 32),      # GQA 2:1
        (1, 256, 8, 2, 16),      # GQA 4:1
        (1, 96, 2, 1, 32),       # ragged seq (pad path)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, b, s, h, kv, hd, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
        k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
        v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
        got = flash_attention(q, k, v, causal=True, q_block=64, kv_block=64,
                              interpret=True)
        want = ref.attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("window", [32, 64])
    def test_sliding_window_matches_ref(self, window):
        ks = jax.random.split(KEY, 3)
        b, s, h, kv, hd = 1, 192, 4, 2, 32
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
        got = flash_attention(q, k, v, causal=True, window=window,
                              q_block=64, kv_block=64, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        ks = jax.random.split(KEY, 3)
        b, s, h, kv, hd = 1, 128, 2, 2, 32
        q = 5 * jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = 5 * jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
        got = flash_attention(q, k, v, causal=True, softcap=50.0,
                              q_block=64, kv_block=64, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


class TestChunkChecksum:
    @pytest.mark.parametrize("n,block", [(1024, 256), (5000, 256),
                                         (256, 256), (70000, 1024)])
    def test_matches_oracle(self, n, block):
        data = jax.random.randint(KEY, (n,), 0, 256, dtype=jnp.int32)
        got = chunk_checksum(data, block=block, interpret=True)
        want, _ = ref.poly_digest_ref(data, block=block)
        assert np.uint32(got) == np.uint32(want)

    def test_detects_single_bitflip(self):
        data = jax.random.randint(KEY, (4096,), 0, 256, dtype=jnp.int32)
        d1 = chunk_checksum(data, block=256, interpret=True)
        flipped = data.at[1234].set(data[1234] ^ 0x01)
        d2 = chunk_checksum(flipped, block=256, interpret=True)
        assert np.uint32(d1) != np.uint32(d2)

    def test_block_digests_localise_corruption(self):
        data = jax.random.randint(KEY, (2048,), 0, 256, dtype=jnp.int32)
        ref_blocks = block_digests(data, block=256, interpret=True)
        flipped = data.at[700].set(data[700] ^ 0xFF)
        got_blocks = block_digests(flipped, block=256, interpret=True)
        diff = np.nonzero(np.asarray(ref_blocks) != np.asarray(got_blocks))[0]
        assert list(diff) == [700 // 256]

    def test_wire_format_fnv_unchanged(self):
        # The federation's python FNV-1a (chunk.py) is a separate,
        # wire-format digest — sanity-check both coexist.
        assert fnv1a64(b"chunk") == fnv1a64(b"chunk")
        assert fnv1a64(b"chunk") != fnv1a64(b"chunk2")


class TestSSDIntra:
    @pytest.mark.parametrize("b,nc,q,h,p,n", [
        (1, 2, 32, 2, 16, 8),
        (2, 1, 64, 4, 8, 16),
        (1, 3, 16, 1, 32, 4),
    ])
    def test_matches_oracle(self, b, nc, q, h, p, n):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, nc, q, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, q, h)))
        la = -0.1 * jax.nn.softplus(jax.random.normal(ks[2], (b, nc, q, h)))
        cum = jnp.cumsum(la, axis=2)
        b_in = jax.random.normal(ks[3], (b, nc, q, n), jnp.float32)
        c_in = jax.random.normal(ks[4], (b, nc, q, n), jnp.float32)
        got = ssd_intra(x, dt, cum, b_in, c_in, interpret=True)
        want = ref.ssd_intra_ref(x, dt, cum, b_in, c_in)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_consistent_with_model_ssd(self):
        """Kernel + inter-chunk scan == ssd_chunked (end-to-end)."""
        from repro.models.ssm import ssd_chunked
        bsz, l, h, p, n, chunk = 1, 64, 2, 8, 4, 16
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (bsz, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        b_in = jax.random.normal(ks[3], (bsz, l, n))
        c_in = jax.random.normal(ks[4], (bsz, l, n))
        y_full, _ = ssd_chunked(x, dt, a, b_in, c_in, chunk)
        # reproduce the intra part with the kernel and compare at chunk 0
        nc = l // chunk
        xc = x.reshape(bsz, nc, chunk, h, p)
        dtc = dt.reshape(bsz, nc, chunk, h)
        la = dtc * a[None, None, None, :]
        cum = jnp.cumsum(la, axis=2)
        bc = b_in.reshape(bsz, nc, chunk, n)
        cc = c_in.reshape(bsz, nc, chunk, n)
        y_intra = ssd_intra(xc, dtc, cum, bc, cc, interpret=True)
        # chunk 0 has no inter-chunk contribution → must equal full output
        np.testing.assert_allclose(y_intra[:, 0], y_full[:, :chunk],
                                   rtol=1e-4, atol=1e-4)
