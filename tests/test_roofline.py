"""§Roofline methodology validation.

1. The analytic per-op FLOP formulas (benchmarks/analytic_cost.py) are
   validated against XLA's cost_analysis on *scan-free* instances (XLA
   counts while bodies once, so validation uses single-block shapes).
2. The HLO collective parser is validated on representative HLO text.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config

pytestmark = pytest.mark.filterwarnings("ignore")


def _measured_flops(fn, *args):
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    # jax < 0.4.27 returns a one-element list of dicts; newer jax returns
    # the dict itself.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost.get("flops", 0)


class TestAnalyticFormulas:
    def test_attention_flops(self):
        import benchmarks.analytic_cost as ac
        cfg = dataclasses.replace(get_config("qwen2-7b", smoke=True),
                                  dtype="float32")
        from repro.models.attention import attention_forward, init_attention
        p, _ = init_attention(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        b, s = 2, 64
        x = jnp.zeros((b, s, cfg.d_model), jnp.float32)
        pos = jnp.arange(s)[None, :]
        meas = _measured_flops(
            lambda p_, x_: attention_forward(p_, x_, cfg, pos,
                                             q_block=512), p, x)
        f = ac.attn_fwd_flops(cfg, tokens=b * s, span=s)
        want = f["proj"] + f["attn"] + 2 * b * s * cfg.resolved_num_heads \
            * cfg.resolved_head_dim * cfg.d_model  # + wo projection
        # formulas target matmul flops; XLA adds elementwise ops → within 2×
        assert 0.4 < meas / want < 2.0, (meas, want)

    def test_mlp_flops(self):
        import benchmarks.analytic_cost as ac
        cfg = dataclasses.replace(get_config("qwen2-7b", smoke=True),
                                  dtype="float32")
        from repro.models.layers import init_mlp, mlp_forward
        p, _ = init_mlp(jax.random.PRNGKey(0), cfg.d_model, cfg.d_ff,
                        dtype=jnp.float32)
        b, s = 2, 64
        x = jnp.zeros((b, s, cfg.d_model), jnp.float32)
        meas = _measured_flops(lambda p_, x_: mlp_forward(p_, x_), p, x)
        want = ac.mlp_fwd_flops(cfg, tokens=b * s)
        assert 0.8 < meas / want < 1.3, (meas, want)

    def test_train_multiplier_orders(self):
        """Analytic train cost ≈ 4× fwd layer matmuls + 3× logits."""
        import benchmarks.analytic_cost as ac
        from repro.configs.base import SHAPES
        from repro.sharding.rules import make_rules
        import types
        cfg = get_config("phi3-mini-3.8b")
        mesh = types.SimpleNamespace(shape={"data": 16, "model": 16})
        rules = make_rules(cfg, mesh, global_batch=256)
        train = ac.cell_cost(cfg, SHAPES["train_4k"], "single", rules.table)
        prefill_shape = dataclasses.replace(SHAPES["prefill_32k"],
                                            seq_len=4096, global_batch=256)
        fwd = ac.cell_cost(cfg, prefill_shape, "single", rules.table)
        ratio = train["flops_per_dev"] / fwd["flops_per_dev"]
        assert 3.0 < ratio < 4.5, ratio


class TestCollectiveParser:
    HLO = """
  %all-gather = f32[128,512]{0,1} all-gather(%copy), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}, metadata={op_name="jit(f)/while/body/dot" }
  %all-reduce.3 = bf16[1024]{0} all-reduce(%x), channel_id=2, replica_groups=[4,2]<=[8], metadata={op_name="jit(f)/loss" }
  %rs = f32[64]{0} reduce-scatter(%y), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
  %other = f32[8]{0} add(%a, %b)
"""

    def test_kinds_counts_and_loop_attribution(self):
        from repro.launch.dryrun import parse_collectives
        out = parse_collectives(self.HLO)
        assert out["all-gather@loop"]["count"] == 1     # while/body metadata
        assert out["all-reduce"]["count"] == 1
        assert out["reduce-scatter"]["count"] == 1
        # all-gather wire: result 128·512·4 B × (g−1)/g with g=4
        assert out["all-gather@loop"]["bytes"] == 128 * 512 * 4 * 3 // 4
        # all-reduce: 2 × 1024·2 B × 1/2 (g=2)
        assert out["all-reduce"]["bytes"] == 2 * 1024 * 2 * 1 // 2
        # reduce-scatter: result × (g−1), g=8
        assert out["reduce-scatter"]["bytes"] == 64 * 4 * 7

    def test_dominant_classification(self):
        from benchmarks.bench_roofline import advice
        assert "collective" in advice("collective", 0.9)
        assert "useful" in advice("compute", 0.3)
