"""Batched scenario sweeps: SweepSpec semantics, batched-vs-serial
parity (every cell's byte/hit/egress counters must equal a serial
``run_scenario`` of the same cell), serial fallback for cells outside
the vectorized regime, and the CI regression gate."""
import dataclasses
import json

import pytest

from repro.core import (FederationSpec, FetchRequest, ScenarioSpec,
                        SweepAggregator, SweepSpec, WorkloadSpec,
                        run_scenario, run_sweep)

PARITY_INTS = ("requests", "completed", "bytes_moved", "cache_hits",
               "cache_misses", "origin_egress_bytes", "evictions",
               "bytes_evicted", "admission_rejects", "cache_failovers",
               "origin_fallbacks", "group_failovers", "outages",
               "recoveries")
PARITY_FLOATS = ("hit_rate", "mean_seconds", "p50_seconds", "p95_seconds")


def base_spec(n_requests=24, **fed_kw):
    fed_kw.setdefault("num_pods", 2)
    fed_kw.setdefault("hosts_per_pod", 2)
    return ScenarioSpec(
        name="cell", engine="analytic",
        federation=FederationSpec.fleet(**fed_kw),
        workload=WorkloadSpec(kind="zipf", n_requests=n_requests,
                              working_set=8, duration=600.0, seed=5))


class TestSweepSpec:
    def test_cross_product_order_and_len(self):
        sweep = SweepSpec(name="s", base=base_spec(), axes={
            "workload.zipf_a": [0.9, 1.3],
            "workload.seed": [0, 1, 2],
        })
        assert len(sweep) == 6
        cells = sweep.cells()
        assert len(cells) == 6
        # last axis fastest
        assert [p["workload.seed"] for p, _ in cells[:3]] == [0, 1, 2]
        assert cells[0][0] == {"workload.zipf_a": 0.9, "workload.seed": 0}
        assert cells[0][1].workload.zipf_a == 0.9

    def test_axis_routing(self):
        sweep = SweepSpec(name="s", base=base_spec(), axes={
            "federation.cache_replicas": [3],
            "federation.proxy_ttl": [120.0],
            "streams": [4],
            "outage_rate": [0.5],
        })
        params, spec = sweep.cells()[0]
        cache_sites = [s for s in spec.federation.sites if s.has_cache]
        assert all(s.cache_replicas == 3 for s in cache_sites)
        assert spec.federation.proxy_ttl == 120.0
        assert spec.streams == 4
        assert spec.outages is not None and len(spec.outages) > 0
        # cold restarts at half the workload horizon
        assert all(ev.time >= 300.0 for ev in spec.outages)
        # base spec untouched (inert data)
        assert base_spec().streams == 8

    def test_unknown_axes_rejected(self):
        for axis in ("workload.nope", "federation.nope", "nope",
                     "name", "outages", "federation.name"):
            with pytest.raises(ValueError):
                SweepSpec(name="s", base=base_spec(),
                          axes={axis: [1]}).cells()

    def test_outage_axis_names_real_caches(self):
        """The outage axis must address the caches build() will create
        — one naming authority (FederationSpec.cache_names)."""
        spec = base_spec(cache_replicas=2).federation
        fed = spec.build()
        assert set(spec.cache_names()) == set(fed.caches)

    def test_cell_names_carry_params(self):
        sweep = SweepSpec(name="s", base=base_spec(),
                          axes={"workload.seed": [7]})
        _, spec = sweep.cells()[0]
        assert spec.name == "s/workload.seed=7"


class TestBatchedSerialParity:
    @pytest.fixture(scope="class")
    def reports(self):
        sweep = SweepSpec(name="parity", base=base_spec(), axes={
            "federation.cache_replicas": [1, 2],
            "workload.zipf_a": [0.9, 1.4],
            "outage_rate": [0.0, 0.5],
        })
        batched = run_sweep(sweep, batched=True)
        serial = run_sweep(sweep, batched=False, price_contention=False)
        return batched, serial

    def test_every_cell_is_byte_exact(self, reports):
        batched, serial = reports
        assert batched.batched_cells == len(batched.cells)
        for cb, cs in zip(batched.cells, serial.cells):
            assert cb.params == cs.params
            for k in PARITY_INTS:
                assert cb.summary[k] == cs.summary[k], (cb.params, k)
            for k in PARITY_FLOATS:
                assert cb.summary[k] == pytest.approx(cs.summary[k],
                                                      rel=1e-9), \
                    (cb.params, k)

    def test_outage_cells_actually_failover(self, reports):
        batched, _ = reports
        stormy = [c for c in batched.cells
                  if c.params["outage_rate"] > 0]
        assert sum(c.summary["outages"] for c in stormy) > 0
        assert any(c.summary["cache_failovers"] > 0
                   or c.summary["group_failovers"] > 0
                   or c.summary["origin_fallbacks"] > 0 for c in stormy)

    def test_pricing_gauges_present(self, reports):
        batched, _ = reports
        assert batched.solver["solve_calls"] >= 1
        assert batched.solver["priced_cells"] == len(batched.cells)
        for c in batched.cells:
            assert c.pricing["peak_flows"] > 0
            assert c.pricing["storm_finish_seconds"] > 0

    def test_single_cell_sweep(self):
        """Batch-of-one: a sweep with no axes still runs (and prices)."""
        sweep = SweepSpec(name="one", base=base_spec(n_requests=8))
        rep = run_sweep(sweep, batched=True)
        assert len(rep.cells) == 1
        assert rep.cells[0].executor == "batched"
        serial = run_scenario(sweep.cells()[0][1])
        for k in ("bytes_moved", "cache_hits", "cache_misses",
                  "origin_egress_bytes"):
            assert rep.cells[0].summary[k] == serial.summary()[k]

    def test_direct_method_cells(self):
        sweep = SweepSpec(name="direct",
                          base=dataclasses.replace(base_spec(n_requests=10),
                                                   method="direct"),
                          axes={"workload.seed": [0, 1]})
        b = run_sweep(sweep, batched=True)
        s = run_sweep(sweep, batched=False, price_contention=False)
        assert b.batched_cells == 2
        for cb, cs in zip(b.cells, s.cells):
            for k in ("bytes_moved", "origin_egress_bytes", "cache_hits"):
                assert cb.summary[k] == cs.summary[k]
            assert cb.summary["cache_hits"] == 0  # direct bypasses caches

    def test_explicit_request_workload(self):
        reqs = [FetchRequest(path=f"/d/obj{i % 3}", site="pod0",
                             worker=i % 2, at=float(i), size=int(5e7))
                for i in range(12)]
        sweep = SweepSpec(
            name="explicit",
            base=dataclasses.replace(base_spec(), workload=reqs))
        b = run_sweep(sweep, batched=True)
        s = run_sweep(sweep, batched=False, price_contention=False)
        assert b.cells[0].executor == "batched"
        for k in PARITY_INTS:
            assert b.cells[0].summary[k] == s.cells[0].summary[k], k

    def test_not_found_requests_under_outage_stay_exact(self):
        """Unpublished (size-0) paths still walk the ranked chain on
        the serial plane, so their group-failover accounting must
        survive an outage on the batched path too."""
        # horizon = max(at) + 60 = 140 -> cold restart at t=70 for 35 s:
        # the t=70/t=80 requests run while every cache is down
        times = (0.0, 20.0, 70.0, 80.0)
        reqs = [FetchRequest(path="/d/real", site="pod0", at=t,
                             size=int(5e7)) for t in times]
        reqs += [FetchRequest(path="/d/ghost", site="pod0", at=t,
                              size=0) for t in times]
        sweep = SweepSpec(
            name="ghost",
            base=dataclasses.replace(base_spec(num_pods=1), workload=reqs),
            axes={"outage_rate": [1.0]})
        b = run_sweep(sweep, batched=True, price_contention=False)
        s = run_sweep(sweep, batched=False, price_contention=False)
        assert b.cells[0].executor == "batched"
        for k in PARITY_INTS:
            assert b.cells[0].summary[k] == s.cells[0].summary[k], k
        assert b.cells[0].summary["group_failovers"] > 0


class TestSerialFallback:
    def test_sim_engine_cells_fall_back(self):
        sweep = SweepSpec(name="mixed", base=base_spec(n_requests=6),
                          axes={"engine": ["analytic", "sim"]})
        rep = run_sweep(sweep, batched=True)
        by_engine = {c.params["engine"]: c for c in rep.cells}
        assert by_engine["analytic"].executor == "batched"
        assert by_engine["sim"].executor == "serial"
        assert rep.serial_cells == 1 and rep.batched_cells == 1
        # engine parity on byte counters holds across the two cells
        for k in ("bytes_moved", "cache_hits", "cache_misses",
                  "origin_egress_bytes"):
            assert (by_engine["analytic"].summary[k]
                    == by_engine["sim"].summary[k]), k

    def test_proxy_method_falls_back(self):
        sweep = SweepSpec(
            name="proxy",
            base=dataclasses.replace(base_spec(n_requests=6),
                                     method="proxy"))
        rep = run_sweep(sweep, batched=True)
        assert rep.cells[0].executor == "serial"

    def test_lfu_and_ttl_policies_fall_back(self):
        """Victim orders the kernels don't model (LFU frequency buckets,
        TTL expiry) still run serially — with identical semantics."""
        sweep = SweepSpec(name="pol", base=base_spec(n_requests=8),
                          axes={"federation.eviction_policy":
                                ["lru", "fifo", "lfu", "ttl"]})
        rep = run_sweep(sweep, batched=True)
        by_policy = {c.params["federation.eviction_policy"]: c.executor
                     for c in rep.cells}
        assert by_policy == {"lru": "batched", "fifo": "batched",
                             "lfu": "serial", "ttl": "serial"}

    def test_policy_instance_axis_falls_back(self):
        """A non-string eviction_policy (a policy *instance*) cannot be
        introspected by the kernels; the cell must go serial."""
        from repro.core import LRUPolicy
        sweep = SweepSpec(name="inst", base=base_spec(n_requests=6),
                          axes={"federation.eviction_policy":
                                [LRUPolicy()]})
        rep = run_sweep(sweep, batched=True)
        assert rep.cells[0].executor == "serial"

    def test_control_plane_cells_fall_back_and_are_counted(self):
        """Admission queues / breakers are stateful across requests in
        ways the hit/miss kernels don't model: a cell with a
        ControlPlaneSpec must be classified serial (and counted as
        such), while its control-free sibling stays batched with
        byte-exact parity against a serial run."""
        from repro.core import ControlPlaneSpec
        base = base_spec(n_requests=12)
        base = dataclasses.replace(
            base, workload=dataclasses.replace(base.workload, duration=2.0))
        sweep = SweepSpec(name="ctrl", base=base,
                          axes={"control": [None, ControlPlaneSpec(
                              max_concurrent=1, queue_depth=1)]})
        rep = run_sweep(sweep, batched=True)
        by_ctrl = {c.params["control"] is not None: c for c in rep.cells}
        assert by_ctrl[False].executor == "batched"
        assert by_ctrl[True].executor == "serial"
        assert rep.serial_cells == 1 and rep.batched_cells == 1
        # the control-free cell is bit-identical to a serial run of the
        # same spec: attaching control elsewhere must not perturb it
        serial = run_scenario(base).summary()
        for k in PARITY_INTS:
            assert by_ctrl[False].summary[k] == serial[k], k
        # the control cell actually exercised the queue
        assert by_ctrl[True].summary["sheds"] + \
            by_ctrl[True].summary["queue_waits"] > 0

    def test_control_free_sweep_has_zero_serial_cells(self):
        """Acceptance guard: adding the control axis must not push
        ordinary sweeps off the batched path."""
        sweep = SweepSpec(name="plain", base=base_spec(n_requests=12),
                          axes={"workload.seed": [0, 1, 2]})
        rep = run_sweep(sweep, batched=True)
        assert rep.serial_cells == 0
        assert rep.batched_cells == 3


class TestEvictionParity:
    """The regime PR 5 closes: capacity / policy / admission axes run
    batched (stack-distance + state-machine kernels) with cell-exact
    counters — including evictions, bytes_evicted and re-pull egress."""

    @pytest.fixture(scope="class")
    def reports(self):
        # working set (~8 objects × ~hundreds of MB) far exceeds the
        # smallest capacities → heavy eviction churn in half the cells
        sweep = SweepSpec(name="evict", base=base_spec(n_requests=40), axes={
            "federation.cache_capacity": [2e8, 5e8, 1e9, 32e12],
            "federation.eviction_policy": ["lru", "fifo"],
            "federation.admission_max_fraction": [1.0, 0.3],
        })
        batched = run_sweep(sweep, batched=True)
        serial = run_sweep(sweep, batched=False, price_contention=False)
        return batched, serial

    def test_acceptance_no_serial_cells(self, reports):
        """ISSUE-5 acceptance: the capacity × {lru,fifo} × admission
        sweep runs wholly through the batched executor."""
        batched, _ = reports
        assert batched.serial_cells == 0
        assert batched.batched_cells == len(batched.cells) == 16

    def test_every_cell_is_byte_exact(self, reports):
        batched, serial = reports
        for cb, cs in zip(batched.cells, serial.cells):
            assert cb.params == cs.params
            for k in PARITY_INTS:
                assert cb.summary[k] == cs.summary[k], (cb.params, k)
            for k in PARITY_FLOATS:
                assert cb.summary[k] == pytest.approx(cs.summary[k],
                                                      rel=1e-9), \
                    (cb.params, k)

    def test_evictions_actually_happen_and_drive_egress(self, reports):
        batched, _ = reports
        tiny = [c for c in batched.cells
                if c.params["federation.cache_capacity"] == 2e8
                and c.params["federation.admission_max_fraction"] == 1.0]
        huge = [c for c in batched.cells
                if c.params["federation.cache_capacity"] == 32e12
                and c.params["federation.admission_max_fraction"] == 1.0]
        assert all(c.summary["evictions"] > 0 for c in tiny)
        assert all(c.summary["bytes_evicted"] > 0 for c in tiny)
        assert all(c.summary["evictions"] == 0 for c in huge)
        # re-pulls of evicted chunks show up as extra origin egress
        for t, h in zip(tiny, huge):
            assert (t.summary["origin_egress_bytes"]
                    > h.summary["origin_egress_bytes"])

    def test_admission_rejects_are_counted(self, reports):
        batched, _ = reports
        filtered = [c for c in batched.cells
                    if c.params["federation.admission_max_fraction"] < 1.0
                    and c.params["federation.cache_capacity"] <= 1e9]
        assert any(c.summary["admission_rejects"] > 0 for c in filtered)

    def test_eviction_cells_under_outage_stay_exact(self):
        """Cold restarts interleave with eviction churn: segment-aware
        distances and state-machine resets must both stay exact."""
        sweep = SweepSpec(name="stormy", base=base_spec(n_requests=30),
                          axes={
                              "federation.cache_capacity": [4e8],
                              "federation.eviction_policy": ["lru", "fifo"],
                              "outage_rate": [0.5],
                          })
        b = run_sweep(sweep, batched=True, price_contention=False)
        s = run_sweep(sweep, batched=False, price_contention=False)
        assert b.serial_cells == 0
        for cb, cs in zip(b.cells, s.cells):
            for k in PARITY_INTS:
                assert cb.summary[k] == cs.summary[k], (cb.params, k)
            assert cb.summary["evictions"] > 0

    def test_single_evicting_cell_matches_run_scenario(self):
        """Straight against run_scenario, not just the serial sweep."""
        sweep = SweepSpec(name="tiny", base=base_spec(n_requests=20),
                          axes={"federation.cache_capacity": [5e8]})
        rep = run_sweep(sweep, batched=True)
        assert rep.cells[0].executor == "batched"
        serial = run_scenario(sweep.cells()[0][1]).summary()
        for k in PARITY_INTS:
            assert rep.cells[0].summary[k] == serial[k], k

    def test_policy_marginals_surface(self):
        """SweepAggregator groups the eviction axis for dashboards."""
        from repro.core import SweepAggregator
        sweep = SweepSpec(name="pm", base=base_spec(n_requests=16), axes={
            "federation.eviction_policy": ["lru", "fifo"],
            "federation.cache_capacity": [3e8, 1e12],
        })
        rep = run_sweep(sweep, batched=True, price_contention=False)
        agg = SweepAggregator()
        for cell in rep.cells:
            agg.add(cell.params, cell.summary)
        rows = agg.policy_marginals()
        assert {r[0] for r in rows} == {"lru", "fifo"}
        by_policy = {r[0]: r for r in rows}
        # (policy, cells, hit_rate, evictions, bytes_evicted, rejects)
        assert by_policy["lru"][1] == 2
        assert by_policy["lru"][3] > 0   # mean evictions over the column


class TestSweepAggregator:
    def test_marginals(self):
        agg = SweepAggregator()
        for a in (1, 2):
            for b in (10, 20):
                agg.add({"a": a, "b": b},
                        {"hit_rate": 0.1 * a + 0.001 * b})
        assert len(agg) == 4
        assert agg.axes() == {"a": [1, 2], "b": [10, 20]}
        rows = agg.marginal("a", "hit_rate")
        assert rows[0][0] == 1 and rows[0][1] == 2
        assert rows[0][2] == pytest.approx(0.1 + 0.015)
        assert rows[1][2] == pytest.approx(0.2 + 0.015)
        table = agg.table("hit_rate")
        assert {r[0] for r in table} == {"a", "b"}

    def test_report_marginal(self):
        sweep = SweepSpec(name="m", base=base_spec(n_requests=8),
                          axes={"workload.zipf_a": [0.8, 1.6]})
        rep = run_sweep(sweep, batched=True, price_contention=False)
        rows = rep.marginal("workload.zipf_a", "hit_rate")
        assert [v for v, _ in rows] == [0.8, 1.6]


class TestRegressionGate:
    @pytest.fixture()
    def baseline(self):
        from benchmarks.check_regression import BASELINE
        return json.loads(BASELINE.read_text())

    def test_committed_baseline_passes_on_itself(self, baseline):
        from benchmarks.check_regression import compare
        current = {name: float(spec["value"])
                   for name, spec in baseline["metrics"].items()}
        failures, rows = compare(baseline, current)
        assert failures == []
        assert all(r[-1] == "ok" for r in rows)

    def test_two_x_slowdown_fails(self, baseline):
        from benchmarks.check_regression import compare, format_table
        current = {}
        for name, spec in baseline["metrics"].items():
            v = float(spec["value"])
            current[name] = (v / 2 if spec.get("direction", "min") == "min"
                             else v * 2 + 1)
        failures, rows = compare(baseline, current)
        assert any("sweep_speedup" in f for f in failures)
        # every 'min' metric halved must regress (25% tolerance < 50%)
        regressed = {r[0] for r in rows if r[-1] == "REGRESSED"}
        assert "sweep_speedup" in regressed
        assert "storm_coalescing_ratio" in regressed
        # the diff is readable: metric name + verdict in the table
        table = format_table(rows)
        assert "sweep_speedup" in table and "REGRESSED" in table

    def test_missing_artifact_fails(self, baseline):
        from benchmarks.check_regression import compare
        failures, rows = compare(baseline, {})
        assert len(failures) == len(baseline["metrics"])
        assert all(r[-1] == "MISSING" for r in rows)

    def test_update_refuses_partial_baselines(self, baseline, tmp_path):
        """--update with missing artifacts must not silently keep stale
        values for the unrefreshed metrics."""
        from benchmarks.check_regression import update_baseline
        path = tmp_path / "baseline.json"
        path.write_text(json.dumps(baseline))
        partial = {"sweep_speedup": 9.0}   # everything else missing
        missing = update_baseline(json.loads(path.read_text()), partial,
                                  path)
        assert "storm_coalescing_ratio" in missing
        # nothing was written
        assert json.loads(path.read_text()) == baseline
        full = {name: float(spec["value"]) + 1
                for name, spec in baseline["metrics"].items()}
        assert update_baseline(json.loads(path.read_text()), full,
                               path) == []
        updated = json.loads(path.read_text())
        assert all(updated["metrics"][n]["value"] == v
                   for n, v in full.items())

    def test_speedup_floor_is_enforced(self, baseline):
        """The ISSUE-4 acceptance floor: even a baseline drift cannot
        let the batched path fall under 3x."""
        from benchmarks.check_regression import compare
        spec = baseline["metrics"]["sweep_speedup"]
        assert float(spec.get("floor", 0)) >= 3.0
        current = {"sweep_speedup": 2.9}
        failures, _ = compare({"metrics": {"sweep_speedup": spec}}, current)
        assert failures


class TestRunHarnessArtifactHygiene:
    def test_failed_bench_discards_its_artifacts(self, tmp_path):
        import benchmarks.run as harness

        class FakeBench:
            ARTIFACT_FILES = ("__stale_test__.json",)

        stale = (harness.Path(harness.__file__).parent / "artifacts"
                 / "__stale_test__.json")
        stale.parent.mkdir(exist_ok=True, parents=True)
        stale.write_text("{}")
        try:
            removed = harness.discard_artifacts(FakeBench())
            assert removed == ["__stale_test__.json"]
            assert not stale.exists()
            # idempotent: nothing left to remove
            assert harness.discard_artifacts(FakeBench()) == []
        finally:
            if stale.exists():
                stale.unlink()

    def test_every_artifact_writer_declares_ownership(self):
        """Each bench that writes artifacts must declare ARTIFACT_FILES
        so the harness can discard stale JSON when it fails."""
        import benchmarks.run as harness
        for name, mod in harness.discover().items():
            src = open(mod.__file__).read()
            if "write_text" in src and "artifacts" in src.lower():
                assert getattr(mod, "ARTIFACT_FILES", None), \
                    f"{name} writes artifacts but declares no " \
                    f"ARTIFACT_FILES"
