"""Cache hierarchies end to end: TierSpec/parent validation, the OSDF
preset, analytic-vs-sim per-tier byte parity, collapsed cache-to-cache
fill under a regional flash crowd, the two-round vectorized L1×L2 sweep
(zero serial cells, cell-exact against serial replay), tier sweep axes,
link-degradation scenarios, and the batched-executor regime gates."""
import dataclasses

import pytest

from repro.core import (FederationSpec, OutageSchedule, ScenarioSpec,
                        SiteSpec, SweepSpec, TierSpec, WorkloadSpec,
                        build_osdf_federation, run_scenario, run_sweep,
                        site_tiers)

PARITY_INTS = ("requests", "completed", "bytes_moved", "cache_hits",
               "cache_misses", "origin_egress_bytes", "parent_fill_bytes",
               "evictions", "bytes_evicted", "admission_rejects",
               "cache_failovers", "origin_fallbacks", "group_failovers",
               "outages", "recoveries")
PARITY_DICTS = ("tier_hits", "tier_misses", "tier_fill_bytes")
PARITY_FLOATS = ("hit_rate", "mean_seconds", "p50_seconds", "p95_seconds")

GB = 1000**3


def osdf_spec(n_requests=200, engine="analytic", **osdf_kw):
    osdf_kw.setdefault("edges_per_region", 2)
    osdf_kw.setdefault("workers_per_edge", 2)
    osdf_kw.setdefault("l1_capacity", 4 * GB)
    osdf_kw.setdefault("l2_capacity", 24 * GB)
    return ScenarioSpec(
        name="tiered", engine=engine,
        federation=FederationSpec.osdf(**osdf_kw),
        workload=WorkloadSpec(kind="zipf", n_requests=n_requests,
                              working_set=12, duration=600.0, seed=11))


# ---------------------------------------------------------------------------
# TierSpec / parent-graph validation
# ---------------------------------------------------------------------------
class TestTierSpec:
    def test_flatten_stamps_parent(self):
        tier = TierSpec(parent="backbone",
                        sites=[SiteSpec(name="a"), SiteSpec(name="b")])
        flat = tier.flatten()
        assert [s.parent for s in flat] == ["backbone", "backbone"]
        # originals untouched (flatten copies)
        assert all(s.parent is None for s in tier.sites)

    def test_site_tiers_depths(self):
        spec = FederationSpec.osdf(regions=("us-east", "us-west"))
        tiers = spec.site_tiers()
        assert tiers["us-east-edge0"] == 1
        assert tiers["us-west-edge1"] == 1
        assert tiers["us-east-backbone"] == 2
        assert "origin-facility" not in tiers  # cache-less
        assert spec.tier_depth() == 2

    def test_flat_federation_depth_one(self):
        assert FederationSpec.fleet(num_pods=2).tier_depth() == 1

    def test_unknown_parent_rejected(self):
        with pytest.raises(ValueError):
            site_tiers([SiteSpec(name="a", parent="ghost")])

    def test_cacheless_parent_rejected(self):
        with pytest.raises(ValueError):
            site_tiers([SiteSpec(name="a", parent="b"),
                        SiteSpec(name="b", has_cache=False)])

    def test_cacheless_child_with_parent_rejected(self):
        with pytest.raises(ValueError):
            site_tiers([SiteSpec(name="a", has_cache=False, parent="b"),
                        SiteSpec(name="b")])

    def test_parent_cycle_rejected(self):
        with pytest.raises(ValueError) as ei:
            site_tiers([SiteSpec(name="a", parent="b"),
                        SiteSpec(name="b", parent="a")])
        assert "cycle" in str(ei.value)

    def test_three_tier_chain(self):
        tiers = site_tiers([SiteSpec(name="edge", parent="mid"),
                            SiteSpec(name="mid", parent="top"),
                            SiteSpec(name="top")])
        assert tiers == {"edge": 1, "mid": 2, "top": 3}


# ---------------------------------------------------------------------------
# OSDF preset
# ---------------------------------------------------------------------------
class TestOsdfPreset:
    def test_build_shape(self):
        fed = build_osdf_federation()
        spec = FederationSpec.osdf()
        assert set(spec.cache_names()) == set(fed.caches)
        # CacheServer.tier stamped from the parent graph
        assert fed.caches["us-east-edge0/cache"].tier == 1
        assert fed.caches["us-east-backbone/cache"].tier == 2
        # edges fill from their regional backbone's ring
        edge = fed.caches["us-west-edge1/cache"]
        assert edge.parent_group is not None
        assert all(c.name.startswith("us-west-backbone")
                   for c in edge.parent_caches("/any/path"))
        # backbones are top tier: no parent
        assert fed.caches["us-east-backbone/cache"].parent_group is None

    def test_backbones_hold_no_workers(self):
        spec = FederationSpec.osdf()
        by_name = {s.name: s for s in spec.sites}
        assert by_name["us-east-backbone"].workers == 0
        assert by_name["origin-facility"].has_cache is False
        assert spec.origin_site == "origin-facility"


# ---------------------------------------------------------------------------
# Analytic vs simulated engine: per-tier byte parity
# ---------------------------------------------------------------------------
class TestTieredEngineParity:
    @pytest.fixture(scope="class")
    def summaries(self):
        # sequential single-flow chain with non-binding capacities: the
        # regime where both engines agree byte-for-byte (same framing as
        # TestEngineParity in test_api.py — eviction *timing* is engine-
        # specific; eviction-regime tiering is pinned by the batched-vs-
        # serial sweep parity below instead)
        spec = dataclasses.replace(
            osdf_spec(n_requests=200, engine="analytic",
                      l1_capacity=400 * GB, l2_capacity=400 * GB),
            sequential=True)
        analytic = run_scenario(spec).summary()
        sim = run_scenario(
            dataclasses.replace(spec, engine="sim")).summary()
        return analytic, sim

    def test_byte_exact_counters(self, summaries):
        analytic, sim = summaries
        for k in ("requests", "completed", "bytes_moved", "cache_hits",
                  "cache_misses", "origin_egress_bytes",
                  "parent_fill_bytes"):
            assert analytic[k] == sim[k], k
        for k in PARITY_DICTS:
            assert analytic[k] == sim[k], k

    def test_tier_counters_shape(self, summaries):
        analytic, _ = summaries
        assert set(analytic["tier_hits"]) == {"1", "2"}
        # edge misses fill from the parent tier, so tier-1 fill bytes
        # (bytes_from_parent + bytes_from_origin at tier 1) are positive
        assert analytic["tier_fill_bytes"]["1"] > 0
        assert analytic["parent_fill_bytes"] > 0
        # every origin byte egresses through the top tier
        assert analytic["tier_fill_bytes"]["2"] == \
            analytic["origin_egress_bytes"]

    def test_totals_cross_check(self, summaries):
        analytic, _ = summaries
        assert sum(analytic["tier_hits"].values()) == analytic["cache_hits"]
        assert sum(analytic["tier_misses"].values()) == \
            analytic["cache_misses"]


# ---------------------------------------------------------------------------
# Collapsed forwarding: a regional flash crowd fills cache-to-cache
# ---------------------------------------------------------------------------
class TestFlashCrowdEgress:
    @pytest.fixture(scope="class")
    def reports(self):
        tiered = osdf_spec(n_requests=120)
        flat_fed = dataclasses.replace(
            tiered.federation,
            sites=[dataclasses.replace(s, parent=None)
                   for s in tiered.federation.sites])
        crowd = WorkloadSpec(
            kind="flash_crowd", n_requests=120, working_set=12,
            duration=600.0, seed=11,
            hot_sites=("us-east-edge0", "us-east-edge1"),
            crowd_factor=4.0, crowd_at=60.0, crowd_duration=120.0,
            n_objects=3, size=500_000_000)
        t = run_scenario(dataclasses.replace(
            tiered, workload=crowd)).summary()
        f = run_scenario(dataclasses.replace(
            tiered, federation=flat_fed, workload=crowd)).summary()
        return t, f

    def test_tiered_fill_cuts_origin_egress(self, reports):
        tiered, flat = reports
        assert tiered["origin_egress_bytes"] < flat["origin_egress_bytes"]
        assert tiered["parent_fill_bytes"] > 0
        assert flat["parent_fill_bytes"] == 0

    def test_crowd_requests_present(self, reports):
        tiered, flat = reports
        assert tiered["requests"] == flat["requests"] > 120


# ---------------------------------------------------------------------------
# Two-round vectorized sweep: L1×L2 split-sizing with zero serial cells
# ---------------------------------------------------------------------------
class TestTierSweepParity:
    @pytest.fixture(scope="class")
    def reports(self):
        sweep = SweepSpec(name="l1xl2", base=osdf_spec(n_requests=60), axes={
            "federation.tier1.cache_capacity": [2 * GB, 6 * GB],
            "federation.tier2.cache_capacity": [4 * GB, 12 * GB, 24 * GB],
            "federation.eviction_policy": ["lru", "fifo"],
        })
        batched = run_sweep(sweep, batched=True)
        serial = run_sweep(sweep, batched=False, price_contention=False)
        return batched, serial

    def test_no_serial_cells(self, reports):
        batched, _ = reports
        assert len(batched.cells) == 12
        assert batched.batched_cells == len(batched.cells)
        assert batched.serial_cells == 0
        assert all(c.executor == "batched" for c in batched.cells)

    def test_two_kernel_rounds(self, reports):
        batched, _ = reports
        assert batched.solver.get("tier_rounds") == 2

    def test_every_cell_is_byte_exact(self, reports):
        batched, serial = reports
        for cb, cs in zip(batched.cells, serial.cells):
            assert cb.params == cs.params
            for k in PARITY_INTS:
                assert cb.summary[k] == cs.summary[k], (cb.params, k)
            for k in PARITY_DICTS:
                assert cb.summary[k] == cs.summary[k], (cb.params, k)
            for k in PARITY_FLOATS:
                assert cb.summary[k] == pytest.approx(cs.summary[k],
                                                      rel=1e-9), \
                    (cb.params, k)

    def test_split_sizing_moves_the_needle(self, reports):
        batched, _ = reports
        egress = {c.summary["origin_egress_bytes"] for c in batched.cells}
        assert len(egress) > 1  # the L1/L2 split actually matters


class TestTierSweepAxes:
    def test_tier_axis_targets_one_tier(self):
        sweep = SweepSpec(name="s", base=osdf_spec(), axes={
            "federation.tier2.cache_capacity": [7 * GB]})
        _, spec = sweep.cells()[0]
        tiers = spec.federation.site_tiers()
        for s in spec.federation.sites:
            if not s.has_cache:
                continue
            if tiers[s.name] == 2:
                assert s.cache_capacity == 7 * GB
            else:
                assert s.cache_capacity != 7 * GB

    def test_missing_tier_rejected(self):
        with pytest.raises(ValueError):
            SweepSpec(name="s", base=osdf_spec(),
                      axes={"federation.tier3.cache_capacity": [1]}).cells()

    def test_structural_tier_fields_rejected(self):
        for axis in ("federation.tier1.name", "federation.tier1.parent",
                     "federation.tier1.nope"):
            with pytest.raises(ValueError):
                SweepSpec(name="s", base=osdf_spec(),
                          axes={axis: [1]}).cells()


# ---------------------------------------------------------------------------
# Parent-tier outages stay vectorized (cache-kind events only)
# ---------------------------------------------------------------------------
class TestParentOutageParity:
    @pytest.fixture(scope="class")
    def reports(self):
        base = dataclasses.replace(
            osdf_spec(n_requests=60),
            outages=OutageSchedule.restart_storm(
                ["us-east-backbone/cache"], at=150.0, downtime=200.0,
                cold=True))
        sweep = SweepSpec(name="parent-outage", base=base, axes={
            "federation.tier1.cache_capacity": [2 * GB, 6 * GB],
            "workload.seed": [11, 12],
        })
        batched = run_sweep(sweep, batched=True)
        serial = run_sweep(sweep, batched=False, price_contention=False)
        return batched, serial

    def test_dead_parent_falls_back_flat(self, reports):
        batched, serial = reports
        assert batched.serial_cells == 0
        assert sum(c.summary["outages"] for c in batched.cells) > 0
        for cb, cs in zip(batched.cells, serial.cells):
            for k in PARITY_INTS + PARITY_DICTS:
                assert cb.summary[k] == cs.summary[k], (cb.params, k)


# ---------------------------------------------------------------------------
# Backbone-link degradation (simulated engine)
# ---------------------------------------------------------------------------
class TestLinkDegradation:
    def test_degraded_region_net_slows_transfers(self):
        spec = osdf_spec(n_requests=80, engine="sim")
        base = run_scenario(spec).summary()
        degraded = run_scenario(dataclasses.replace(
            spec, outages=OutageSchedule.link_degradation(
                ["region/us-east", "region/us-west"], at=30.0,
                duration=540.0, factor=0.02))).summary()
        # caches never fail over — the path just got slower
        assert degraded["cache_failovers"] == base["cache_failovers"]
        assert degraded["mean_seconds"] > base["mean_seconds"]

    def test_degrade_restore_idempotent(self):
        fed = build_osdf_federation()
        link = fed.topology.region_net("us-east")
        nominal = link.bandwidth
        link.degrade(0.1)
        link.degrade(0.1)  # composes against the original, not itself
        assert link.bandwidth == pytest.approx(0.1 * nominal)
        link.restore()
        assert link.bandwidth == nominal


# ---------------------------------------------------------------------------
# Batched-regime gates: what must fall back to serial replay
# ---------------------------------------------------------------------------
class TestBatchableGates:
    def _one_cell(self, base):
        return run_sweep(SweepSpec(name="gate", base=base), batched=True)

    def test_probe_ranking_serializes(self):
        rep = self._one_cell(dataclasses.replace(
            osdf_spec(n_requests=24), ranking="probe"))
        assert rep.cells[0].executor == "serial"

    def test_link_outage_serializes(self):
        rep = self._one_cell(dataclasses.replace(
            osdf_spec(n_requests=24),
            outages=OutageSchedule.link_degradation(
                ["region/us-east"], at=30.0, duration=100.0)))
        assert rep.cells[0].executor == "serial"

    def test_three_tier_hierarchy_serializes(self):
        deep = FederationSpec(sites=[
            SiteSpec(name="edge", workers=2, has_proxy=False,
                     parent="mid", cache_capacity=2 * GB),
            SiteSpec(name="mid", workers=0, has_proxy=False,
                     parent="top", cache_capacity=4 * GB),
            SiteSpec(name="top", workers=0, has_proxy=False,
                     cache_capacity=8 * GB),
            SiteSpec(name="store", workers=0, has_cache=False,
                     has_proxy=False)],
            origin_site="store")
        base = dataclasses.replace(osdf_spec(n_requests=24),
                                   federation=deep)
        rep = self._one_cell(base)
        assert rep.cells[0].executor == "serial"
        # and the serial replay still agrees with a direct run
        serial = run_scenario(base)
        assert rep.cells[0].summary["origin_egress_bytes"] == \
            serial.summary()["origin_egress_bytes"]


# ---------------------------------------------------------------------------
# Monitoring: the per-tier fleet table
# ---------------------------------------------------------------------------
class TestTierMonitoring:
    def test_tier_table_splits_levels(self):
        spec = osdf_spec(n_requests=80, engine="sim")
        fed = spec.federation.build()
        run_scenario(spec, federation=fed)
        for cache in fed.caches.values():
            cache.report_usage()
        rows = fed.monitor.tier_table()
        assert [r[0] for r in rows] == [1, 2]
        tier1, tier2 = rows
        assert tier1[1] == 4 and tier2[1] == 2  # caches per tier
        assert tier1[3] > 0   # edges pulled cache-to-cache from parents
        assert tier2[3] == 0  # backbones have no parent: origin pulls only
