"""Fluid-flow DES tests: fair sharing, contention, paper-scenario logic."""
import pytest

from repro.core import (
    BandwidthProfile, Coord, DownloadResult, FluidFlowSim, Topology,
    build_osg_federation, direct_download, proxy_download, stash_download,
)


def _topo_two_sites():
    topo = Topology()
    topo.add_site("a", BandwidthProfile(site_uplink=1e9))
    topo.add_site("b", BandwidthProfile(site_uplink=1e9))
    topo.add_node("a0", Coord("a", 0, 0), nic_bw=1e9)
    topo.add_node("a1", Coord("a", 0, 1), nic_bw=1e9)
    topo.add_node("b0", Coord("b", 0, 0), nic_bw=1e9)
    topo.wan.bandwidth = 10e9
    return topo


class TestFluidFlow:
    def test_single_flow_uses_bottleneck(self):
        topo = _topo_two_sites()
        sim = FluidFlowSim(topo)
        done = {}

        def proc():
            f = yield sim.flow("a0", "b0", 1e9, streams=16)
            done["t"] = sim.t

        sim.spawn(proc())
        sim.run()
        # 1 GB over a 1 Gbps-bottleneck path ≈ 1s (plus negligible latency)
        assert done["t"] == pytest.approx(1.0, rel=0.05)

    def test_two_flows_share_bottleneck_fairly(self):
        topo = _topo_two_sites()
        sim = FluidFlowSim(topo)
        finish = []

        def proc(src):
            yield sim.flow(src, "b0", 1e9, streams=16)
            finish.append(sim.t)

        sim.spawn(proc("a0"))
        sim.spawn(proc("a1"))
        sim.run()
        # Both share b0's 1 Gbps NIC → each ~0.5 Gbps → ~2s.
        assert finish[-1] == pytest.approx(2.0, rel=0.05)

    def test_tcp_single_stream_cap_on_wan(self):
        """Single-stream HTTP is window-limited on long-RTT paths; 8-stream
        XRootD is not (paper §3.1's multi-stream rationale)."""
        topo = _topo_two_sites()
        topo.wan.latency = 0.050  # 100 ms RTT
        sim = FluidFlowSim(topo)
        t = {}

        def proc(streams, key):
            yield sim.flow("a0", "b0", 1e9, streams=streams)
            t[key] = sim.t

        sim.spawn(proc(1, "http"))
        sim.run()
        sim2 = FluidFlowSim(topo)

        def proc2():
            yield sim2.flow("a0", "b0", 1e9, streams=8)
            t["xrootd"] = sim2.t

        sim2.spawn(proc2())
        sim2.run()
        assert t["http"] > 2.0 * t["xrootd"]

    def test_max_min_respects_flow_cap(self):
        topo = _topo_two_sites()
        topo.wan.latency = 0.050
        sim = FluidFlowSim(topo)
        fin = {}

        def proc(name, streams):
            yield sim.flow("a0", "b0", 5e8, streams=streams)
            fin[name] = sim.t

        sim.spawn(proc("capped", 1))    # TCP-capped well under fair share
        sim.spawn(proc("greedy", 32))   # takes the leftover
        sim.run()
        assert fin["greedy"] < fin["capped"]

    def test_run_until(self):
        topo = _topo_two_sites()
        sim = FluidFlowSim(topo)

        def proc():
            yield sim.flow("a0", "b0", 1e12)

        sim.spawn(proc())
        assert sim.run(until=0.5) == 0.5
        assert sim.active  # still transferring


class TestEventLoopScaling:
    def _many_site_topo(self, n):
        topo = Topology()
        topo.add_site("dst", BandwidthProfile(site_uplink=1e9))
        topo.add_node("dst0", Coord("dst", 0, 0), nic_bw=1e9)
        for i in range(n):
            topo.add_site(f"s{i}", BandwidthProfile(site_uplink=1e9))
            topo.add_node(f"w{i}", Coord(f"s{i}", 0, 0), nic_bw=1e9)
        return topo

    def test_reallocations_count_distinct_event_times_not_arrivals(self):
        """A storm of same-timestamp arrivals is ONE solve; symmetric
        flows complete together, so each completion batch is one more."""
        n = 30
        topo = self._many_site_topo(n)
        sim = FluidFlowSim(topo, solver="scalar")

        def proc(i, at):
            yield sim.delay(at)
            yield sim.flow(f"w{i}", "dst0", 1e9, streams=16)

        for i in range(n):
            sim.spawn(proc(i, 0.0))       # batch 1: all arrive at t=0
        for i in range(n):
            sim.spawn(proc(i, 1.0))       # batch 2: all arrive at t=1
        sim.run()
        assert sim.completed_flows == 2 * n
        assert sim.flow_events == 4 * n   # arrivals + completions
        # One solve per distinct event time with work remaining: the two
        # arrival batches and the first completion batch.  The final
        # completion batch empties the active set — nothing to solve.
        assert sim.reallocations == 3

    def test_run_until_resume_matches_uninterrupted(self):
        """Chunked run(until=...) must complete the same flows at the
        same times as one uninterrupted run()."""
        def build():
            topo = self._many_site_topo(8)
            sim = FluidFlowSim(topo, solver="scalar")
            done = []

            def proc(i, at, nbytes, streams):
                yield sim.delay(at)
                yield sim.flow(f"w{i}", "dst0", nbytes, streams=streams)
                done.append((i, sim.t))

            for i in range(8):
                sim.spawn(proc(i, 0.13 * i, 5e8 + 1e8 * i, 4 + i))
            return sim, done

        sim1, done1 = build()
        sim1.run()
        sim2, done2 = build()
        t = 0.25
        while sim2._eventq or sim2.active:
            sim2.run(until=t)
            t += 0.25
        assert len(done2) == len(done1) == 8
        for (i1, t1), (i2, t2) in zip(done1, done2):
            assert i1 == i2
            assert t2 == pytest.approx(t1, rel=1e-9)
        assert sim2.link_bytes["dst0/nic"] == pytest.approx(
            sim1.link_bytes["dst0/nic"], rel=1e-6)

    def test_run_until_never_moves_time_backward(self):
        topo = self._many_site_topo(1)
        sim = FluidFlowSim(topo)

        def proc():
            yield sim.flow("w0", "dst0", 1e12)

        sim.spawn(proc())
        assert sim.run(until=0.5) == 0.5
        assert sim.run(until=0.25) == 0.5  # stale deadline: no-op
        assert sim.t == 0.5

    def test_resume_after_idle_until_processes_later_events(self):
        """Events scheduled beyond the first `until` horizon still fire
        when the sim is resumed (finish-heap state survives the pause)."""
        topo = self._many_site_topo(2)
        sim = FluidFlowSim(topo)
        done = []

        def proc(i, at):
            yield sim.delay(at)
            yield sim.flow(f"w{i}", "dst0", 1e9, streams=16)
            done.append(sim.t)

        sim.spawn(proc(0, 0.0))
        sim.spawn(proc(1, 5.0))    # arrives long after the pause point
        sim.run(until=0.5)
        assert not done
        sim.run()
        assert len(done) == 2
        assert done[0] == pytest.approx(1.0, rel=0.05)
        assert done[1] == pytest.approx(6.0, rel=0.05)


class TestPaperScenarios:
    def setup_method(self):
        self.fed = build_osg_federation()
        self.origin = self.fed.origins[0]
        self.meta = self.origin.put_object("/testing/f", 2_335_000_000)

    def _sim(self):
        return FluidFlowSim(self.fed.topology, self.fed.net)

    def test_stash_cold_vs_warm(self):
        sim = self._sim()
        cache = self.fed.caches["syracuse/cache"]
        wnode = self.fed.client("syracuse", 0).node.name
        cold, warm = DownloadResult("/testing/f", 1, "s"), \
            DownloadResult("/testing/f", 1, "s")
        sim.spawn(stash_download(sim, wnode, cache, self.origin.node.name,
                                 "chicago/redirector1", self.meta, 0.2,
                                 result=cold))
        sim.run()
        sim2 = self._sim()
        sim2.spawn(stash_download(sim2, wnode, cache, self.origin.node.name,
                                  "chicago/redirector1", self.meta, 0.2,
                                  result=warm))
        sim2.run()
        assert not cold.cache_hit and warm.cache_hit
        assert warm.seconds < cold.seconds  # Fig. 7: cached always better

    def test_proxy_never_caches_big_file(self):
        sim = self._sim()
        proxy = self.fed.proxies["nebraska"]
        wnode = self.fed.client("nebraska", 0).node.name
        r1, r2 = DownloadResult("f", 1, "p"), DownloadResult("f", 1, "p")
        sim.spawn(proxy_download(sim, wnode, proxy, self.origin.node.name,
                                 self.meta, result=r1))
        sim.run()
        sim2 = self._sim()
        sim2.spawn(proxy_download(sim2, wnode, proxy, self.origin.node.name,
                                  self.meta, result=r2))
        sim2.run()
        assert not r1.cache_hit and not r2.cache_hit  # 2.3 GB > cacheable cap

    def test_wan_contention_many_workers(self):
        """N workers pulling directly from origin saturate the site uplink;
        with a local cache, the WAN sees the file once (Fig. 5)."""
        meta = self.origin.put_object("/testing/ws", 500_000_000)
        # direct: 8 workers, no cache
        sim = self._sim()
        for w in range(8):
            wnode = self.fed.client("syracuse", w).node.name
            sim.spawn(direct_download(sim, wnode, self.origin.node.name,
                                      meta, streams=8))
        sim.run()
        wan_direct = sim.link_bytes.get("wan", 0.0)
        # cached: same 8 workers through the site cache
        sim2 = self._sim()
        cache = self.fed.caches["syracuse/cache"]
        for w in range(8):
            wnode = self.fed.client("syracuse", w).node.name
            sim2.spawn(stash_download(sim2, wnode, cache,
                                      self.origin.node.name,
                                      "chicago/redirector1", meta, 0.2))
        sim2.run()
        wan_cached = sim2.link_bytes.get("wan", 0.0)
        assert wan_direct >= 7.5 * wan_cached  # ≥8× WAN offload
