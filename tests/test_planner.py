"""Predictive planner: fitted cache models (forward accuracy against
exact replays, gradient flow) and the inverse capacity optimizer
(feasibility by exact-replay verification, savings vs uniform sizing),
plus the SweepAggregator validation surfaces they publish through."""
import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (FederationSpec, PlannerSpec, ScenarioSpec,
                        SweepAggregator, SweepSpec, WorkloadSpec,
                        apply_capacities, generate_workload,
                        groups_for_federation, plan_capacity, predict,
                        run_sweep, verify_plan)
from repro.kernels.cache_model import (ReuseHistogram, fit_interp_model,
                                       fit_lognormal_mixture,
                                       fleet_hit_rate, predict_hit_rate,
                                       predict_miss_bytes, reuse_histogram,
                                       stack_models)

CAP_AXIS = "federation.cache_capacity"


def chunk_hit(summary):
    """Chunk-level hit rate — the fraction the models predict (the
    request-level ``summary['hit_rate']`` mixes multi-chunk files)."""
    refs = summary["cache_hits"] + summary["cache_misses"]
    return summary["cache_hits"] / max(refs, 1)


def base_spec(n_requests=260, **fed_kw):
    fed_kw.setdefault("num_pods", 2)
    fed_kw.setdefault("hosts_per_pod", 2)
    fed_kw.setdefault("cache_capacity", 2e9)
    return ScenarioSpec(
        name="cell", engine="analytic",
        federation=FederationSpec.fleet(**fed_kw),
        workload=WorkloadSpec(kind="zipf", n_requests=n_requests,
                              working_set=8, duration=600.0, seed=5))


def hetero_spec():
    """Two pods with very different locality: pod0 hot and skewed,
    pod1 mostly cold — the planner should starve pod1."""
    fed = FederationSpec.fleet(num_pods=2, hosts_per_pod=2,
                               cache_capacity=2e9)
    wl = (generate_workload([fed.sites[0].name], 700, seed=0,
                            working_set=6, zipf_a=1.6)
          + generate_workload([fed.sites[1].name], 150, seed=1,
                              working_set=64, zipf_a=1.05))
    wl.sort(key=lambda r: r.time)
    return ScenarioSpec(name="hetero", engine="analytic",
                        federation=fed, workload=wl)


@pytest.fixture(scope="module")
def fit_report():
    grid = list(np.geomspace(4e8, 2e10, 6))
    return run_sweep(SweepSpec(name="fit", base=base_spec(),
                               axes={CAP_AXIS: grid}), fit=True)


@pytest.fixture(scope="module")
def hetero_fit():
    base = hetero_spec()
    rep = run_sweep(SweepSpec(name="hfit", base=base, axes={}), fit=True)
    return base, rep


class TestFitSweep:
    def test_fit_attaches_models_and_histograms(self, fit_report):
        models = fit_report.fitted_models()
        hists = fit_report.reuse_histograms()
        assert models and set(models) == set(hists)
        assert all(m.kind == "hist" for m in models.values())
        assert fit_report.summary()["fitted_cells"] == len(fit_report.cells)
        assert fit_report.summary()["solver"]["fit_streams"] >= len(models)
        # the histogram dicts are JSON-safe (what a dashboard ingests)
        json.dumps(hists)

    def test_fit_off_by_default(self):
        rep = run_sweep(SweepSpec(name="nofit", base=base_spec(60),
                                  axes={}))
        assert rep.fitted_models() == {}
        assert rep.reuse_histograms() == {}
        assert rep.summary()["fitted_cells"] == 0

    def test_histogram_conservation(self, fit_report):
        """Bucketed mass + compulsory mass = totals, exactly."""
        for d in fit_report.reuse_histograms().values():
            h = ReuseHistogram.from_dict(d)
            assert h.ref_weights.sum() + h.compulsory_refs == pytest.approx(
                h.total_refs)
            assert h.byte_weights.sum() + h.compulsory_bytes == (
                pytest.approx(h.total_bytes, rel=1e-9))

    def test_histogram_roundtrip(self):
        rng = np.random.default_rng(0)
        dist = rng.exponential(1e9, 500)
        dist[rng.random(500) < 0.2] = np.inf
        sizes = rng.integers(1, 1e8, 500).astype(float)
        h = reuse_histogram(dist, sizes)
        h2 = ReuseHistogram.from_dict(h.to_dict())
        np.testing.assert_allclose(h2.edges, h.edges)
        np.testing.assert_allclose(h2.ref_weights, h.ref_weights)
        assert h2.total_refs == h.total_refs


class TestForwardAccuracy:
    def test_heldout_grid_within_two_percent(self, fit_report):
        """The acceptance gate: the fitted curves never saw the swept
        capacities (they come from capacity-independent distances), so
        every grid cell is held out."""
        models = fit_report.fitted_models()
        errs = []
        for c in fit_report.cells:
            pred = predict(models, c.params[CAP_AXIS])["hit_rate"]
            errs.append(abs(pred - chunk_hit(c.summary)))
        assert max(errs) <= 0.02

    def test_mixture_compact_signature(self, fit_report):
        """The parametric mixture trades accuracy for compactness:
        looser band, but still monotone and close."""
        hists = {k: ReuseHistogram.from_dict(d)
                 for k, d in fit_report.reuse_histograms().items()}
        models = {k: fit_lognormal_mixture(h) for k, h in hists.items()}
        assert all(m.kind == "mixture" for m in models.values())
        errs = []
        for c in fit_report.cells:
            pred = predict(models, c.params[CAP_AXIS])["hit_rate"]
            errs.append(abs(pred - chunk_hit(c.summary)))
        assert max(errs) <= 0.04

    def test_fifo_interp_heldout(self):
        """FIFO columns are out of the stack model's reach; the interp
        model fits exact swept points and interpolates between them.
        FIFO hit curves are genuine staircases (whole hot objects cross
        the boundary at once), so midpoint interpolation carries a few
        points of error the ≤2% gate on the smooth LRU models does not
        — the band here covers the worst step."""
        spec = base_spec()
        fed = dataclasses.replace(spec.federation, sites=[
            dataclasses.replace(s, eviction_policy="fifo")
            if s.has_cache else s for s in spec.federation.sites])
        spec = dataclasses.replace(spec, federation=fed)
        grid = list(np.geomspace(4e8, 2e10, 13))
        rep = run_sweep(SweepSpec(name="fifo", base=spec,
                                  axes={CAP_AXIS: grid}))
        pts = [(c.params[CAP_AXIS], chunk_hit(c.summary))
               for c in rep.cells]
        train, held = pts[::2], pts[1::2]
        model = fit_interp_model([p[0] for p in train],
                                 [p[1] for p in train])
        errs = [abs(float(predict_hit_rate(model, cap)) - h)
                for cap, h in held]
        assert max(errs) <= 0.06

    def test_interp_exact_at_knots(self):
        model = fit_interp_model([1e9, 4e9, 1e10], [0.1, 0.4, 0.6])
        for cap, h in ((1e9, 0.1), (4e9, 0.4), (1e10, 0.6)):
            assert float(predict_hit_rate(model, cap)) == pytest.approx(
                h, abs=1e-6)
        # clipped, not extrapolated, outside the knots
        assert float(predict_hit_rate(model, 1e6)) == pytest.approx(0.1)
        assert float(predict_hit_rate(model, 1e14)) == pytest.approx(0.6)

    def test_miss_bytes_complements_hits(self, fit_report):
        """At huge capacity only compulsory bytes miss; at tiny
        capacity everything does."""
        for m in fit_report.fitted_models().values():
            tiny = float(predict_miss_bytes(m, 1.0))
            huge = float(predict_miss_bytes(m, 1e18))
            assert tiny == pytest.approx(m.total_bytes, rel=1e-3)
            assert huge == pytest.approx(m.compulsory_bytes, rel=1e-3)


class TestGradients:
    def test_grad_flows_through_fleet_hit_rate(self, fit_report):
        models = fit_report.fitted_models()
        stacked = stack_models(models)
        with enable_x64():
            def fleet(logc):
                return fleet_hit_rate(stacked, jnp.exp(logc))

            g = jax.grad(fleet)(jnp.full(len(stacked.names),
                                         np.log(2e9), jnp.float64))
            g = np.asarray(g)
        assert np.isfinite(g).all()
        assert (g > 0).all()   # more capacity never hurts

    def test_predict_matches_stacked(self, fit_report):
        models = fit_report.fitted_models()
        stacked = stack_models(models)
        caps = {n: 3e9 for n in models}
        with enable_x64():
            fleet = float(fleet_hit_rate(
                stacked, jnp.asarray([caps[n] for n in stacked.names],
                                     jnp.float64)))
        # predict() evaluates in default f32, the stacked path in f64
        assert predict(models, caps)["hit_rate"] == pytest.approx(
            fleet, abs=1e-5)


class TestAggregatorSurfaces:
    def _agg(self):
        agg = SweepAggregator()
        for policy in ("lru", "fifo"):
            for i, cap in enumerate((1e9, 2e9, 4e9)):
                agg.add({"federation.eviction_policy": policy,
                         CAP_AXIS: cap},
                        {"hit_rate": 0.2 + 0.1 * i
                         + (0.05 if policy == "lru" else 0.0),
                         "evictions": 10, "bytes_evicted": 100,
                         "admission_rejects": 0})
        return agg

    def test_hit_rate_curve_matches_policy_marginals(self):
        """Averaging a policy's curve points reproduces that policy's
        marginal — same rows, two views."""
        agg = self._agg()
        curves = {c[0]["federation.eviction_policy"]: c[1]
                  for c in agg.hit_rate_curve()}
        marginals = {row[0]: row[2] for row in agg.policy_marginals()}
        assert set(curves) == set(marginals)
        for policy, pts in curves.items():
            assert [p[0] for p in pts] == [1e9, 2e9, 4e9]   # sorted
            mean = sum(v for _, v in pts) / len(pts)
            assert mean == pytest.approx(marginals[policy])

    def test_hit_rate_curve_no_capacity_axis(self):
        agg = SweepAggregator()
        agg.add({"workload.seed": 1}, {"hit_rate": 0.5})
        assert agg.hit_rate_curve() == []

    def test_model_residuals(self):
        agg = self._agg()

        def pred(params):
            if params["federation.eviction_policy"] != "lru":
                return None
            return 0.3

        rows = agg.model_residuals(pred)
        assert len(rows) == 3   # fifo cells skipped
        for params, observed, predicted, residual in rows:
            assert predicted == 0.3
            assert residual == pytest.approx(predicted - observed)


class TestInversePlanner:
    def test_plan_feasible_and_beats_uniform(self, hetero_fit):
        base, rep = hetero_fit
        models = rep.fitted_models()
        groups = groups_for_federation(base.federation.build(), models)
        spec = PlannerSpec(models=models, target_hit_rate=0.5,
                           groups=groups)
        plan = plan_capacity(spec)
        assert plan.predicted_hit_rate >= 0.5
        assert set(plan.capacities) == set(groups)
        assert set(plan.per_cache) == set(models)
        ver = verify_plan(plan, base)
        assert ver.verification["feasible"]
        assert ver.verification["achieved_hit_rate"] >= 0.5
        assert ver.verification["executor"] == "batched"
        # the asymmetric optimum is far cheaper than uniform sizing
        assert ver.savings_vs_uniform > 0.2
        assert ver.total_capacity < ver.uniform_total

    def test_plan_summary_schema(self, hetero_fit):
        base, rep = hetero_fit
        models = rep.fitted_models()
        plan = plan_capacity(PlannerSpec(models=models,
                                         target_hit_rate=0.4),
                             federation=base.federation.build())
        ver = verify_plan(plan, base)
        s = ver.summary()
        for key in ("capacities", "per_cache", "predicted_hit_rate",
                    "total_capacity", "uniform_total",
                    "savings_vs_uniform", "verification", "telemetry"):
            assert key in s
        assert s["verification"]["feasible"] in (True, False)
        json.dumps(s)

    def test_infeasible_target_reported_not_hidden(self, hetero_fit):
        """A target above the workload's compulsory-miss ceiling can
        never verify; the report says so instead of pretending."""
        base, rep = hetero_fit
        models = rep.fitted_models()
        plan = plan_capacity(PlannerSpec(models=models,
                                         target_hit_rate=0.99))
        ver = verify_plan(plan, base, max_attempts=2)
        assert not ver.verification["feasible"]
        assert ver.verification["attempts"] == 2

    def test_apply_capacities_roundtrip(self, hetero_fit):
        base, _ = hetero_fit
        caps = {s.name: 7e9 for s in base.federation.sites}
        fed = apply_capacities(base.federation, caps)
        assert all(s.cache_capacity == 7e9 for s in fed.sites
                   if s.name in caps)
        # untouched spec stays inert
        assert base.federation.sites[0].cache_capacity == 2e9

    def test_egress_budget_constrains(self, hetero_fit):
        base, rep = hetero_fit
        models = rep.fitted_models()
        loose = plan_capacity(PlannerSpec(models=models,
                                          target_hit_rate=0.4))
        tight = plan_capacity(PlannerSpec(
            models=models, target_hit_rate=0.4,
            target_egress_bytes=loose.predicted_egress_bytes * 0.8))
        assert tight.predicted_egress_bytes <= (
            loose.predicted_egress_bytes * 0.8 * 1.02)
        assert tight.total_capacity >= loose.total_capacity * 0.99
