"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps,
fed by the federation, with checkpoint/restart fault tolerance.

The full production path in miniature: synthetic token shards published
through the data plane → per-pod caches → ranged cvmfs FetchRequests →
FederatedDataLoader → jitted train step → write-back checkpoint stores →
injected failure at step 60 → automatic restore + exact replay.  Loader
and checkpointer both talk only to the one AnalyticPlane; their unified
FetchRollups roll up into the Table-1-style consumer table.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch qwen2-7b]
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.core import AnalyticPlane, build_fleet_federation, consumer_table
from repro.data import DatasetSpec, FederatedDataLoader, SyntheticTokens
from repro.train import (AdamWConfig, FailureInjector, FederatedCheckpointer,
                         Trainer)


def hundred_m_config(arch: str):
    """Scale the chosen architecture family to ~100M params."""
    base = get_config(arch, smoke=True)
    return dataclasses.replace(
        base, name=f"{arch}-100m", num_layers=max(4, len(base.pattern()) * 2),
        d_model=512, num_heads=8, num_kv_heads=4 if base.num_kv_heads else 0,
        head_dim=64 if base.num_heads else 0,
        d_ff=2048 if base.d_ff else 0, vocab_size=32_768,
        ssm_state=base.ssm_state and 64, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = hundred_m_config(args.arch)
    print(f"config: {cfg.name}: {cfg.param_count() / 1e6:.0f}M params")

    fed = build_fleet_federation(num_pods=2, hosts_per_pod=8)
    plane = AnalyticPlane(fed)
    spec = DatasetSpec("train-demo", vocab_size=cfg.vocab_size,
                       tokens_per_shard=1 << 18, num_shards=32)
    SyntheticTokens(spec).publish(fed.origins[0])
    loader = FederatedDataLoader(plane, spec, global_batch=args.batch,
                                 seq_len=args.seq, site="pod0", worker=0)
    ck = FederatedCheckpointer("train-demo", plane, site="pod0", worker=1)
    trainer = Trainer(cfg, loader,
                      AdamWConfig(lr=3e-3, warmup_steps=20,
                                  total_steps=args.steps),
                      checkpointer=ck, checkpoint_every=50)

    t0 = time.time()
    report = trainer.run(args.steps,
                         failure=FailureInjector(fail_at=[60]))
    dt = time.time() - t0
    print(f"ran {report.steps_run} steps in {dt:.1f}s "
          f"({report.steps_run / dt:.1f} steps/s)")
    print(f"loss {report.losses[0]:.3f} → {report.final_loss:.3f}")
    print(f"restarts: {report.restarts} (restored from checkpoint at "
          f"{report.restored_from})")
    print(f"data-plane cache hit rate: {report.cache_hit_rate:.2f}")
    print(f"origin egress: {fed.origins[0].stats.egress_bytes / 1e6:.1f} MB "
          f"for {loader.stats.bytes_fetched / 1e6:.1f} MB consumed")
    for row in consumer_table([loader.stats, ck.stats]):
        print(f"  {row['consumer']}: {row['fetches']} fetches / "
              f"{row['stores']} stores, hit rate {row['hit_rate']:.2f}")
    assert report.final_loss < report.losses[0], "loss must improve"


if __name__ == "__main__":
    main()
