"""How much disk does each site need for a 95% fleet hit rate?

One fit=True sweep distills each cache's reuse profile into a
differentiable hit-rate curve; the planner then *minimizes total fleet
capacity* subject to the target by gradient descent, and the
recommendation is verified by an exact batched replay — no trial sweeps.

Run:  PYTHONPATH=src python examples/plan_capacity.py
"""
from repro.core import (FederationSpec, PlannerSpec, ScenarioSpec, SweepSpec,
                        WorkloadSpec, groups_for_federation, plan_capacity,
                        run_sweep, verify_plan)


def main():
    base = ScenarioSpec(
        name="zipf", engine="analytic",
        federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=2),
        workload=WorkloadSpec(kind="zipf", n_requests=2000, working_set=4,
                              duration=3600.0, seed=7))
    report = run_sweep(SweepSpec(name="fit", base=base, axes={}), fit=True)
    models = report.fitted_models()
    groups = groups_for_federation(base.federation.build(), models)
    plan = verify_plan(plan_capacity(PlannerSpec(
        models=models, target_hit_rate=0.95, groups=groups)), base)
    for site, cap in sorted(plan.capacities.items()):
        print(f"{site}: {cap / 1e9:8.2f} GB")
    print(f"fleet hit rate {plan.verification['achieved_hit_rate']:.3f} "
          f"(exact replay), {plan.savings_vs_uniform:.1%} less disk than "
          f"uniform sizing")


if __name__ == "__main__":
    main()
