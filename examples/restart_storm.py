"""Restart-storm demo: 128 hosts re-read one checkpoint after a preemption.

The fleet translation of the paper's headline value: with pod caches the
origin serves each byte once per pod (collapsed forwarding absorbs the
concurrent pulls); direct-to-origin it serves it 128 times and the storm
takes ~9× longer (see benchmarks/bench_restart_storm.py for the measured
sweep).

Run:  PYTHONPATH=src python examples/restart_storm.py
"""
from repro.core import (FluidFlowSim, build_fleet_federation,
                        direct_download, stash_download)


def storm(use_cache: bool, pods=2, hosts=64, ckpt_gb=8.0):
    fed = build_fleet_federation(num_pods=pods, hosts_per_pod=hosts)
    origin = fed.origins[0]
    meta = origin.put_object("/ckpt/run/step_42/params.npy",
                             int(ckpt_gb * 1e9))
    sim = FluidFlowSim(fed.topology, fed.net)
    redirector = fed.redirectors.members[0].node.name
    for p in range(pods):
        cache = fed.caches[f"pod{p}/cache"]
        for h in range(hosts):
            wnode = fed.client(f"pod{p}", h).node.name
            if use_cache:
                sim.spawn(stash_download(sim, wnode, cache,
                                         origin.node.name, redirector, meta,
                                         fed.geoip.lookup_latency))
            else:
                sim.spawn(direct_download(sim, wnode, origin.node.name,
                                          meta, streams=8))
    dur = sim.run()
    egress = sum(c.stats.bytes_from_origin for c in fed.caches.values()) \
        if use_cache else int(ckpt_gb * 1e9) * pods * hosts
    return dur, egress


def main():
    t_direct, e_direct = storm(use_cache=False)
    t_cached, e_cached = storm(use_cache=True)
    print(f"direct-to-origin : {t_direct:7.1f}s, origin egress "
          f"{e_direct / 1e12:.2f} TB")
    print(f"through pod cache: {t_cached:7.1f}s, origin egress "
          f"{e_cached / 1e9:.1f} GB")
    print(f"→ storm {t_direct / t_cached:.1f}× faster, origin egress "
          f"{e_direct / e_cached:.0f}× lower")


if __name__ == "__main__":
    main()
