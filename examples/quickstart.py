"""Quickstart: the StashCache federation in 60 seconds.

Everything goes through the unified data plane (`repro.core.api`): you
name data by path, the federation (redirectors → namespace → caches)
resolves and serves it.  The same code runs on the instant *analytic*
engine here; swap `AnalyticPlane` for `SimulatedPlane` (or run a
`ScenarioSpec` with `engine="sim"`) to replay it under link contention.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (AnalyticPlane, FederationSpec, FetchRequest,
                        ScenarioSpec, WorkloadSpec, run_scenario)


def main():
    # Build the paper's OSG deployment (5 sites, HA redirectors, site
    # proxies) and wrap it in a data plane — the only handle you need.
    plane = AnalyticPlane(FederationSpec.osg().build())

    # A researcher publishes a dataset; the namespace routes the path to
    # the origin that exports its prefix (no origin reference held).
    plane.publish("/ligo/frames/L1-GWOSC.gwf", b"\x42" * 5_000_000, mtime=1.0)
    plane.publish("/ligo/frames/big.gwf", 3 * 10 ** 9)  # 3 GB synthetic

    # A job at Nebraska fetches through the federation: cold then warm.
    cold = plane.fetch(FetchRequest("/ligo/frames/L1-GWOSC.gwf",
                                    site="nebraska", worker=0))
    warm = plane.fetch(FetchRequest("/ligo/frames/L1-GWOSC.gwf",
                                    site="nebraska", worker=1))
    print(f"cold fetch: {cold.seconds * 1e3:8.1f} ms "
          f"({cold.cache_misses} chunk misses via {cold.source})")
    print(f"warm fetch: {warm.seconds * 1e3:8.1f} ms "
          f"({warm.cache_hits} chunk hits) "
          f"→ {cold.seconds / warm.seconds:.1f}× faster")

    # Large file: the site proxy refuses to cache it, StashCache doesn't.
    via_proxy = plane.fetch(FetchRequest("/ligo/frames/big.gwf",
                                         site="nebraska", method="proxy"))
    via_stash = plane.fetch(FetchRequest("/ligo/frames/big.gwf",
                                         site="nebraska", method="stash"))
    again = plane.fetch(FetchRequest("/ligo/frames/big.gwf",
                                     site="nebraska", method="proxy"))
    print(f"3 GB via proxy: {via_proxy.seconds:6.1f} s  "
          f"(re-fetch still a hit? {again.cache_hit})")
    print(f"3 GB via stash: {via_stash.seconds:6.1f} s  "
          f"(warm copy now resident at {via_stash.source})")

    # stat() is the namespace-first metadata lookup.
    st = plane.stat("/ligo/frames/big.gwf")
    print(f"stat: {st.size / 1e9:.1f} GB in {st.num_chunks} chunks, "
          f"exported by {st.origin}")

    # The same scenario, declaratively — and on either engine.  A restart
    # storm (every worker pulls the same checkpoint at t=0) on the
    # fluid-flow simulator with max-min link contention:
    spec = ScenarioSpec(
        name="quickstart-storm",
        federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=8),
        workload=WorkloadSpec(kind="storm", path="/ckpt/step1/params",
                              size=int(2e9), workers_per_site=8),
        engine="sim")
    rep = run_scenario(spec)
    print(f"storm ({rep.engine}): {len(rep.results)} pulls in "
          f"{rep.sim_seconds:.1f} s simulated, origin served "
          f"{rep.origin_egress_bytes / 1e9:.0f} GB "
          f"(collapsed from {rep.bytes_moved / 1e9:.0f} GB moved)")

    # Monitoring flowed end-to-end (paper §3.2).
    fed = plane.fed
    print(f"monitoring: {fed.aggregator.records} transfer records, "
          f"usage table {fed.aggregator.usage_table()[:2]}")


if __name__ == "__main__":
    main()
