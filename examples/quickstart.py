"""Quickstart: the StashCache federation in 60 seconds.

Builds the paper's OSG deployment (5 sites, HA redirectors, site proxies),
publishes a dataset at the origin, and shows the three headline behaviours:
cold-miss → warm-hit, the stashcp fallback chain, and proxy vs cache on a
large file.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import build_osg_federation


def main():
    fed = build_osg_federation()
    origin = fed.origins[0]

    # A researcher stages data at their origin (authoritative source).
    data = b"\x42" * 5_000_000
    origin.put_object("/ligo/frames/L1-GWOSC.gwf", data, mtime=1.0)
    origin.put_object("/ligo/frames/big.gwf", 3 * 10 ** 9)  # 3 GB synthetic

    # A job at Nebraska reads through CVMFS: cold then warm.
    client = fed.client("nebraska", worker=0)
    _, cold = client.read("/ligo/frames/L1-GWOSC.gwf")
    client2 = fed.client("nebraska", worker=1)
    _, warm = client2.read("/ligo/frames/L1-GWOSC.gwf")
    print(f"cold read : {cold.seconds * 1e3:8.1f} ms "
          f"({cold.cache_misses} chunk misses)")
    print(f"warm read : {warm.seconds * 1e3:8.1f} ms "
          f"({warm.cache_hits} chunk hits) "
          f"→ {cold.seconds / warm.seconds:.1f}× faster")

    # stashcp fallback chain: no CVMFS, no XRootD → curl still works.
    curl_only = fed.client("syracuse", 0, cvmfs=False, xrootd=False)
    _, st = curl_only.copy("/ligo/frames/L1-GWOSC.gwf")
    print(f"stashcp   : method={st.method} ({st.seconds * 1e3:.1f} ms)")

    # Large file: the site proxy refuses to cache it, StashCache doesn't.
    proxy = fed.proxies["nebraska"]
    meta = origin.meta("/ligo/frames/big.gwf")
    proxy.get_object(client.node.name, meta, now=0.0)
    print(f"proxy cached 3GB? {proxy.resident('/ligo/frames/big.gwf', 0.0)} "
          f"(uncacheable count={proxy.stats.uncacheable})")
    client.copy("/ligo/frames/big.gwf")
    cache = fed.caches["nebraska/cache"]
    print(f"stash cached 3GB? {cache.usage_bytes >= 3e9} "
          f"(cache usage {cache.usage_bytes / 1e9:.1f} GB)")

    # Monitoring flowed end-to-end (paper §3.2).
    print(f"monitoring: {fed.aggregator.records} transfer records, "
          f"usage table {fed.aggregator.usage_table()[:2]}")


if __name__ == "__main__":
    main()
