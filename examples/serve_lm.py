"""Serving example: weights distributed through the federation, then
batched prefill/decode with the ServeEngine.

Weight distribution is the paper's sweet spot — multi-GB objects where
StashCache beats HTTP proxies (Table 3): the first serving host pulls the
checkpoint from the origin and warms the pod cache; the other hosts load
at cache speed.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_fleet_federation
from repro.models import init_lm
from repro.serve import Request, ServeEngine
from repro.train import FederatedCheckpointer


def main():
    cfg = dataclasses.replace(get_config("gemma2-2b", smoke=True),
                              dtype="float32")
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)

    # Publish weights through the write-back cache to the origin.
    fed = build_fleet_federation(num_pods=1, hosts_per_pod=8)
    ck0 = FederatedCheckpointer("serve-demo", fed.writeback("pod0/cache"),
                                fed.client("pod0", 0))
    ck0.save(0, params)
    print(f"published {ck0.stats.leaves} weight objects "
          f"({ck0.stats.save_bytes / 1e6:.1f} MB) to the federation")

    # Eight serving hosts load them; host 0 warms the cache.
    for host in range(2):
        ck = FederatedCheckpointer("serve-demo",
                                   fed.writeback("pod0/cache"),
                                   fed.client("pod0", host))
        loaded, st = ck.restore(0, like=params)
        print(f"host{host}: restored in {st.seconds:.3f}s federation-time, "
              f"misses={st.cache_misses} hits={st.cache_hits}")
    params = loaded

    engine = ServeEngine(cfg, params, batch_size=4, max_seq=96)
    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=8 + i),
                        max_new_tokens=12)
                for i in range(6)]
    done = engine.generate(requests)
    for r in done[:3]:
        print(f"req{r.rid}: prompt_len={len(r.prompt)} → {r.output}")
    print(f"engine: {engine.stats.prefills} prefills, "
          f"{engine.stats.decode_steps} decode steps, "
          f"{engine.stats.tokens_out} tokens out")


if __name__ == "__main__":
    main()
