"""Serving example: weights distributed through the federation's data
plane, then batched prefill/decode with the ServeEngine.

Weight distribution is the paper's sweet spot — multi-GB objects where
StashCache beats HTTP proxies (Table 3): the first serving host pulls the
checkpoint from the origin and warms the pod cache; the other hosts load
at cache speed.  Publish and restore both go through the one
AnalyticPlane (``DataPlane.store`` → write-back cache; ``fetch`` →
cache tier), and every transfer lands in a per-consumer FetchRollup.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import AnalyticPlane, build_fleet_federation
from repro.models import init_lm
from repro.serve import Request, ServeEngine
from repro.train import FederatedCheckpointer


def main():
    cfg = dataclasses.replace(get_config("gemma2-2b", smoke=True),
                              dtype="float32")
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)

    # Publish weights through the plane's write path to the origin.
    fed = build_fleet_federation(num_pods=1, hosts_per_pod=8)
    plane = AnalyticPlane(fed)
    ck0 = FederatedCheckpointer("serve-demo", plane, site="pod0", worker=0)
    ck0.save(0, params)
    print(f"published {ck0.leaves} weight objects "
          f"({ck0.stats.bytes_stored / 1e6:.1f} MB) to the federation")

    # Serving hosts load them through the cache tier; host 0 warms it.
    for host in range(2):
        ck = FederatedCheckpointer("serve-demo", plane,
                                   site="pod0", worker=host)
        loaded, st = ck.restore(0, like=params)
        print(f"host{host}: restored in {st.seconds:.3f}s federation-time, "
              f"misses={st.cache_misses} hits={st.cache_hits}")
    params = loaded

    engine = ServeEngine(cfg, params, batch_size=4, max_seq=96,
                         plane=plane, site="pod0", worker=1)
    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=8 + i),
                        max_new_tokens=12)
                for i in range(6)]
    done = engine.generate(requests)
    for r in done[:3]:
        print(f"req{r.rid}: prompt_len={len(r.prompt)} → {r.output}")
    print(f"engine: {engine.stats.prefills} prefills, "
          f"{engine.stats.decode_steps} decode steps, "
          f"{engine.stats.tokens_out} tokens out")

    # The KV/weight-shard read path: re-fetch a published shard object
    # the way the serving workload does (Zipf-popular model shards).
    shard = "/ckpt/serve-demo/step_00000000/manifest.json"
    res = engine.fetch_shard(shard, method="cvmfs")
    print(f"shard fetch: {res.bytes} B from {res.source or 'local'} "
          f"(hit={res.cache_hit}); serve data-plane hit rate "
          f"{engine.data_stats.hit_rate:.2f}")


if __name__ == "__main__":
    main()
