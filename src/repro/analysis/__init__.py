"""fedlint: repo-native static invariant analysis for the federation stack.

Nearly every hard bug in this repo's history was an *invariant*
violation, not a logic error: ``OutageSchedule`` lacking a usable
``__eq__``/``__hash__`` silently broke federation sharing keys (PR 5),
shared eviction-policy instances cross-contaminated replicas (PR 5),
and engine-parity gaps only surfaced through the expensive 220-trace
differential fuzz (PR 6).  ``fedlint`` turns those invariants into AST
checks that fail in seconds at lint time:

* ``spec-hygiene``      — sharing-key value types must hash like values
* ``jit-purity``        — no host side effects inside jitted functions
* ``parity-surface``    — report counters written by both engines
* ``x64-scoping``       — float64 in kernels/ only under enable_x64
* ``deprecation-hygiene`` — no internal callers of deprecated shims

Run it::

    PYTHONPATH=src python -m repro.analysis --strict src/repro

The runtime companion (``repro.analysis.sanitize``) replays seeded
scenarios twice per engine and checks byte-identical reports; see
``python -m repro.analysis.sanitize``.
"""
from .core import (  # noqa: F401
    Checker,
    ModuleInfo,
    Violation,
    all_rules,
    load_baseline,
    register,
    run_analysis,
)

__all__ = [
    "Checker",
    "ModuleInfo",
    "Violation",
    "all_rules",
    "load_baseline",
    "register",
    "run_analysis",
]
