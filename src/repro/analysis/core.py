"""fedlint framework: rule registry, suppressions, module walking.

The pieces:

* :class:`Violation` — one finding (rule, file, line, message).
* :class:`Checker` — base class; subclasses visit each module's AST
  and/or do a project-wide pass in :meth:`Checker.finalize`.
* :func:`register` — class decorator adding a checker to the registry.
* :func:`run_analysis` — walk ``*.py`` files, parse, run every
  checker, apply inline + baseline suppressions.

Suppression layers (both count as *suppressed*, never deleted — the
JSON output carries them so the CI floor can gate suppression creep):

* inline: ``# fedlint: disable=rule-a,rule-b`` on the flagged line or
  on a comment-only line directly above it;
* baseline: ``fedlint.toml`` ``[[suppress]]`` entries with a required
  ``reason`` (reviewed, justified debt — e.g. analytic-engine fields
  documented as zeroed).

``fedlint.toml`` is parsed by a tiny TOML-subset reader because the
container's Python 3.10 predates :mod:`tomllib`; see
:func:`load_baseline` for the accepted grammar.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

__all__ = [
    "BaselineEntry",
    "Checker",
    "ModuleInfo",
    "Violation",
    "all_rules",
    "load_baseline",
    "register",
    "run_analysis",
]

# `# fedlint: disable=rule-a, rule-b` — the only inline directive.
_DIRECTIVE = re.compile(r"#\s*fedlint:\s*disable=([\w\-, ]+)")


@dataclass(frozen=True)
class Violation:
    """One finding. ``suppressed_by`` names the layer that silenced it
    (``"inline"`` or ``"baseline"``) or is ``None`` when it gates."""

    rule: str
    path: str
    line: int
    message: str
    symbol: str = ""
    suppressed_by: Optional[str] = None

    @property
    def suppressed(self) -> bool:
        return self.suppressed_by is not None

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "symbol": self.symbol,
            "suppressed_by": self.suppressed_by,
        }

    def render(self) -> str:
        tag = f" [suppressed:{self.suppressed_by}]" if self.suppressed else ""
        sym = f" ({self.symbol})" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule}{sym}: " \
               f"{self.message}{tag}"


@dataclass
class ModuleInfo:
    """A parsed source module plus its inline-suppression map."""

    path: Path
    relpath: str
    source: str
    tree: ast.Module
    # line number -> set of rule names disabled on that line
    suppressions: Dict[int, frozenset] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: Path, root: Path) -> "ModuleInfo":
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
        try:
            rel = str(path.relative_to(root))
        except ValueError:
            rel = str(path)
        sup = _parse_suppressions(source)
        # A directive above (or on) a decorator also covers the
        # decorated `class`/`def` line the checkers anchor at.
        for node in ast.walk(tree):
            decs = getattr(node, "decorator_list", None)
            if decs:
                merged = sup.get(node.lineno, frozenset())
                for line in range(decs[0].lineno, node.lineno):
                    merged = merged | sup.get(line, frozenset())
                if merged:
                    sup[node.lineno] = merged
        return cls(path=path, relpath=rel, source=source, tree=tree,
                   suppressions=sup)

    def disabled_rules(self, line: int) -> frozenset:
        """Rules inline-disabled for ``line`` (same line, or a
        comment-only line directly above)."""
        return self.suppressions.get(line, frozenset())


def _parse_suppressions(source: str) -> Dict[int, frozenset]:
    """Map each source line to the rules disabled there.

    A directive on a code line applies to that line.  A directive on a
    comment-only line applies to the next line instead (the idiomatic
    "annotate above" placement), chaining across consecutive
    comment-only lines.
    """
    out: Dict[int, frozenset] = {}
    pending: frozenset = frozenset()
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _DIRECTIVE.search(text)
        rules = frozenset(
            r.strip() for r in m.group(1).split(",") if r.strip()
        ) if m else frozenset()
        stripped = text.strip()
        if stripped.startswith("#"):
            pending = pending | rules
            continue
        if not stripped:
            pending = frozenset()
            continue
        here = rules | pending
        pending = frozenset()
        if here:
            out[lineno] = here
    return out


@dataclass(frozen=True)
class BaselineEntry:
    """One reviewed ``[[suppress]]`` entry from ``fedlint.toml``."""

    rule: str
    file: str
    reason: str
    symbol: str = ""

    def matches(self, v: Violation) -> bool:
        if self.rule != v.rule:
            return False
        if Path(v.path).as_posix() != Path(self.file).as_posix() \
                and not Path(v.path).as_posix().endswith(
                    "/" + Path(self.file).as_posix()):
            return False
        if self.symbol and self.symbol != v.symbol:
            return False
        return True


def load_baseline(path: Path) -> List[BaselineEntry]:
    """Parse the ``fedlint.toml`` baseline-suppression file.

    Python 3.10 has no :mod:`tomllib`, so this reads the narrow subset
    the file actually uses: ``[[suppress]]`` table headers followed by
    ``key = "string value"`` pairs.  Anything else (nesting, arrays,
    multi-line strings) is a parse error — the baseline should stay
    simple enough to review by eye.
    """
    entries: List[BaselineEntry] = []
    current: Optional[Dict[str, str]] = None

    def flush() -> None:
        nonlocal current
        if current is None:
            return
        missing = {"rule", "file", "reason"} - set(current)
        if missing:
            raise ValueError(
                f"{path}: [[suppress]] entry missing {sorted(missing)}: "
                f"{current}")
        if not current["reason"].strip():
            raise ValueError(
                f"{path}: [[suppress]] for {current['rule']} in "
                f"{current['file']} has an empty reason — every baseline "
                f"suppression must be justified")
        entries.append(BaselineEntry(
            rule=current["rule"], file=current["file"],
            reason=current["reason"], symbol=current.get("symbol", "")))
        current = None

    for lineno, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[suppress]]":
            flush()
            current = {}
            continue
        m = re.fullmatch(r'(\w+)\s*=\s*"((?:[^"\\]|\\.)*)"\s*(?:#.*)?', line)
        if m and current is not None:
            current[m.group(1)] = m.group(2).replace('\\"', '"')
            continue
        raise ValueError(f"{path}:{lineno}: unparseable line {raw!r} "
                         f"(fedlint.toml supports only [[suppress]] tables "
                         f"of string keys)")
    flush()
    return entries


class Checker:
    """Base class for fedlint rules.

    Subclasses set :attr:`rule` (the suppression name) and
    :attr:`description`, then override :meth:`check_module` for
    per-file findings and/or :meth:`finalize` for project-wide ones
    (e.g. parity-surface, which needs writes from *several* files
    before it can call a field single-sided).
    """

    rule: str = ""
    description: str = ""

    def check_module(self, mod: ModuleInfo) -> Iterable[Violation]:
        return ()

    def finalize(self) -> Iterable[Violation]:
        return ()

    # helper for subclasses
    def violation(self, mod: ModuleInfo, node: ast.AST, message: str,
                  symbol: str = "") -> Violation:
        return Violation(rule=self.rule, path=mod.relpath,
                         line=getattr(node, "lineno", 0), message=message,
                         symbol=symbol)


_REGISTRY: Dict[str, Type[Checker]] = {}


def register(cls: Type[Checker]) -> Type[Checker]:
    """Class decorator adding a checker to the global registry."""
    if not cls.rule:
        raise ValueError(f"{cls.__name__} has no rule name")
    if cls.rule in _REGISTRY:
        raise ValueError(f"duplicate fedlint rule {cls.rule!r}")
    _REGISTRY[cls.rule] = cls
    return cls


def all_rules() -> Dict[str, Type[Checker]]:
    # Import for the registration side effect; cheap and idempotent.
    from . import checkers  # noqa: F401
    return dict(_REGISTRY)


def _iter_sources(targets: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for t in targets:
        if t.is_dir():
            files.extend(sorted(p for p in t.rglob("*.py")
                                if "__pycache__" not in p.parts))
        elif t.suffix == ".py":
            files.append(t)
    return files


def run_analysis(
    targets: Sequence[Path],
    root: Optional[Path] = None,
    rules: Optional[Sequence[str]] = None,
    baseline: Optional[Path] = None,
) -> Tuple[List[Violation], List[BaselineEntry]]:
    """Run the selected checkers over ``targets``.

    Returns ``(violations, baseline_entries)`` — violations carry
    their suppression state; callers decide what gates (``--strict``
    fails on any unsuppressed finding).
    """
    root = root or Path.cwd()
    registry = all_rules()
    names = list(rules) if rules else sorted(registry)
    unknown = sorted(set(names) - set(registry))
    if unknown:
        raise ValueError(f"unknown fedlint rule(s): {', '.join(unknown)}; "
                         f"known: {', '.join(sorted(registry))}")
    checkers = [registry[n]() for n in names]

    modules: List[ModuleInfo] = []
    findings: List[Violation] = []
    for path in _iter_sources([Path(t) for t in targets]):
        try:
            mod = ModuleInfo.parse(path, root)
        except SyntaxError as exc:
            findings.append(Violation(
                rule="parse-error", path=str(path),
                line=exc.lineno or 0,
                message=f"could not parse: {exc.msg}"))
            continue
        modules.append(mod)

    per_module: Dict[str, ModuleInfo] = {m.relpath: m for m in modules}
    for checker in checkers:
        for mod in modules:
            findings.extend(checker.check_module(mod))
        findings.extend(checker.finalize())

    entries = load_baseline(baseline) if baseline and baseline.exists() \
        else []
    out: List[Violation] = []
    for v in findings:
        mod = per_module.get(v.path)
        if mod is not None and v.rule in mod.disabled_rules(v.line):
            v = Violation(**{**v.__dict__, "suppressed_by": "inline"})
        elif any(e.matches(v) for e in entries):
            v = Violation(**{**v.__dict__, "suppressed_by": "baseline"})
        out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out, entries


def report_json(violations: List[Violation],
                entries: List[BaselineEntry]) -> str:
    active = [v for v in violations if not v.suppressed]
    return json.dumps({
        "violations": [v.to_json() for v in violations],
        "counts": {
            "total": len(violations),
            "active": len(active),
            "suppressed_inline": sum(
                1 for v in violations if v.suppressed_by == "inline"),
            "suppressed_baseline": sum(
                1 for v in violations if v.suppressed_by == "baseline"),
            "baseline_entries": len(entries),
        },
    }, indent=2, sort_keys=True)
