"""CLI entry point: ``python -m repro.analysis [--strict] [paths...]``.

Exit codes: 0 clean (or only suppressed findings), 1 unsuppressed
violations under ``--strict``, 2 usage/parse errors.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from .core import all_rules, report_json, run_analysis


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="fedlint: static invariant analysis for the "
                    "federation stack")
    ap.add_argument("targets", nargs="*", default=["src/repro"],
                    help="files or directories to analyze "
                         "(default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unsuppressed violation")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit machine-readable JSON instead of the "
                         "human listing")
    ap.add_argument("--rule", action="append", dest="rules", default=None,
                    metavar="RULE",
                    help="run only this rule (repeatable)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline suppression file "
                         "(default: fedlint.toml next to the first "
                         "target's repo root, if present)")
    ap.add_argument("--list-rules", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, cls in sorted(all_rules().items()):
            print(f"{name:<22} {cls.description}")
        return 0

    targets = [Path(t) for t in (args.targets or ["src/repro"])]
    for t in targets:
        if not t.exists():
            print(f"repro.analysis: no such path: {t}", file=sys.stderr)
            return 2

    baseline = args.baseline
    if baseline is None:
        # walk up from the first target looking for fedlint.toml
        probe = targets[0].resolve()
        for parent in [probe] + list(probe.parents):
            cand = parent / "fedlint.toml"
            if cand.exists():
                baseline = cand
                break

    try:
        violations, entries = run_analysis(
            targets, root=Path.cwd(), rules=args.rules, baseline=baseline)
    except ValueError as exc:
        print(f"repro.analysis: {exc}", file=sys.stderr)
        return 2

    active = [v for v in violations if not v.suppressed]
    if args.as_json:
        print(report_json(violations, entries))
    else:
        for v in violations:
            print(v.render())
        n_sup = len(violations) - len(active)
        print(f"fedlint: {len(active)} violation(s), "
              f"{n_sup} suppressed"
              + (f" (baseline: {baseline})" if baseline else ""))
    if args.strict and active:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
