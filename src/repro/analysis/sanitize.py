"""Determinism sanitizer: replay-twice byte equality for both engines.

The static checkers (``repro.analysis.checkers``) prove the *code*
keeps its invariants; this module proves the *runtime* does.  Three
properties, all cheap enough for per-push CI:

1. **Double replay** — the same seeded :class:`ScenarioSpec` executed
   twice on the same engine must produce byte-identical reports
   including the per-request result rows *in order* (the event
   ordering of the run).  Any drift means hidden global state: an
   unseeded RNG, a shared mutable default, dict-order dependence.

2. **Engine coverage** — property 1 holds on both the analytic and
   the simulated engine, for a scenario family that exercises storms,
   Zipf traces, and outage schedules.

3. **Insertion-order independence** — the fluid-flow simulator
   coalesces same-timestamp events into one waterfill solve (PR 2);
   that coalescing must not depend on the order the events were
   *inserted*.  We materialize a same-timestamp storm workload,
   shuffle the request list with a seeded RNG, and require the
   canonical (order-normalized) report — totals, ``sim_seconds``,
   solver telemetry, and every per-request row keyed by identity — to
   be byte-identical to the unshuffled run.

Run it::

    PYTHONPATH=src python -m repro.analysis.sanitize          # full
    PYTHONPATH=src python -m repro.analysis.sanitize --quick  # CI smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import random
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import (
    FederationSpec,
    OutageSchedule,
    ScenarioSpec,
    WorkloadSpec,
    run_scenario,
)

__all__ = [
    "SanitizeFailure",
    "canonical_report_bytes",
    "check_double_replay",
    "check_shuffled_insertion",
    "default_specs",
    "run_sanitizer",
]


class SanitizeFailure(AssertionError):
    """A determinism property failed; the message carries the first
    differing field so the drift is debuggable without a bisect."""


def _encode(obj) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str).encode()


def canonical_report_bytes(rep, ordered: bool = True) -> bytes:
    """Serialize a :class:`ScenarioReport` deterministically.

    ``ordered=True`` keeps the per-request rows in execution order —
    the event ordering of the run, which double replay must reproduce
    exactly.  ``ordered=False`` sorts rows by request identity
    (path, site, worker, start time) for comparisons across runs that
    legitimately permute *insertion* order.
    """
    d = dataclasses.asdict(rep)
    rows = d.pop("results")
    if not ordered:
        rows = sorted(rows, key=lambda r: _encode(r))
    d["results"] = rows
    return _encode(d)


def _first_diff(a: bytes, b: bytes) -> str:
    if len(a) != len(b):
        note = f"lengths differ ({len(a)} vs {len(b)}); "
    else:
        note = ""
    for i, (x, y) in enumerate(zip(a, b)):
        if x != y:
            lo, hi = max(0, i - 60), i + 60
            return (f"{note}first divergence at byte {i}: "
                    f"...{a[lo:hi]!r} vs ...{b[lo:hi]!r}")
    return note + "one serialization is a prefix of the other"


def check_double_replay(spec: ScenarioSpec) -> Dict[str, int]:
    """Run ``spec`` twice from scratch; byte-identical or raise."""
    rep1 = run_scenario(spec)
    rep2 = run_scenario(spec)
    b1 = canonical_report_bytes(rep1, ordered=True)
    b2 = canonical_report_bytes(rep2, ordered=True)
    if b1 != b2:
        raise SanitizeFailure(
            f"double replay of {spec.name!r} on engine={spec.engine!r} "
            f"diverged — hidden global state in the {spec.engine} path: "
            f"{_first_diff(b1, b2)}")
    return {"requests": len(rep1.results), "bytes": len(b1)}


def check_shuffled_insertion(spec: ScenarioSpec, seed: int = 0,
                             rounds: int = 3) -> Dict[str, int]:
    """Same-timestamp insertion-order independence on the simulator.

    Materializes the spec's workload into an explicit request list,
    then runs ``rounds`` seeded shuffles of that list and requires the
    order-normalized report bytes to match the unshuffled run — the
    coalesced solve must not care who arrived first *in the queue*
    when everyone arrived at the same simulated instant.
    """
    if spec.engine != "sim":
        raise ValueError("shuffled-insertion check drives the simulator; "
                         f"got engine={spec.engine!r}")
    fed = spec.federation.build()
    reqs = spec.requests(fed)
    stamps = {r.at for r in reqs}
    if len(stamps) >= len(reqs):
        raise ValueError(
            f"workload of {spec.name!r} has no same-timestamp requests "
            f"({len(reqs)} requests, {len(stamps)} distinct timestamps) — "
            f"the shuffle would prove nothing")
    base_spec = dataclasses.replace(spec, workload=tuple(reqs))
    want = canonical_report_bytes(run_scenario(base_spec), ordered=False)
    rng = random.Random(seed)
    for rnd in range(rounds):
        shuffled = list(reqs)
        rng.shuffle(shuffled)
        got = canonical_report_bytes(
            run_scenario(dataclasses.replace(spec,
                                             workload=tuple(shuffled))),
            ordered=False)
        if got != want:
            raise SanitizeFailure(
                f"shuffled insertion round {rnd} of {spec.name!r} "
                f"diverged — same-timestamp coalescing is insertion-"
                f"order dependent: {_first_diff(want, got)}")
    return {"requests": len(reqs), "rounds": rounds,
            "timestamps": len(stamps)}


def default_specs(quick: bool = False) -> List[ScenarioSpec]:
    """The sanitized scenario family: storm (same-timestamp fan-in),
    Zipf trace (seeded randomness), storm+outages (coalescing under a
    schedule) — each on both engines."""
    pods, hosts, n_req = (1, 4, 40) if quick else (2, 8, 160)
    fed = FederationSpec.fleet(num_pods=pods, hosts_per_pod=hosts)
    caches = [f"pod{p}/cache" for p in range(pods)]
    storm = WorkloadSpec(kind="storm", path="/ckpt/step/params",
                         size=int(2e8), workers_per_site=hosts)
    zipf = WorkloadSpec(kind="zipf", n_requests=n_req, working_set=16,
                        seed=7)
    specs: List[ScenarioSpec] = []
    for engine in ("analytic", "sim"):
        specs.append(ScenarioSpec(name="sanitize-storm", federation=fed,
                                  workload=storm, engine=engine))
        specs.append(ScenarioSpec(name="sanitize-zipf", federation=fed,
                                  workload=zipf, engine=engine))
        specs.append(ScenarioSpec(
            name="sanitize-storm-outage", federation=fed, workload=storm,
            engine=engine,
            outages=OutageSchedule.restart_storm(caches, at=5.0,
                                                 downtime=10.0)))
    return specs


def run_sanitizer(quick: bool = False,
                  specs: Optional[Sequence[ScenarioSpec]] = None
                  ) -> List[Tuple[str, str, Dict[str, int]]]:
    """Run every check; returns ``(check, scenario, stats)`` rows or
    raises :class:`SanitizeFailure` on the first drift."""
    rows: List[Tuple[str, str, Dict[str, int]]] = []
    for spec in (specs if specs is not None else default_specs(quick)):
        stats = check_double_replay(spec)
        rows.append(("double-replay", f"{spec.name}/{spec.engine}", stats))
        if spec.engine == "sim" and spec.outages is None \
                and isinstance(spec.workload, WorkloadSpec) \
                and spec.workload.kind == "storm":
            stats = check_shuffled_insertion(spec, seed=13,
                                             rounds=2 if quick else 4)
            rows.append(("shuffled-insertion", spec.name, stats))
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.sanitize",
        description="determinism sanitizer: double-replay byte equality "
                    "on both engines + shuffled same-timestamp insertion")
    ap.add_argument("--quick", action="store_true",
                    help="small federation / short traces (CI smoke)")
    args = ap.parse_args(argv)
    try:
        rows = run_sanitizer(quick=args.quick)
    except SanitizeFailure as exc:
        print(f"FAIL: {exc}", file=sys.stderr)
        return 1
    for check, scenario, stats in rows:
        detail = ", ".join(f"{k}={v}" for k, v in stats.items())
        print(f"ok {check:<20} {scenario:<28} {detail}")
    print(f"sanitizer: {len(rows)} determinism checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
