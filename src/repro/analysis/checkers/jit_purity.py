"""jit-purity: no host side effects inside traced functions.

``jax.jit`` traces a function once and replays the compiled
computation; host side effects inside the traced body execute at trace
time only (or never again), so a ``time.time()``, an unseeded
``random``/``np.random`` draw, ``print``, file I/O, or ``global``/
``nonlocal`` mutation there is almost always a bug — the value is
frozen into the compiled graph and every later call silently reuses
it.  This rule finds every function that flows into ``jax.jit`` /
``jax.vmap`` / ``jax.pmap`` / ``jax.lax.scan`` (decorators, including
``functools.partial(jax.jit, ...)``; direct calls; lambdas) and flags
host-effect calls in its body, walking one call level deep into
same-module helpers.

Seeded constructors are allowed: ``np.random.default_rng(seed)`` /
``random.Random(seed)`` with an argument are deterministic factories,
not hidden global-state draws.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..core import Checker, ModuleInfo, Violation, register

# dotted-call suffixes that are host effects inside a traced function
_EFFECT_CALLS = {
    "time.time": "reads the host clock at trace time",
    "time.perf_counter": "reads the host clock at trace time",
    "time.monotonic": "reads the host clock at trace time",
    "time.sleep": "blocks the host at trace time only",
    "datetime.now": "reads the host clock at trace time",
    "os.urandom": "draws host entropy at trace time",
}
# bare names that are host effects
_EFFECT_NAMES = {
    "print": "prints at trace time only, then never again",
    "open": "performs file I/O at trace time",
    "input": "blocks on host input at trace time",
}
# random-module draw functions (unseeded global state)
_RANDOM_DRAWS = {
    "random", "randint", "randrange", "uniform", "gauss", "normal",
    "choice", "shuffle", "sample", "rand", "randn", "random_sample",
    "permutation",
}
_JIT_ENTRY_SUFFIXES = ("jit", "vmap", "pmap")
_SCAN_SUFFIXES = ("scan", "fori_loop", "while_loop", "cond", "map")


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted name for a call target ('jax.lax.scan')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_jit_entry(call_target: ast.expr) -> bool:
    name = _dotted(call_target)
    if not name:
        return False
    last = name.split(".")[-1]
    if last in _JIT_ENTRY_SUFFIXES:
        return True
    # jax.lax.scan / lax.scan / lax.fori_loop etc.
    if last in _SCAN_SUFFIXES and ("lax" in name.split(".")
                                   or name.startswith("jax.")):
        return True
    return False


def _partial_jit(call: ast.Call) -> bool:
    """functools.partial(jax.jit, static_argnames=...) used as decorator."""
    if _dotted(call.func).split(".")[-1] != "partial":
        return False
    return bool(call.args) and _is_jit_entry(call.args[0])


@register
class JitPurityChecker(Checker):
    rule = "jit-purity"
    description = ("no host side effects (clock, unseeded random, I/O, "
                   "print, global mutation) reachable inside jitted "
                   "functions, one call level deep")

    def check_module(self, mod: ModuleInfo) -> Iterable[Violation]:
        defs: Dict[str, ast.AST] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, node)

        roots: List[Tuple[ast.AST, str]] = []  # (func node, how traced)
        seen: Set[int] = set()

        def add_root(fn: Optional[ast.AST], how: str) -> None:
            if fn is not None and id(fn) not in seen:
                seen.add(id(fn))
                roots.append((fn, how))

        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    if _is_jit_entry(target) or (
                            isinstance(dec, ast.Call) and _partial_jit(dec)):
                        add_root(node, _dotted(target) or "jit")
            if isinstance(node, ast.Call) and _is_jit_entry(node.func):
                how = _dotted(node.func)
                for arg in node.args:
                    if isinstance(arg, ast.Lambda):
                        add_root(arg, how)
                    elif isinstance(arg, ast.Name) and arg.id in defs:
                        add_root(defs[arg.id], how)
                    elif isinstance(arg, ast.Attribute) \
                            and isinstance(arg.value, ast.Name) \
                            and arg.value.id == "self" \
                            and "_" + arg.attr in defs:
                        pass  # method refs resolved below by bare name
                # self._method / cls._method references
                for arg in node.args:
                    if isinstance(arg, ast.Attribute) \
                            and arg.attr in defs:
                        add_root(defs[arg.attr], how)

        out: List[Violation] = []
        for fn, how in roots:
            out.extend(self._check_body(mod, fn, how, defs, depth=0))
        return out

    def _check_body(self, mod: ModuleInfo, fn: ast.AST, how: str,
                    defs: Dict[str, ast.AST], depth: int,
                    _visited: Optional[Set[int]] = None
                    ) -> Iterable[Violation]:
        visited = _visited if _visited is not None else set()
        if id(fn) in visited:
            return []
        visited.add(id(fn))
        out: List[Violation] = []
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        label = getattr(fn, "name", "<lambda>")

        for node in ast.walk(ast.Module(body=body, type_ignores=[])):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                out.append(self.violation(
                    mod, node,
                    f"{label} (traced via {how}) mutates "
                    f"{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                    f" state {', '.join(node.names)} — the mutation runs at "
                    f"trace time only", symbol=label))
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            last = name.split(".")[-1] if name else ""
            if name in _EFFECT_NAMES and isinstance(node.func, ast.Name):
                out.append(self.violation(
                    mod, node,
                    f"{label} (traced via {how}) calls {name}() which "
                    f"{_EFFECT_NAMES[name]}", symbol=label))
                continue
            for suffix, why in _EFFECT_CALLS.items():
                if name == suffix or name.endswith("." + suffix):
                    out.append(self.violation(
                        mod, node,
                        f"{label} (traced via {how}) calls {name}() which "
                        f"{why}", symbol=label))
                    break
            else:
                if last in _RANDOM_DRAWS and name and (
                        name.startswith("random.")
                        or ".random." in name
                        or name.startswith("np.random")
                        or name.startswith("numpy.random")):
                    out.append(self.violation(
                        mod, node,
                        f"{label} (traced via {how}) draws from unseeded "
                        f"global randomness {name}() — use jax.random with "
                        f"an explicit key", symbol=label))
                elif last in ("Random", "default_rng", "seed") \
                        and not node.args and not node.keywords \
                        and ("random" in name):
                    out.append(self.violation(
                        mod, node,
                        f"{label} (traced via {how}) constructs {name}() "
                        f"without a seed — trace-time entropy makes the "
                        f"compiled function nondeterministic",
                        symbol=label))
                elif depth == 0 and isinstance(node.func, ast.Name) \
                        and node.func.id in defs:
                    # walk one call level deep into same-module helpers
                    out.extend(self._check_body(
                        mod, defs[node.func.id], f"{how} via {label}",
                        defs, depth=1, _visited=visited))
        return out
