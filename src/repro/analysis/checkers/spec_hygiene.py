"""spec-hygiene: sharing-key value types must behave like values.

Federation sharing (``_SharedFederations``) and sweep-axis dedup
compare ``*Spec`` objects with ``==``; a spec that is mutable, or that
defines ``__eq__`` without ``__hash__`` (Python then sets
``__hash__ = None``), silently breaks those keys — the exact PR 5
``OutageSchedule`` bug.  For every class whose name ends in ``Spec``
or ``Schedule`` this rule requires one of:

* ``@dataclass(frozen=True)`` (eq/hash generated consistently), or
* an explicit ``__eq__`` **and** a real ``__hash__`` (``__hash__ =
  None`` does not count: unhashable specs cannot move to set/dict
  sharing keys later).

Additionally, *mutable defaults* are flagged everywhere they can
cross-contaminate instances: ``field(default_factory=list)`` is fine,
but a class-level ``x = []`` / ``= {}`` / ``= set()`` literal, or a
dataclass default that is a shared mutable instance, is an error (the
PR 5 shared-eviction-policy bug generalized).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from ..core import Checker, ModuleInfo, Violation, register

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)
# constructor-call defaults that are fine to share across instances
_IMMUTABLE_CALLS = {"tuple", "frozenset", "field"}


def _is_spec_class(node: ast.ClassDef) -> bool:
    return node.name.endswith("Spec") or node.name.endswith("Schedule")


def _dataclass_decorator(node: ast.ClassDef) -> Optional[ast.expr]:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = None
        if isinstance(target, ast.Name):
            name = target.id
        elif isinstance(target, ast.Attribute):
            name = target.attr
        if name == "dataclass":
            return dec
    return None


def _dataclass_is_frozen(dec: ast.expr) -> bool:
    if not isinstance(dec, ast.Call):
        return False
    for kw in dec.keywords:
        if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


def _dataclass_eq_disabled(dec: ast.expr) -> bool:
    if not isinstance(dec, ast.Call):
        return False
    for kw in dec.keywords:
        if kw.arg == "eq" and isinstance(kw.value, ast.Constant):
            return not kw.value.value
    return False


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


@register
class SpecHygieneChecker(Checker):
    rule = "spec-hygiene"
    description = ("*Spec/*Schedule classes must be frozen dataclasses or "
                   "define consistent __eq__/__hash__; no mutable defaults")

    def check_module(self, mod: ModuleInfo) -> Iterable[Violation]:
        out: List[Violation] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and _is_spec_class(node):
                out.extend(self._check_class(mod, node))
        return out

    def _check_class(self, mod: ModuleInfo,
                     node: ast.ClassDef) -> Iterable[Violation]:
        out: List[Violation] = []
        dec = _dataclass_decorator(node)
        frozen = dec is not None and _dataclass_is_frozen(dec)

        methods = {n.name for n in node.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        hash_assigned_none = False
        hash_assigned_real = False
        for stmt in node.body:
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == "__hash__":
                        if isinstance(stmt.value, ast.Constant) \
                                and stmt.value.value is None:
                            hash_assigned_none = True
                        else:
                            hash_assigned_real = True
        has_eq = "__eq__" in methods or (
            dec is not None and not _dataclass_eq_disabled(dec))
        has_hash = ("__hash__" in methods or hash_assigned_real
                    or frozen)

        if not frozen:
            if "__eq__" in methods and not has_hash:
                msg = ("defines __eq__ without a usable __hash__ "
                       + ("(__hash__ = None makes it unhashable) "
                          if hash_assigned_none else "")
                       + "— sharing-key lookups that move to dict/set "
                         "keys will break; freeze the class or add a "
                         "__hash__ consistent with __eq__")
                out.append(self.violation(mod, node, msg, symbol=node.name))
            elif dec is not None and not has_hash:
                # plain @dataclass: __eq__ generated, __hash__ set to None
                out.append(self.violation(
                    mod, node,
                    "non-frozen dataclass generates __eq__ but sets "
                    "__hash__ = None; use @dataclass(frozen=True) so the "
                    "spec is a true value type for federation sharing "
                    "keys and sweep axes", symbol=node.name))
            elif dec is None and not has_eq:
                out.append(self.violation(
                    mod, node,
                    "plain class with neither dataclass machinery nor "
                    "__eq__ — sharing-key comparison falls back to "
                    "identity, so equal specs will not share a "
                    "federation", symbol=node.name))

        # mutable defaults: class-level literals and shared call instances
        for stmt in node.body:
            target_name, value = None, None
            if isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                    and isinstance(stmt.target, ast.Name):
                target_name, value = stmt.target.id, stmt.value
            elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                target_name, value = stmt.targets[0].id, stmt.value
            if value is None or target_name is None \
                    or target_name.startswith("__"):
                continue
            if isinstance(value, _MUTABLE_LITERALS):
                out.append(self.violation(
                    mod, value,
                    f"field {target_name!r} has a mutable literal default "
                    f"shared by every instance; use "
                    f"field(default_factory=...) or a tuple",
                    symbol=node.name))
            elif dec is not None and isinstance(value, ast.Call):
                name = _call_name(value)
                if name and name not in _IMMUTABLE_CALLS \
                        and name[0].isupper():
                    # Uppercase call = constructing an instance shared by
                    # every spec (the PR 5 shared-policy bug shape).
                    out.append(self.violation(
                        mod, value,
                        f"field {target_name!r} defaults to a shared "
                        f"{name}() instance; every spec will alias one "
                        f"object — use field(default_factory={name})",
                        symbol=node.name))
        return out
