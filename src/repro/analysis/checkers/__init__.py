"""fedlint checkers — importing this package registers every rule."""
from . import (  # noqa: F401
    deprecation,
    jit_purity,
    parity_surface,
    spec_hygiene,
    x64_scoping,
)
