"""deprecation-hygiene: shims warn properly and stay external-only.

PR 9 kept the legacy client/writeback call sites alive behind
``ClientPlane`` deprecation shims in ``core/api.py``.  Two invariants
keep that debt from re-rooting:

* No *internal* call site constructs ``ClientPlane`` — the shim exists
  for out-of-tree callers.  The only in-tree functions allowed to
  touch it are the compat fallbacks that themselves emit a
  ``DeprecationWarning`` (the shims in ``data/loader.py`` /
  ``train/checkpoint.py``), plus its defining module and tests.
* Every ``DeprecationWarning`` is raised with ``stacklevel>=2`` so the
  warning points at the *caller*, not at the shim's own line —
  a stacklevel-1 warning is undebuggable noise.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..core import Checker, ModuleInfo, Violation, register

DEPRECATED_NAMES = ("ClientPlane",)
# modules allowed to reference the shim freely
_DEFINING_SUFFIXES = ("core/api.py", "core/__init__.py")


def _dotted(node: ast.expr) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _emits_deprecation_warning(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) \
                and _dotted(node.func).split(".")[-1] == "warn":
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if "DeprecationWarning" in _dotted(arg):
                    return True
    return False


@register
class DeprecationHygieneChecker(Checker):
    rule = "deprecation-hygiene"
    description = ("no internal ClientPlane shim call sites outside the "
                   "compat fallbacks; DeprecationWarning needs "
                   "stacklevel>=2")

    def check_module(self, mod: ModuleInfo) -> Iterable[Violation]:
        p = mod.relpath.replace("\\", "/")
        out: List[Violation] = []
        is_test = "/tests/" in f"/{p}" or p.startswith("tests/") \
            or p.split("/")[-1].startswith("test_")
        is_defining = any(p.endswith(s) for s in _DEFINING_SUFFIXES)

        # map each node id to its innermost enclosing function
        enclosing = {}
        for fn in ast.walk(mod.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(fn):
                    enclosing[id(sub)] = fn  # innermost wins (walk order)

        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            last = name.split(".")[-1]
            # stacklevel audit applies everywhere, tests included
            if last == "warn":
                is_dep = any(
                    "DeprecationWarning" in _dotted(a)
                    for a in list(node.args)
                    + [k.value for k in node.keywords])
                if is_dep:
                    level = None
                    if len(node.args) >= 3 and isinstance(
                            node.args[2], ast.Constant):
                        level = node.args[2].value
                    for kw in node.keywords:
                        if kw.arg == "stacklevel" \
                                and isinstance(kw.value, ast.Constant):
                            level = kw.value.value
                    if not isinstance(level, int) or level < 2:
                        out.append(self.violation(
                            mod, node,
                            "DeprecationWarning raised with "
                            f"stacklevel={level!r} — must be >=2 so the "
                            "warning points at the caller, not the shim"))
                continue
            if is_test or is_defining:
                continue
            if last in DEPRECATED_NAMES:
                fn = enclosing.get(id(node))
                if fn is not None and _emits_deprecation_warning(fn):
                    continue  # this IS a compat shim: it warns
                where = f" in {fn.name}()" if fn is not None else ""
                out.append(self.violation(
                    mod, node,
                    f"internal call site constructs deprecated {last}"
                    f"{where} without emitting a DeprecationWarning — "
                    f"route through DataPlane.for_federation instead of "
                    f"the PR 9 compat shim",
                    symbol=getattr(fn, "name", "")))
        return out
