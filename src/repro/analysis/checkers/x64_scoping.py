"""x64-scoping: float64 in kernels/ only under scoped ``enable_x64``.

PR 5's convention: JAX runs in float32 by default, and the exact
eviction kernels that need double precision (stack-distance ties,
byte-exact eviction accounting) opt in with the *scoped*
``jax.experimental.enable_x64()`` context manager — never the global
``jax.config.update("jax_enable_x64", ...)`` switch, which would flip
precision (and recompile) for every other kernel in the process.  This
rule flags, in ``kernels/`` modules only:

* any *JAX* ``float64`` dtype reference (``jnp.float64``,
  ``jax.numpy.float64``, or a ``dtype="float64"`` string) outside the
  lexical body of a ``with enable_x64():`` block — host-side
  ``np.float64`` is exempt, numpy is always 64-bit capable;
* any ``config.update("jax_enable_x64", ...)`` global flip, anywhere.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Tuple

from ..core import Checker, ModuleInfo, Violation, register


def _dotted(node: ast.expr) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _x64_spans(tree: ast.Module) -> List[Tuple[int, int]]:
    """Line spans of ``with enable_x64():`` bodies."""
    spans: List[Tuple[int, int]] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        for item in node.items:
            expr = item.context_expr
            target = expr.func if isinstance(expr, ast.Call) else expr
            if _dotted(target).split(".")[-1] == "enable_x64":
                end = node.end_lineno or node.lineno
                spans.append((node.lineno, end))
                break
    return spans


@register
class X64ScopingChecker(Checker):
    rule = "x64-scoping"
    description = ("float64 dtype use in kernels/ only inside scoped "
                   "'with enable_x64():' blocks; no global x64 flips")

    def check_module(self, mod: ModuleInfo) -> Iterable[Violation]:
        p = mod.relpath.replace("\\", "/")
        if "/kernels/" not in p and not p.startswith("kernels/"):
            return ()
        spans = _x64_spans(mod.tree)

        def scoped(line: int) -> bool:
            return any(lo <= line <= hi for lo, hi in spans)

        out: List[Violation] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr == "float64":
                root = _dotted(node).split(".")[0]
                # host numpy is exempt; only JAX dtypes need enable_x64
                if root in ("np", "numpy"):
                    continue
                if not scoped(node.lineno):
                    out.append(self.violation(
                        mod, node,
                        f"{_dotted(node)} outside a scoped "
                        f"'with enable_x64():' block — under the default "
                        f"float32 config this silently truncates to f32 "
                        f"(or requires a global flip); wrap the use in "
                        f"the scoped context manager"))
            elif isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name.split(".")[-1] == "update" and node.args \
                        and isinstance(node.args[0], ast.Constant) \
                        and node.args[0].value == "jax_enable_x64":
                    out.append(self.violation(
                        mod, node,
                        "global jax_enable_x64 config flip — this "
                        "recompiles and changes precision for every "
                        "kernel in the process; use the scoped "
                        "jax.experimental.enable_x64() context manager"))
                elif isinstance(node, ast.Call):
                    for kw in node.keywords:
                        if kw.arg == "dtype" \
                                and isinstance(kw.value, ast.Constant) \
                                and kw.value.value == "float64" \
                                and not scoped(kw.value.lineno):
                            out.append(self.violation(
                                mod, kw.value,
                                "dtype=\"float64\" outside a scoped "
                                "'with enable_x64():' block"))
        return out
