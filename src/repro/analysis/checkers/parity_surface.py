"""parity-surface: every counter must be written by *both* engines.

The repo's core guarantee is analytic-vs-simulated engine parity on
the accounting surface (bytes, hits, egress, per-tier counters).  A
counter field declared on one of the report/stats classes but assigned
in only one engine path is a latent parity gap: the differential fuzz
(PR 6) eventually finds it, hours later.  This rule finds it at lint
time.

Mechanics: collect the numeric (``int``/``float``-annotated) fields
declared on the target classes, then collect every assignment to a
matching attribute name — plain writes (``r.outages = n``), augmented
writes (``stats.bytes += n``) and constructor keywords
(``ScenarioReport(bytes_moved=...)``) — partitioned into the analytic
file set (``core/api.py``, ``core/client.py``), the simulated file set
(``core/simclient.py``, ``core/simulator.py``), and shared modules
(everything else, e.g. ``core/ring.py``; a shared write counts for
both engines because both route through it).  A field with writes in
one engine set but not the other is a violation, anchored at the field
declaration.

Matching is by attribute *name*, not by tracked type — field names on
these classes are distinctive enough (``bytes_moved``,
``origin_egress_bytes``) that name-matching is the right
cost/precision trade for a repo-native linter.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, Iterable, List, Set, Tuple

from ..core import Checker, ModuleInfo, Violation, register

TARGET_CLASSES = ("ScenarioReport", "TransferStats", "GroupStats",
                  "FetchRollup", "CacheUsagePacket")
ANALYTIC_FILES = ("core/api.py", "core/client.py")
SIM_FILES = ("core/simclient.py", "core/simulator.py")
_NUMERIC_ANNOTATIONS = {"int", "float"}


def _file_set(relpath: str) -> str:
    p = relpath.replace("\\", "/")
    if any(p.endswith(s) for s in ANALYTIC_FILES):
        return "analytic"
    if any(p.endswith(s) for s in SIM_FILES):
        return "sim"
    return "shared"


def _is_numeric_field(stmt: ast.AnnAssign) -> bool:
    ann = stmt.annotation
    if isinstance(ann, ast.Name):
        return ann.id in _NUMERIC_ANNOTATIONS
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value in _NUMERIC_ANNOTATIONS
    return False


@dataclass
class _FieldDecl:
    cls: str
    name: str
    mod: ModuleInfo
    node: ast.AST


@register
class ParitySurfaceChecker(Checker):
    rule = "parity-surface"
    description = ("numeric counters on report/stats classes must be "
                   "assigned by both the analytic and simulated engine "
                   "paths")

    def __init__(self) -> None:
        self._decls: List[_FieldDecl] = []
        # attr name -> set of engine sides that write it
        self._writes: Dict[str, Set[str]] = {}
        self._saw_engine_file = {"analytic": False, "sim": False}

    def check_module(self, mod: ModuleInfo) -> Iterable[Violation]:
        side = _file_set(mod.relpath)
        if side in self._saw_engine_file:
            self._saw_engine_file[side] = True
        sides = ("analytic", "sim") if side == "shared" else (side,)

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) \
                    and node.name in TARGET_CLASSES:
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) \
                            and isinstance(stmt.target, ast.Name) \
                            and _is_numeric_field(stmt):
                        self._decls.append(_FieldDecl(
                            cls=node.name, name=stmt.target.id,
                            mod=mod, node=stmt))
            # attribute writes: r.field = / r.field += ...
            targets: List[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for tgt in targets:
                if isinstance(tgt, ast.Attribute):
                    self._mark(tgt.attr, sides)
            # constructor keywords: ScenarioReport(bytes_moved=...)
            if isinstance(node, ast.Call):
                fname = node.func
                cname = fname.attr if isinstance(fname, ast.Attribute) \
                    else fname.id if isinstance(fname, ast.Name) else ""
                if cname in TARGET_CLASSES or cname == "replace":
                    for kw in node.keywords:
                        if kw.arg:
                            self._mark(kw.arg, sides)
        return ()

    def _mark(self, attr: str, sides: Tuple[str, ...]) -> None:
        self._writes.setdefault(attr, set()).update(sides)

    def finalize(self) -> Iterable[Violation]:
        # Only meaningful when both engine files were in the analyzed
        # set — linting a lone fixture module must not claim the whole
        # engine is missing.
        if not (self._saw_engine_file["analytic"]
                and self._saw_engine_file["sim"]):
            return []
        out: List[Violation] = []
        for d in self._decls:
            sides = self._writes.get(d.name, set())
            missing = {"analytic", "sim"} - sides
            if missing and len(missing) < 2:
                present = next(iter(sides & {"analytic", "sim"}))
                out.append(self.violation(
                    d.mod, d.node,
                    f"counter {d.cls}.{d.name} is assigned on the "
                    f"{present} engine path but never on the "
                    f"{next(iter(missing))} path — latent engine-parity "
                    f"gap", symbol=f"{d.cls}.{d.name}"))
            elif len(missing) == 2:
                out.append(self.violation(
                    d.mod, d.node,
                    f"counter {d.cls}.{d.name} is declared but never "
                    f"assigned by either engine path — dead parity "
                    f"surface", symbol=f"{d.cls}.{d.name}"))
        return out
