"""deepseek-coder-33b — dense llama-architecture decoder.

[arXiv:2401.14196; hf] 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256.  Pure full attention → long_500k skipped.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128,
    rope_theta=100_000.0, tie_embeddings=False,
    padded_heads=64,   # TP-16 head padding (EXPERIMENTS.md §Perf)
)

SMOKE = ArchConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256, head_dim=16,
    tie_embeddings=False,
)

register(FULL, SMOKE)
