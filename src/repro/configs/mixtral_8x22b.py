"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA.  Sub-quadratic via SWA → runs long_500k.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    num_experts=8, experts_per_token=2,
    sliding_window=4096, subquadratic=True,
    rope_theta=1_000_000.0, tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="mixtral-8x22b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    num_experts=4, experts_per_token=2,
    sliding_window=16, subquadratic=True,
    tie_embeddings=False,
)

register(FULL, SMOKE)
