"""gemma2-2b — local/global alternating attention with logit softcaps.

[arXiv:2408.00118; hf] 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000.  Alternating sliding-window(4096)/global layers, attention
logit softcap 50, final logit softcap 30, tied embeddings, head_dim 256.
Windowed layers → sub-quadratic path → runs long_500k.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256_000, head_dim=256,
    sliding_window=4096, local_global_period=2,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    tie_embeddings=True, subquadratic=True,
    padded_heads=16,   # TP-16 head padding (EXPERIMENTS.md §Perf)
)

SMOKE = ArchConfig(
    name="gemma2-2b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    sliding_window=16, local_global_period=2,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    tie_embeddings=True, subquadratic=True,
)

register(FULL, SMOKE)
