"""Architecture configuration schema + input-shape registry.

Every assigned architecture is a frozen :class:`ArchConfig`; its layer
stack is derived from a repeating *pattern* of (sequence-mixer,
channel-mixer) block kinds so heterogeneous stacks (Jamba's 1:7
Mamba:attention interleave, Gemma-2's local/global alternation,
Llama-3.2-Vision's cross-attention every 5th layer) compile as a
``lax.scan`` over homogeneous groups.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

# Block kinds: sequence mixer × channel mixer.
MIXER_ATTN = "attn"          # causal self attention (full or windowed)
MIXER_ATTN_LOCAL = "attn_local"   # sliding-window self attention
MIXER_SSM = "ssm"            # Mamba2 SSD
MIXER_XATTN = "xattn"        # cross-attention to modality embeddings
FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"            # Mamba2 blocks carry no separate FFN


@dataclasses.dataclass(frozen=True)
class BlockSpec_:
    """One position in the repeating layer pattern."""

    mixer: str
    ffn: str


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1             # MoE FFN on layers where i % moe_every == r
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # --- attention flavour ---
    sliding_window: int = 0        # >0 → SWA on MIXER_ATTN_LOCAL layers
    local_global_period: int = 0   # gemma2: alternate local/global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    attn_every: int = 0            # hybrid: attention on i % attn_every == k
    attn_offset: int = 0
    # --- VLM ---
    cross_attn_every: int = 0      # cross-attn on i % every == offset
    cross_attn_offset: int = 0
    num_image_tokens: int = 0
    # --- misc ---
    # TP head padding (§Perf): pad q-heads to this count with zero-init
    # rows so attention shards over a model axis the true head count does
    # not divide.  Zero wq/wo rows contribute nothing at init; pad-head
    # FLOPs are the price of sharding (e.g. deepseek 56→64: +14% attn
    # FLOPs instead of 16× replication).
    padded_heads: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # long_500k applicability (sub-quadratic sequence path available?)
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def resolved_num_heads(self) -> int:
        return self.padded_heads or self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def pattern(self) -> List[BlockSpec_]:
        """The repeating unit of the layer stack."""
        period = 1
        if self.attn_every:
            period = _lcm(period, self.attn_every)
        if self.cross_attn_every:
            period = _lcm(period, self.cross_attn_every)
        if self.local_global_period:
            period = _lcm(period, self.local_global_period)
        if self.num_experts and self.moe_every > 1:
            period = _lcm(period, self.moe_every)
        out: List[BlockSpec_] = []
        for i in range(period):
            if self.family == "ssm":
                mixer = MIXER_SSM
            elif self.attn_every:      # hybrid: mostly SSM, sparse attention
                mixer = (MIXER_ATTN if i % self.attn_every == self.attn_offset
                         else MIXER_SSM)
            elif self.cross_attn_every:
                mixer = (MIXER_XATTN
                         if i % self.cross_attn_every == self.cross_attn_offset
                         else MIXER_ATTN)
            elif self.local_global_period:
                mixer = (MIXER_ATTN_LOCAL
                         if i % self.local_global_period == 0 else MIXER_ATTN)
            elif self.sliding_window:
                mixer = MIXER_ATTN_LOCAL
            else:
                mixer = MIXER_ATTN
            if mixer == MIXER_SSM:
                ffn = FFN_NONE if self.family == "ssm" else (
                    FFN_MOE if self.num_experts
                    and i % self.moe_every == self.moe_offset else FFN_DENSE)
            elif self.num_experts and i % self.moe_every == self.moe_offset:
                ffn = FFN_MOE
            else:
                ffn = FFN_DENSE if self.d_ff else FFN_NONE
            out.append(BlockSpec_(mixer, ffn))
        return out

    def num_groups(self) -> int:
        p = len(self.pattern())
        if self.num_layers % p:
            raise ValueError(
                f"{self.name}: {self.num_layers} layers not divisible by "
                f"pattern period {p}")
        return self.num_layers // p

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f = self.d_model, self.d_ff
        hd = self.resolved_head_dim
        n = self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for spec in self.pattern() * self.num_groups():
            if spec.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL, MIXER_XATTN):
                # padded q-heads allocate real (zero) rows
                n += d * hd * (self.resolved_num_heads
                               + 2 * self.num_kv_heads)
                n += self.resolved_num_heads * hd * d
            elif spec.mixer == MIXER_SSM:
                di, ns, hs = self.d_inner, self.ssm_state, self.ssm_heads
                n += d * (2 * di + 2 * ns + hs)  # in_proj(z,x,B,C,dt)
                n += di * d                       # out_proj
                n += self.ssm_conv_width * (di + 2 * ns) + 2 * hs + di
            if spec.ffn == FFN_DENSE:
                n += 3 * d * f
            elif spec.ffn == FFN_MOE:
                n += d * self.num_experts + 3 * d * f * self.num_experts
            n += 2 * d  # norms
        return n

    def active_param_count(self) -> int:
        """Parameters doing useful work per token (MoE: routed experts
        only; TP padding: zero pad-head rows excluded)."""
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        if self.padded_heads:
            attn_layers = sum(
                1 for s in self.pattern()
                if s.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL, MIXER_XATTN)) \
                * self.num_groups()
            total -= attn_layers * 2 * d * self.resolved_head_dim * \
                (self.padded_heads - self.num_heads)
        if not self.num_experts:
            return total
        moe_layers = sum(1 for s in self.pattern() if s.ffn == FFN_MOE) \
            * self.num_groups()
        inactive = moe_layers * 3 * d * f * \
            (self.num_experts - self.experts_per_token)
        return total - inactive


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Input-shape registry (LM-family: seq_len × global_batch)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig) -> List[InputShape]:
    """All 4 shapes, except long_500k for pure full-attention archs
    (skip recorded in DESIGN.md §4)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        out.append(SHAPES["long_500k"])
    return out


_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass
class ArchEntry:
    full: ArchConfig
    smoke: ArchConfig


def register(full: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[full.name] = ArchEntry(full, smoke)
    return full


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    _ensure_loaded()
    entry = _REGISTRY.get(name)
    if entry is None:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return entry.smoke if smoke else entry.full


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (deepseek_coder_33b, gemma2_2b, jamba_1_5_large,  # noqa
                   llama_3_2_vision_90b, mamba2_780m, mixtral_8x22b,
                   musicgen_medium, phi3_mini_3_8b, phi3_5_moe, qwen2_7b)
