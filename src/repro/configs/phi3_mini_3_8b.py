"""phi3-mini-3.8b — dense decoder, RoPE + SwiGLU, MHA (kv=heads).

[arXiv:2404.14219; unverified] 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064.  Pure full attention → long_500k skipped.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="phi3-mini-3.8b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    tie_embeddings=False,
)

register(FULL, SMOKE)
