"""qwen2-7b — dense decoder with GQA and QKV bias.

[arXiv:2407.10671; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064.  Pure full attention → long_500k skipped.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152_064, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=False,
    padded_heads=32,   # TP-16 head padding (EXPERIMENTS.md §Perf)
)

SMOKE = ArchConfig(
    name="qwen2-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256, head_dim=16,
    qkv_bias=True, tie_embeddings=False,
)

register(FULL, SMOKE)

