"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE.

[hf:microsoft/Phi-3.5-MoE-instruct; hf] 32L d_model=4096 32H (GQA kv=8)
d_ff=6400 vocab=32064, MoE 16e top-2.  Pure full attention → long_500k
skipped (DESIGN.md §4).
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064, head_dim=128,
    num_experts=16, experts_per_token=2,
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="phi3.5-moe-42b-a6.6b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=256, head_dim=16,
    num_experts=4, experts_per_token=2,
    tie_embeddings=False,
)

register(FULL, SMOKE)
