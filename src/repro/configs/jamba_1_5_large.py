"""jamba-1.5-large-398b — Mamba+attention 1:7 hybrid with 16-expert MoE.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2.  One attention layer per 8 (1:7 interleave),
MoE every other layer.  SSM majority → sub-quadratic → runs long_500k.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    num_experts=16, experts_per_token=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4,
    ssm_state=128, ssm_headdim=128, ssm_expand=2,
    tie_embeddings=False, subquadratic=True,
)

SMOKE = ArchConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    num_experts=4, experts_per_token=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=8,
    tie_embeddings=False, subquadratic=True,
)

register(FULL, SMOKE)
