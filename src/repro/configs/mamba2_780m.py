"""mamba2-780m — attention-free SSM with state-space duality (SSD).

[arXiv:2405.21060; unverified] 48L d_model=1536 (attn-free) d_ff=0
vocab=50280, ssm_state=128.  Fully sub-quadratic → runs long_500k.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2,
    tie_embeddings=True, subquadratic=True,
)

SMOKE = ArchConfig(
    name="mamba2-780m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=8,
    tie_embeddings=True, subquadratic=True,
)

register(FULL, SMOKE)
