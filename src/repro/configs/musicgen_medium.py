"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (kv=24, i.e. MHA) d_ff=6144
vocab=2048.  The EnCodec tokenizer/delay-pattern frontend is a STUB:
``input_specs()`` provides precomputed frame token ids over the 2048-entry
codebook (DESIGN.md §4).  Pure full attention → long_500k skipped.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="musicgen-medium-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=128, head_dim=16,
    tie_embeddings=False,
)

register(FULL, SMOKE)
