"""Architecture configs: one module per assigned architecture."""
from .base import (ArchConfig, InputShape, SHAPES, get_config, list_archs,
                   shapes_for)

__all__ = ["ArchConfig", "InputShape", "SHAPES", "get_config", "list_archs",
           "shapes_for"]
