"""llama-3.2-vision-90b — dense decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified] 100L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256.  Every 5th layer cross-attends to
precomputed vision patch embeddings; the vision encoder is a STUB —
``input_specs()`` provides (batch, 1600, d_model) patch embeddings
(DESIGN.md §4).  Pure full attention → long_500k skipped.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128_256, head_dim=128,
    cross_attn_every=5, cross_attn_offset=4, num_image_tokens=1600,
    rope_theta=500_000.0, tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    cross_attn_every=5, cross_attn_offset=4, num_image_tokens=8,
    tie_embeddings=False,
)

register(FULL, SMOKE)
