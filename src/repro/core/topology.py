"""Topology & the GeoIP analogue.

The paper's clients find the nearest cache with GeoIP.  Inside a TPU fleet
there is no IP geolocation, so we replace geographic distance with
coordinate distance over ``(site/pod, rack, host)`` and classed link
bandwidths: intra-host > intra-rack (ICI) > intra-pod (ICI) > cross-pod
(DCN) > WAN-to-origin.  This preserves the semantics the paper relies on —
pick the cheapest cache first and fall outward — while being measurable in
a cluster (DESIGN.md §2, "GeoIP → mesh topology").

Links are shared, capacity-constrained resources: the site uplink is one
link no matter how many workers pull through it, which is exactly what the
Syracuse WAN graph (paper Fig. 5) is about.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

GB = 1e9  # network giga (bytes)

# Continental backbone segments (one-way propagation latency, seconds) for
# multi-region federations: ~120 ms coast-to-coast RTT, ~180 ms
# transatlantic, ~200-360 ms transpacific.  Pairs are stored with sorted
# keys; unlisted pairs fall back to DEFAULT_BACKBONE_RTT.
CONTINENTAL_RTT: Dict[Tuple[str, str], float] = {
    ("us-east", "us-west"): 0.060,
    ("eu", "us-east"): 0.090,
    ("eu", "us-west"): 0.140,
    ("ap", "us-west"): 0.100,
    ("ap", "us-east"): 0.160,
    ("ap", "eu"): 0.180,
}
DEFAULT_BACKBONE_RTT = 0.080     # one-way, unlisted region pairs
DEFAULT_BACKBONE_BW = 100 * GB / 8
DEFAULT_REGIONAL_RTT = 0.006     # one-way, sites sharing a region
DEFAULT_REGIONAL_BW = 200 * GB / 8


@dataclasses.dataclass(frozen=True)
class Coord:
    """Location of a node: (site, rack, host).  ``site`` doubles as the
    pod index inside a fleet and the university/PoP in the OSG mapping."""

    site: str
    rack: int = 0
    host: int = 0

    def distance(self, other: "Coord") -> int:
        """0 same host, 1 same rack, 2 same site/pod, 3 remote."""
        if self.site != other.site:
            return 3
        if self.rack != other.rack:
            return 2
        if self.host != other.host:
            return 1
        return 0


@dataclasses.dataclass
class Link:
    """A shared, capacity-constrained network resource."""

    name: str
    bandwidth: float          # bytes/sec
    latency: float = 1e-4    # seconds, one-way
    active_flows: int = 0    # maintained by the fluid-flow simulator
    base_bandwidth: Optional[float] = dataclasses.field(default=None,
                                                        repr=False)

    def share(self) -> float:
        return self.bandwidth / max(1, self.active_flows)

    def degrade(self, factor: float) -> None:
        """Scale bandwidth to ``factor`` of the undegraded value
        (idempotent: repeated degrades compose against the original)."""
        if self.base_bandwidth is None:
            self.base_bandwidth = self.bandwidth
        self.bandwidth = self.base_bandwidth * factor

    def restore(self) -> None:
        if self.base_bandwidth is not None:
            self.bandwidth = self.base_bandwidth
            self.base_bandwidth = None


@dataclasses.dataclass
class Node:
    """Any endpoint: worker, cache, proxy, origin, redirector."""

    name: str
    coord: Coord
    nic: Link


@dataclasses.dataclass
class BandwidthProfile:
    """Per-site link speeds (bytes/sec).  Calibratable to the paper's site
    behaviour — e.g. Colorado prioritises proxy↔WAN bandwidth while its
    workers see less bandwidth to the nearest StashCache cache (§5)."""

    worker_nic: float = 10 * GB / 8          # 10 Gbps
    cache_nic: float = 10 * GB / 8           # caches guaranteed ≥10 Gbps (§1)
    proxy_nic: float = 10 * GB / 8
    origin_nic: float = 100 * GB / 8
    site_uplink: float = 100 * GB / 8        # site ↔ WAN/DCN
    wan: float = 100 * GB / 8                # research backbone
    wan_rtt: float = 0.030                   # 30 ms WAN
    lan_rtt: float = 0.0005                  # 0.5 ms LAN
    # Large objects are served from disk, not page cache — squid and
    # xrootd disk caches alike (paper §5: proxies are "optimized for
    # small files").  Objects larger than *_mem_max stream at *_disk_bw.
    proxy_mem_max: float = 4e9
    proxy_disk_bw: float = 0.9 * GB
    cache_mem_max: float = 4e9
    cache_disk_bw: float = 0.0               # 0 → not disk-bound


class Topology:
    """Registry of nodes + shared links and a path model.

    The path between two nodes traverses: src NIC → [src site uplink →
    WAN → dst site uplink] → dst NIC (site-internal hops skip the WAN).
    Fidelity is deliberately at the level the paper reasons about: NICs,
    site uplinks and the backbone — not per-switch fabrics.
    """

    def __init__(self) -> None:
        self.nodes: Dict[str, Node] = {}
        self.site_uplinks: Dict[str, Link] = {}
        self.wan = Link("wan", 100 * GB / 8, latency=0.015)
        self._profiles: Dict[str, BandwidthProfile] = {}
        # Region layer (multi-tier CDN topologies): sites may carry a
        # region; cross-site paths then ride the regional network (same
        # region) or a continental backbone segment (different regions)
        # instead of the single flat WAN link.  Region-less sites keep the
        # legacy WAN path, so flat federations are untouched.
        self.site_region: Dict[str, str] = {}
        self.region_nets: Dict[str, Link] = {}
        self.backbones: Dict[Tuple[str, str], Link] = {}

    # -- construction -----------------------------------------------------
    def add_site(self, site: str,
                 profile: Optional[BandwidthProfile] = None,
                 region: str = "") -> None:
        profile = profile or BandwidthProfile()
        self._profiles[site] = profile
        self.site_region[site] = region
        self.site_uplinks[site] = Link(f"{site}/uplink", profile.site_uplink,
                                       latency=profile.lan_rtt)

    def region_net(self, region: str) -> Link:
        """The shared intra-region network (one link class per region)."""
        link = self.region_nets.get(region)
        if link is None:
            link = Link(f"region/{region}", DEFAULT_REGIONAL_BW,
                        latency=DEFAULT_REGIONAL_RTT)
            self.region_nets[region] = link
        return link

    def backbone(self, ra: str, rb: str) -> Link:
        """The continental backbone segment between two regions (lazily
        created from :data:`CONTINENTAL_RTT`, symmetric in its key)."""
        key = (ra, rb) if ra <= rb else (rb, ra)
        link = self.backbones.get(key)
        if link is None:
            link = Link(f"backbone/{key[0]}-{key[1]}", DEFAULT_BACKBONE_BW,
                        latency=CONTINENTAL_RTT.get(key,
                                                    DEFAULT_BACKBONE_RTT))
            self.backbones[key] = link
        return link

    def set_backbone(self, ra: str, rb: str, bandwidth: Optional[float] = None,
                     rtt: Optional[float] = None) -> Link:
        """Override one backbone segment's bandwidth and/or round-trip
        time (``rtt`` is the full RTT; the link stores one-way latency)."""
        link = self.backbone(ra, rb)
        if bandwidth is not None:
            link.bandwidth = bandwidth
            link.base_bandwidth = None
        if rtt is not None:
            link.latency = rtt / 2.0
        return link

    def find_link(self, name: str) -> Optional[Link]:
        """Resolve a shared link by name — uplinks, WAN, regional nets,
        backbone segments, NICs — for fault injection (link degradation)."""
        if name == self.wan.name:
            return self.wan
        for table in (self.site_uplinks, self.region_nets):
            for link in table.values():
                if link.name == name:
                    return link
        for link in self.backbones.values():
            if link.name == name:
                return link
        node = self.nodes.get(name.split("/nic")[0])
        if node is not None and node.nic.name == name:
            return node.nic
        return None

    def profile(self, site: str) -> BandwidthProfile:
        return self._profiles[site]

    def add_node(self, name: str, coord: Coord, nic_bw: float,
                 latency: float = 1e-4) -> Node:
        if coord.site not in self.site_uplinks:
            self.add_site(coord.site)
        node = Node(name, coord, Link(f"{name}/nic", nic_bw, latency))
        self.nodes[name] = node
        return node

    # -- path & distance --------------------------------------------------
    def path(self, src: str, dst: str) -> List[Link]:
        a, b = self.nodes[src], self.nodes[dst]
        if a is b:
            return []  # loopback: crosses no shared network capacity
        links = [a.nic]
        if a.coord.site != b.coord.site:
            ra = self.site_region.get(a.coord.site, "")
            rb = self.site_region.get(b.coord.site, "")
            if ra and rb:
                middle = (self.region_net(ra) if ra == rb
                          else self.backbone(ra, rb))
            else:
                middle = self.wan
            links += [self.site_uplinks[a.coord.site], middle,
                      self.site_uplinks[b.coord.site]]
        links.append(b.nic)
        return links

    def rtt(self, src: str, dst: str) -> float:
        return 2.0 * sum(l.latency for l in self.path(src, dst))

    def bottleneck_bandwidth(self, src: str, dst: str) -> float:
        p = self.path(src, dst)
        return min(l.bandwidth for l in p) if p else float("inf")

    def distance(self, src: str, dst: str) -> Tuple[int, float]:
        """(coordinate distance, rtt) — the GeoIP sort key."""
        return (self.nodes[src].coord.distance(self.nodes[dst].coord),
                self.rtt(src, dst))


class GeoIPService:
    """Nearest-cache discovery (paper §3.1).

    CVMFS ships a built-in GeoIP locator; ``stashcp`` must *query a remote
    server* to learn its nearest cache, which is the startup cost the paper
    measures against HTTP proxies (whose nearest proxy is handed to them in
    the environment).  ``lookup_latency`` models that remote round-trip and
    is added to stashcp-style transfers by the client.
    """

    def __init__(self, topology: Topology, lookup_latency: float = 0.200):
        self.topology = topology
        self.lookup_latency = lookup_latency
        self.lookups = 0

    def nearest(self, client: str, caches: Sequence[str],
                exclude: Sequence[str] = ()) -> List[str]:
        self.lookups += 1
        # (distance, name): the name tie-break keeps rankings stable when
        # several caches sit at the same coordinate distance + RTT
        # (dict-iteration order is an accident of construction, not policy).
        ranked = sorted((c for c in caches if c not in exclude),
                        key=lambda c: (self.topology.distance(client, c), c))
        return ranked
