"""Workload generation from the paper's production measurements.

Table 2 gives the file-size percentiles of six months of StashCache
monitoring (Oct 2018 – Apr 2019); the evaluation dataset is one file per
percentile plus a forward-looking 10 GB probe.  Table 1 gives the byte mix
by experiment, which we reuse for utilisation benchmarks.
"""
from __future__ import annotations

import bisect
import dataclasses
import random
from typing import Dict, List, Sequence, Tuple

KB = 1000
MB = 1000**2
GB = 1000**3
TB = 1000**4
PB = 1000**5

# Paper Table 2: StashCache file-size percentiles.
FILESIZE_PERCENTILES: List[Tuple[int, int]] = [
    (1, int(5.797 * KB)),
    (5, int(22.801 * MB)),
    (25, int(170.131 * MB)),
    (50, int(467.852 * MB)),
    (75, int(493.337 * MB)),
    (95, int(2.335 * GB)),
    (99, int(2.335 * GB)),
]

# The forward-looking large-file probe used throughout §4.1/§5.
PROBE_10GB = 10 * GB

# Paper Table 1: top StashCache users over 6 months (bytes moved).
USAGE_BY_EXPERIMENT: Dict[str, int] = {
    "osg-gravitational-wave": int(1.079 * PB),
    "des": int(709.051 * TB),
    "minerva": int(514.794 * TB),
    "ligo": int(228.324 * TB),
    "continuous-testing": int(184.773 * TB),
    "nova": int(24.317 * TB),
    "lsst": int(18.966 * TB),
    "bioinformatics": int(17.566 * TB),
    "dune": int(11.677 * TB),
}

# Paper Table 3: measured %Δ download time (StashCache vs HTTP proxy);
# negative = StashCache faster.  Used to validate our simulator's signs.
PAPER_TABLE3: Dict[str, Dict[str, float]] = {
    "bellarmine": {"2.3GB": -68.5, "10GB": -10.0},
    "syracuse": {"2.3GB": +0.9, "10GB": -26.3},
    "colorado": {"2.3GB": +506.5, "10GB": +245.9},
    "nebraska": {"2.3GB": -12.1, "10GB": -2.1},
    "chicago": {"2.3GB": +30.6, "10GB": -7.7},
}


def evaluation_fileset(include_probe: bool = True) -> List[Tuple[str, int]]:
    """One test file per distinct percentile (the paper skipped the 99th
    because it equals the 95th) plus the 10 GB probe."""
    files: List[Tuple[str, int]] = []
    seen = set()
    for pct, size in FILESIZE_PERCENTILES:
        if size in seen:
            continue
        seen.add(size)
        files.append((f"/testing/percentile_p{pct:02d}", size))
    if include_probe:
        files.append(("/testing/probe_10gb", PROBE_10GB))
    return files


class PercentileSampler:
    """Sample file sizes from the piecewise-linear Table 2 distribution."""

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)
        pts = [(0.0, 512.0)] + [(p / 100.0, float(s))
                                for p, s in FILESIZE_PERCENTILES]
        pts.append((1.0, float(PROBE_10GB)))
        self._ps = [p for p, _ in pts]
        self._ss = [s for _, s in pts]

    def sample(self) -> int:
        u = self._rng.random()
        i = bisect.bisect_right(self._ps, u) - 1
        i = min(i, len(self._ps) - 2)
        p0, p1 = self._ps[i], self._ps[i + 1]
        s0, s1 = self._ss[i], self._ss[i + 1]
        frac = (u - p0) / (p1 - p0) if p1 > p0 else 0.0
        # Log-linear interpolation: sizes span 7 decades.
        import math
        return max(1, int(math.exp(math.log(max(s0, 1.0)) * (1 - frac)
                                   + math.log(max(s1, 1.0)) * frac)))


@dataclasses.dataclass
class AccessRequest:
    """One client file access in a generated workload."""

    time: float
    site: str
    worker: int
    path: str
    size: int
    experiment: str
    tenant: str = ""  # fair-share accounting unit; defaults to experiment


def split_bytes(total: int, n: int) -> List[int]:
    """``n`` shard sizes summing *exactly* to ``total`` (the remainder is
    spread one byte each over the first ``total % n`` shards) — the
    canonical sizing every model-traffic generator uses, so request sizes
    always reconcile against the checkpoint/dataset byte total."""
    if n <= 0:
        raise ValueError(f"need at least one shard, got {n}")
    base, rem = divmod(int(total), n)
    return [base + (1 if i < rem else 0) for i in range(n)]


def checkpoint_restart_workload(sites: Sequence[str], prefix: str,
                                total_bytes: int, n_shards: int,
                                workers_per_site: int = 1,
                                tp_degree: int = 1,
                                at: float = 0.0, jitter: float = 0.0,
                                seed: int = 0,
                                manifest_bytes: int = 64 * KB,
                                tenant: str = "restart"
                                ) -> List[AccessRequest]:
    """A training restart storm over a *sharded* checkpoint.

    After a preemption every worker re-fetches the shard manifest, then
    the parameter shards its model-parallel rank owns (shard ``i`` is
    owned by rank ``i % tp_degree``; worker ``w`` holds rank
    ``w % tp_degree``).  With ``tp_degree=1`` every worker re-reads the
    whole checkpoint — the classic every-pod-refetches-a-33B-checkpoint
    storm; with ``tp_degree=k`` each shard is pulled ``workers/k`` times
    per site, the fan-in a pod cache collapses to one origin read.
    """
    if tp_degree <= 0:
        raise ValueError(f"tp_degree must be positive, got {tp_degree}")
    rng = random.Random(seed)
    sizes = split_bytes(total_bytes, n_shards)
    out: List[AccessRequest] = []
    for s in sites:
        for w in range(workers_per_site):
            t = at + (rng.uniform(0.0, jitter) if jitter > 0 else 0.0)
            out.append(AccessRequest(
                time=t, site=s, worker=w,
                path=f"{prefix}/manifest.json", size=manifest_bytes,
                experiment="checkpoint-restart", tenant=tenant))
            rank = w % tp_degree
            for i in range(rank, n_shards, tp_degree):
                out.append(AccessRequest(
                    time=t, site=s, worker=w,
                    path=f"{prefix}/shard_{i:05d}", size=sizes[i],
                    experiment="checkpoint-restart", tenant=tenant))
    out.sort(key=lambda r: r.time)
    return out


def shard_serving_workload(sites: Sequence[str], prefix: str,
                           total_bytes: int, n_shards: int,
                           n_requests: int = 256,
                           duration: float = 3600.0,
                           zipf_a: float = 1.2, seed: int = 0,
                           tenant: str = "serving"
                           ) -> List[AccessRequest]:
    """Model-shard serving traffic: Zipf-popular reads over the shards of
    one model (hot layers / embedding shards dominate), sized so the
    shard set sums exactly to the model's byte total."""
    rng = random.Random(seed)
    sizes = split_bytes(total_bytes, n_shards)
    ranks = [1.0 / (k + 1) ** zipf_a for k in range(n_shards)]
    site_list = list(sites)
    out: List[AccessRequest] = []
    for _ in range(n_requests):
        k = rng.choices(range(n_shards), weights=ranks)[0]
        out.append(AccessRequest(
            time=rng.uniform(0.0, duration),
            site=rng.choice(site_list),
            worker=rng.randrange(0, 1 << 16),
            path=f"{prefix}/shard_{k:05d}", size=sizes[k],
            experiment="shard-serving", tenant=tenant))
    out.sort(key=lambda r: r.time)
    return out


def dataloader_workload(sites: Sequence[str], prefix: str,
                        total_bytes: int, n_shards: int,
                        workers_per_site: int = 1, epochs: int = 1,
                        at: float = 0.0, step_gap: float = 1.0,
                        tenant: str = "dataloader"
                        ) -> List[AccessRequest]:
    """Sequential striped dataset reads: worker ``w`` of each site walks
    shards ``w, w+W, w+2W, ...`` in order (one shard per ``step_gap``
    seconds), so a site's workers collectively sweep the whole dataset
    once per epoch — the training data path's access pattern.
    Deterministic (no randomness): restart-safe like the loader itself."""
    sizes = split_bytes(total_bytes, n_shards)
    stride = max(workers_per_site, 1)
    per_worker = -(-n_shards // stride)  # ceil: epoch length in steps
    out: List[AccessRequest] = []
    for e in range(epochs):
        for s in sites:
            for w in range(workers_per_site):
                owned = range(w % stride, n_shards, stride)
                for k, i in enumerate(owned):
                    out.append(AccessRequest(
                        time=at + (e * per_worker + k) * step_gap,
                        site=s, worker=w,
                        path=f"{prefix}/shard_{i:05d}", size=sizes[i],
                        experiment="dataloader", tenant=tenant))
    out.sort(key=lambda r: r.time)
    return out


def storm_workload(sites: Sequence[str], path: str = "/ckpt/step/params",
                   size: int = 2 * GB, at: float = 0.0,
                   workers_per_site: int = 1, jitter: float = 0.0,
                   seed: int = 0) -> List[AccessRequest]:
    """A restart storm: every worker on every site requests the *same*
    object at (nearly) the same instant — the checkpoint fan-in that
    follows a preemption or rolling restart.  ``jitter`` spreads the
    arrivals uniformly over [at, at+jitter); zero keeps them exactly
    simultaneous, the worst case for the bandwidth solver."""
    rng = random.Random(seed)
    out = [AccessRequest(
        time=at + (rng.uniform(0.0, jitter) if jitter > 0 else 0.0),
        site=s, worker=w, path=path, size=size, experiment="restart-storm")
        for s in sites for w in range(workers_per_site)]
    out.sort(key=lambda r: r.time)
    return out


def generate_workload(sites: Sequence[str], n_requests: int,
                      duration: float = 3600.0, seed: int = 0,
                      working_set: int = 64,
                      zipf_a: float = 1.2,
                      tenants: Dict[str, float] = None
                      ) -> List[AccessRequest]:
    """A production-shaped trace: Table 2 sizes, Table 1 experiment mix,
    Zipf-popular working set (caching only helps if there is reuse).

    ``tenants`` optionally maps tenant name → weight; each request is
    then tagged with a tenant drawn from that mix (on a separate RNG
    stream so the trace itself is unchanged).  Without it the tenant
    defaults to the owning experiment downstream."""
    rng = random.Random(seed)
    sampler = PercentileSampler(seed)
    experiments = list(USAGE_BY_EXPERIMENT)
    weights = [USAGE_BY_EXPERIMENT[e] for e in experiments]
    # Working set: file k of an experiment has Zipf popularity ~ 1/k^a.
    files: List[Tuple[str, int, str]] = []
    for e in experiments:
        for k in range(working_set):
            files.append((f"/{e}/data/file_{k:04d}", sampler.sample(), e))
    ranks = [1.0 / (k + 1) ** zipf_a for k in range(working_set)]
    trng = random.Random(seed ^ 0x7E9A97) if tenants else None
    tnames = list(tenants) if tenants else []
    tweights = [tenants[t] for t in tnames] if tenants else []
    out: List[AccessRequest] = []
    for i in range(n_requests):
        e_idx = rng.choices(range(len(experiments)), weights=weights)[0]
        k = rng.choices(range(working_set), weights=ranks)[0]
        path, size, exp = files[e_idx * working_set + k]
        out.append(AccessRequest(
            time=rng.uniform(0.0, duration),
            site=rng.choice(list(sites)),
            worker=rng.randrange(0, 1 << 16),
            path=path, size=size, experiment=exp,
            tenant=trng.choices(tnames, weights=tweights)[0]
            if trng else ""))
    out.sort(key=lambda r: r.time)
    return out


def herd_workload(sites: Sequence[str], path: str = "/hot/object",
                  size: int = 2 * GB, at: float = 0.0,
                  workers_per_site: int = 1, jitter: float = 0.0,
                  n_objects: int = 1, waves: int = 1,
                  wave_gap: float = 30.0, seed: int = 0,
                  tenant: str = "herd") -> List[AccessRequest]:
    """Thundering herd: repeated synchronized waves of every worker
    hitting one hot object.  Unlike :func:`storm_workload` (one burst),
    the herd re-fires every ``wave_gap`` seconds for ``waves`` rounds,
    optionally rotating through ``n_objects`` distinct hot objects — the
    load shape that keeps an admission queue saturated rather than
    merely spiking it."""
    rng = random.Random(seed)
    out: List[AccessRequest] = []
    for wave in range(waves):
        p = (f"{path}_{wave % max(n_objects, 1):03d}"
             if n_objects > 1 else path)
        t0 = at + wave * wave_gap
        for s in sites:
            for w in range(workers_per_site):
                out.append(AccessRequest(
                    time=t0 + (rng.uniform(0.0, jitter) if jitter > 0
                               else 0.0),
                    site=s, worker=w, path=p, size=size,
                    experiment="thundering-herd", tenant=tenant))
    out.sort(key=lambda r: r.time)
    return out


def flash_crowd_workload(sites: Sequence[str], hot_sites: Sequence[str],
                         n_requests: int, duration: float = 3600.0,
                         seed: int = 0, working_set: int = 64,
                         zipf_a: float = 1.2,
                         crowd_factor: float = 3.0,
                         crowd_at: float = 0.0,
                         crowd_duration: float = 120.0,
                         hot_objects: int = 4,
                         hot_size: int = 493 * MB) -> List[AccessRequest]:
    """A regional flash crowd over a production-shaped background.

    The background is :func:`generate_workload` across every site; on
    top, the workers of ``hot_sites`` (one region's edge sites) fire
    ``crowd_factor × n_requests`` reads of a tiny ``hot_objects``-file
    set compressed into [``crowd_at``, ``crowd_at + crowd_duration``) —
    the release-day / trigger-alert shape where one region suddenly
    hammers a handful of objects.  In a tiered federation the first miss
    per edge fills the regional parent and every sibling edge then fills
    cache-to-cache, so origin egress stays near ``hot_objects ×
    hot_size`` instead of scaling with the crowd."""
    out = generate_workload(sites, n_requests, duration=duration,
                            seed=seed, working_set=working_set,
                            zipf_a=zipf_a)
    rng = random.Random(seed ^ 0xF1A54)
    hot_list = list(hot_sites)
    for _ in range(int(crowd_factor * n_requests)):
        k = rng.randrange(0, max(hot_objects, 1))
        out.append(AccessRequest(
            time=crowd_at + rng.uniform(0.0, crowd_duration),
            site=rng.choice(hot_list),
            worker=rng.randrange(0, 1 << 16),
            path=f"/flash/hot_{k:03d}", size=hot_size,
            experiment="flash-crowd", tenant="flash-crowd"))
    out.sort(key=lambda r: r.time)
    return out


def abusive_workload(sites: Sequence[str], n_requests: int,
                     duration: float = 3600.0, seed: int = 0,
                     working_set: int = 64, zipf_a: float = 1.2,
                     tenants: Dict[str, float] = None,
                     abusive_tenant: str = "abuser",
                     abuse_factor: float = 4.0,
                     abuse_at: float = 0.0,
                     abuse_duration: float = 60.0,
                     abuse_size: int = 512 * MB) -> List[AccessRequest]:
    """A well-behaved Zipf background trace plus one abusive tenant.

    The abuser fires ``abuse_factor × n_requests`` cache-busting reads
    (every path distinct, so each one misses) compressed into
    ``abuse_duration`` seconds — the workload whose damage per-tenant
    quotas exist to contain."""
    out = generate_workload(sites, n_requests, duration=duration,
                            seed=seed, working_set=working_set,
                            zipf_a=zipf_a, tenants=tenants)
    rng = random.Random(seed ^ 0xABB0)
    site_list = list(sites)
    for i in range(int(abuse_factor * n_requests)):
        out.append(AccessRequest(
            time=abuse_at + rng.uniform(0.0, abuse_duration),
            site=rng.choice(site_list),
            worker=rng.randrange(0, 1 << 16),
            path=f"/abuse/blob_{i:05d}", size=abuse_size,
            experiment=abusive_tenant, tenant=abusive_tenant))
    out.sort(key=lambda r: r.time)
    return out
