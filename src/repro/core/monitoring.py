"""Monitoring pipeline (paper §3.2, Figs. 3–4, Table 1).

Each cache/origin emits a record per *user login*, *file open* and *file
close* (in production these are XRootD binary UDP packets).  A central
collector joins the three streams: on every file-close it combines the
matching open + login into one transfer record and publishes it to a
message bus, from which aggregators build usage tables (Table 1) and time
series (Fig. 4).

The collector must tolerate packet loss and out-of-order arrival — our
``MonitorCollector.drop_rate`` and the join-by-id logic model exactly that.
"""
from __future__ import annotations

import dataclasses
import math
import random
from collections import defaultdict
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class UserLogin:
    server: str
    user_id: int
    client_host: str
    protocol: str          # "xrootd" | "http"
    ipv6: bool
    time: float


@dataclasses.dataclass(frozen=True)
class FileOpen:
    server: str
    file_id: int
    user_id: int
    path: str
    file_size: int
    time: float


@dataclasses.dataclass(frozen=True)
class FileClose:
    server: str
    file_id: int
    bytes_read: int
    bytes_written: int
    n_ops: int
    time: float


@dataclasses.dataclass(frozen=True)
class CacheUsagePacket:
    """Periodic per-cache gauge: occupancy + eviction-policy counters.

    Emitted by :meth:`repro.core.cache.CacheServer.report_usage`; the
    collector keeps the latest packet per server so aggregators can build
    per-policy comparison tables (hit rate, evictions, TTL expiries,
    admission rejects) next to the paper's per-experiment usage tables.
    """

    server: str
    policy: str
    usage_bytes: int
    capacity_bytes: int
    hits: int
    misses: int
    evictions: int
    bytes_evicted: int
    ttl_expired: int
    admission_rejects: int
    time: float
    oversize_rejects: int = 0
    tier: int = 1                # hierarchy level (1 = edge)
    bytes_from_parent: int = 0   # cache-to-cache fill received

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class TransferRecord:
    """The joined JSON message sent to the OSG message bus."""

    server: str
    path: str
    experiment: str
    client_host: str
    protocol: str
    file_size: int
    bytes_read: int
    bytes_written: int
    n_ops: int
    start_time: float
    end_time: float
    cache_hit: Optional[bool] = None


def experiment_of(path: str) -> str:
    """Top-level namespace prefix = the owning experiment (Table 1)."""
    parts = [p for p in path.split("/") if p]
    return parts[0] if parts else "unknown"


@dataclasses.dataclass
class FetchRollup:
    """Per-consumer rollup over :class:`~repro.core.api.FetchResult`s —
    the unified stats model for data-plane consumers (data loader,
    checkpointer, serve engine).

    Every result a consumer sees goes through :meth:`add`; the rollup
    keeps the aggregate the consumer used to account privately
    (``bytes_fetched`` / ``fetch_seconds`` / ``hit_rate`` ...) plus a
    per-method breakdown, so :func:`consumer_table` can build the
    training/serving analogue of the paper's Table-1 usage table.
    ``local_hits`` (worker-local CVMFS chunks) count toward
    :attr:`hit_rate` — the best hit of all — but stay separate from
    ``cache_hits`` so site-tier accounting still reconciles against the
    federation's own counters.
    """

    consumer: str = ""
    fetches: int = 0
    stores: int = 0
    steps: int = 0               # consumer-defined unit (loader batches)
    bytes_fetched: int = 0
    bytes_stored: int = 0
    fetch_seconds: float = 0.0
    store_seconds: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    local_hits: int = 0
    chunks: int = 0
    hedged: int = 0
    sheds: int = 0
    errors: int = 0
    queue_seconds: float = 0.0
    by_method: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def add(self, res) -> "FetchRollup":
        """Fold one FetchResult (store results — method ``writeback*`` —
        land in the store lanes, everything else in the fetch lanes)."""
        method = res.method or "unknown"
        bucket = self.by_method.setdefault(
            method, {"count": 0, "bytes": 0, "seconds": 0.0})
        bucket["count"] += 1
        bucket["bytes"] += res.bytes
        bucket["seconds"] += res.seconds
        if method.startswith("writeback"):
            self.stores += 1
            self.bytes_stored += res.bytes
            self.store_seconds += res.seconds
        else:
            self.fetches += 1
            self.bytes_fetched += res.bytes
            self.fetch_seconds += res.seconds
        self.cache_hits += res.cache_hits
        self.cache_misses += res.cache_misses
        self.local_hits += getattr(res, "local_hits", 0)
        self.chunks += res.chunks
        if getattr(res, "hedged", False):
            self.hedged += 1
        if getattr(res, "shed", False):
            self.sheds += 1
        if not res.ok:
            self.errors += 1
        self.queue_seconds += getattr(res, "queue_seconds", 0.0)
        return self

    def tick(self) -> None:
        self.steps += 1

    @property
    def hit_rate(self) -> float:
        served = self.cache_hits + self.local_hits
        total = served + self.cache_misses
        return served / total if total else 0.0

    def merge(self, other: "FetchRollup") -> "FetchRollup":
        for f in ("fetches", "stores", "steps", "bytes_fetched",
                  "bytes_stored", "fetch_seconds", "store_seconds",
                  "cache_hits", "cache_misses", "local_hits", "chunks",
                  "hedged", "sheds", "errors", "queue_seconds"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        for m, b in other.by_method.items():
            mine = self.by_method.setdefault(
                m, {"count": 0, "bytes": 0, "seconds": 0.0})
            for k in mine:
                mine[k] += b[k]
        return self


def consumer_table(rollups) -> List[Dict[str, object]]:
    """Per-consumer usage rows (most bytes first) — the training/serving
    analogue of the paper's Table-1 per-experiment usage table."""
    rows = []
    for r in sorted(rollups, key=lambda r: -(r.bytes_fetched
                                             + r.bytes_stored)):
        rows.append({
            "consumer": r.consumer,
            "fetches": r.fetches,
            "stores": r.stores,
            "bytes_fetched": r.bytes_fetched,
            "bytes_stored": r.bytes_stored,
            "seconds": r.fetch_seconds + r.store_seconds,
            "hit_rate": round(r.hit_rate, 6),
            "hedged": r.hedged,
            "sheds": r.sheds,
            "errors": r.errors,
        })
    return rows


class MessageBus:
    """The OSG message bus: fan-out to subscribed databases/aggregators."""

    def __init__(self) -> None:
        self.subscribers: List[Callable[[TransferRecord], None]] = []
        self.published = 0

    def subscribe(self, fn: Callable[[TransferRecord], None]) -> None:
        self.subscribers.append(fn)

    def publish(self, record: TransferRecord) -> None:
        self.published += 1
        for fn in self.subscribers:
            fn(record)


class MonitorCollector:
    """Joins login/open/close packets into transfer records.

    ``drop_rate`` simulates UDP loss; a close whose open or login packet was
    lost is counted in ``unjoined`` rather than crashing the pipeline.
    """

    def __init__(self, bus: Optional[MessageBus] = None,
                 drop_rate: float = 0.0, seed: int = 0) -> None:
        self.bus = bus or MessageBus()
        self.drop_rate = drop_rate
        self._rng = random.Random(seed)
        self._logins: Dict[tuple, UserLogin] = {}
        self._opens: Dict[tuple, FileOpen] = {}
        self.cache_gauges: Dict[str, CacheUsagePacket] = {}
        self.unjoined = 0
        self.packets = 0

    def _delivered(self) -> bool:
        self.packets += 1
        return self._rng.random() >= self.drop_rate

    # -- packet sinks (called by cache/origin servers) ----------------------
    def user_login(self, ev: UserLogin) -> None:
        if self._delivered():
            self._logins[(ev.server, ev.user_id)] = ev

    def file_open(self, ev: FileOpen) -> None:
        if self._delivered():
            self._opens[(ev.server, ev.file_id)] = ev

    def cache_usage(self, pkt: CacheUsagePacket) -> None:
        """Gauge sink: keep the newest usage/policy packet per server."""
        if not self._delivered():
            return
        prev = self.cache_gauges.get(pkt.server)
        if prev is None or pkt.time >= prev.time:
            self.cache_gauges[pkt.server] = pkt

    def policy_table(self) -> List[tuple]:
        """Aggregate the latest gauges by eviction policy.

        Rows: ``(policy, caches, hit_rate, evictions, ttl_expired,
        admission_rejects, usage_bytes)`` sorted by policy name — the
        monitoring-side view of how each eviction policy is performing
        across the fleet.
        """
        agg: Dict[str, List[float]] = {}
        for pkt in self.cache_gauges.values():
            row = agg.setdefault(pkt.policy, [0, 0, 0, 0, 0, 0, 0])
            row[0] += 1
            row[1] += pkt.hits
            row[2] += pkt.misses
            row[3] += pkt.evictions
            row[4] += pkt.ttl_expired
            row[5] += pkt.admission_rejects
            row[6] += pkt.usage_bytes
        out = []
        for policy in sorted(agg):
            n, h, m, ev, ttl, rej, usage = agg[policy]
            out.append((policy, int(n), h / (h + m) if h + m else 0.0,
                        int(ev), int(ttl), int(rej), int(usage)))
        return out

    def tier_table(self) -> List[tuple]:
        """Aggregate the latest gauges by hierarchy tier.

        Rows: ``(tier, caches, hit_rate, bytes_from_parent,
        usage_bytes)`` sorted by tier — the monitoring-side view of how
        each level of a cache hierarchy is absorbing load (edge tiers
        should show the hits, upper tiers the cache-to-cache fill).
        """
        agg: Dict[int, List[float]] = {}
        for pkt in self.cache_gauges.values():
            row = agg.setdefault(pkt.tier, [0, 0, 0, 0, 0])
            row[0] += 1
            row[1] += pkt.hits
            row[2] += pkt.misses
            row[3] += pkt.bytes_from_parent
            row[4] += pkt.usage_bytes
        out = []
        for tier in sorted(agg):
            n, h, m, fill, usage = agg[tier]
            out.append((tier, int(n), h / (h + m) if h + m else 0.0,
                        int(fill), int(usage)))
        return out

    def file_close(self, ev: FileClose, cache_hit: Optional[bool] = None) -> None:
        if not self._delivered():
            return
        opened = self._opens.pop((ev.server, ev.file_id), None)
        if opened is None:
            self.unjoined += 1
            return
        login = self._logins.get((ev.server, opened.user_id))
        record = TransferRecord(
            server=ev.server,
            path=opened.path,
            experiment=experiment_of(opened.path),
            client_host=login.client_host if login else "unknown",
            protocol=login.protocol if login else "unknown",
            file_size=opened.file_size,
            bytes_read=ev.bytes_read,
            bytes_written=ev.bytes_written,
            n_ops=ev.n_ops,
            start_time=opened.time,
            end_time=ev.time,
            cache_hit=cache_hit,
        )
        self.bus.publish(record)


class SweepAggregator:
    """Per-cell aggregates for parameter sweeps (monitoring-side view).

    Ingests one ``(params, summary)`` row per sweep cell — exactly what
    :class:`~repro.core.api.SweepCell` carries — and answers the
    questions an operator asks of a sweep: *how does a metric move along
    one axis, marginalized over the others?*  The tables sit next to
    :meth:`MonitorCollector.policy_table` as the aggregate surface the
    fleet benches publish.
    """

    def __init__(self) -> None:
        self.rows: List[tuple] = []   # (params, summary) per cell

    def add(self, params: Dict, summary: Dict) -> None:
        self.rows.append((dict(params), dict(summary)))

    def __len__(self) -> int:
        return len(self.rows)

    def axes(self) -> Dict[str, List]:
        """Observed axis values, in first-seen order per axis."""
        out: Dict[str, List] = {}
        for params, _ in self.rows:
            for k, v in params.items():
                vals = out.setdefault(k, [])
                if v not in vals:
                    vals.append(v)
        return out

    def marginal(self, axis: str, metric: str) -> List[tuple]:
        """``(value, cells, mean, min, max)`` of ``metric`` per value of
        ``axis``, marginalized over every other axis."""
        agg: Dict[object, List[float]] = {}
        order: List[object] = []
        for params, summary in self.rows:
            v = params.get(axis)
            if v not in agg:
                agg[v] = []
                order.append(v)
            agg[v].append(float(summary.get(metric, 0.0)))
        return [(v, len(agg[v]), sum(agg[v]) / len(agg[v]),
                 min(agg[v]), max(agg[v])) for v in order]

    def table(self, metric: str) -> List[tuple]:
        """One marginal row set per axis: ``(axis, value, cells, mean,
        min, max)`` — the flat per-cell aggregate a dashboard ingests."""
        out = []
        for axis in self.axes():
            for row in self.marginal(axis, metric):
                out.append((axis,) + row)
        return out

    POLICY_METRICS = ("hit_rate", "evictions", "bytes_evicted",
                      "admission_rejects")

    def policy_marginals(self, axis: Optional[str] = None) -> List[tuple]:
        """Per-eviction-policy marginals, the sweep-side sibling of
        :meth:`MonitorCollector.policy_table`.

        Rows: ``(policy, cells, hit_rate, evictions, bytes_evicted,
        admission_rejects)`` — means over every cell sharing the policy
        value, marginalized over all other axes.  ``axis`` defaults to
        the first observed axis whose name ends with
        ``"eviction_policy"`` (the sweep executor's spelling is
        ``"federation.eviction_policy"``).
        """
        if axis is None:
            axis = next((a for a in self.axes()
                         if a.endswith("eviction_policy")), None)
            if axis is None:
                return []
        means = {metric: {v: mean for v, _, mean, _, _
                          in self.marginal(axis, metric)}
                 for metric in self.POLICY_METRICS}
        return [(value, cells) + tuple(means[m][value]
                                       for m in self.POLICY_METRICS)
                for value, cells, *_ in self.marginal(axis, "hit_rate")]

    def hit_rate_curve(self, axis: Optional[str] = None,
                       metric: str = "hit_rate") -> List[tuple]:
        """``metric`` vs. capacity, one curve per sweep *column*.

        A column is one combination of every axis except ``axis``
        (default: the first observed axis whose name ends with
        ``"cache_capacity"`` — the sweep executor's spelling is
        ``"federation.cache_capacity"``).  Rows:
        ``(column_params, [(capacity, value), ...])`` with the curve
        sorted by capacity ascending — the validation table the
        planner's fitted ``H(C)`` curves are held against
        (``bench_plan``, notebooks)."""
        if axis is None:
            axis = next((a for a in self.axes()
                         if a.endswith("cache_capacity")), None)
            if axis is None:
                return []
        cols: Dict[tuple, List[tuple]] = {}
        order: List[tuple] = []
        for params, summary in self.rows:
            if axis not in params:
                continue
            key = tuple((k, v) for k, v in params.items() if k != axis)
            if key not in cols:
                cols[key] = []
                order.append(key)
            cols[key].append((params[axis],
                              float(summary.get(metric, 0.0))))
        return [(dict(key), sorted(cols[key])) for key in order]

    def model_residuals(self, predict: Callable[[Dict], Optional[float]],
                        metric: str = "hit_rate") -> List[tuple]:
        """Observed-vs-predicted validation table for a fitted model.

        ``predict`` maps a cell's params dict to the model's value for
        ``metric`` (return ``None`` to skip a cell — e.g. a policy the
        model does not cover).  Rows: ``(params, observed, predicted,
        residual)`` with ``residual = predicted − observed``; the
        forward-model acceptance gate asserts
        ``max(abs(residual)) <= 0.02`` over a held-out grid.  Plain
        numpy-free plumbing — the model side stays in
        :mod:`repro.kernels.cache_model`, monitoring only tabulates."""
        rows: List[tuple] = []
        for params, summary in self.rows:
            pred = predict(params)
            if pred is None:
                continue
            obs = float(summary.get(metric, 0.0))
            rows.append((dict(params), obs, float(pred),
                         float(pred) - obs))
        return rows


class UsageAggregator:
    """Builds Table 1 (usage by experiment) and Fig. 4 (usage over time)."""

    def __init__(self, bucket_seconds: float = 86400.0) -> None:
        self.bucket_seconds = bucket_seconds
        self.by_experiment: Dict[str, int] = defaultdict(int)
        self.by_bucket: Dict[int, int] = defaultdict(int)
        self.records = 0

    def __call__(self, rec: TransferRecord) -> None:
        self.records += 1
        moved = rec.bytes_read + rec.bytes_written
        self.by_experiment[rec.experiment] += moved
        self.by_bucket[int(rec.end_time // self.bucket_seconds)] += moved

    def usage_table(self) -> List[tuple]:
        """(experiment, bytes) sorted descending — the paper's Table 1."""
        return sorted(self.by_experiment.items(), key=lambda kv: -kv[1])

    def time_series(self) -> List[tuple]:
        return sorted(self.by_bucket.items())


# ---------------------------------------------------------------------------
# Streaming health gauges (control plane)


class DecayGauge:
    """Exponentially time-decayed counter: ``add`` events, ``read`` a rate.

    The stored value decays with time constant ``tau`` so the gauge
    tracks *recent* behaviour without keeping a window of samples.
    Reads are pure — ``read(now)`` never mutates state — and monotone
    non-increasing under silence, which the property suite checks.
    """

    def __init__(self, tau: float = 60.0) -> None:
        self.tau = float(tau)
        self.value = 0.0
        self.t = 0.0

    def read(self, now: float) -> float:
        if now <= self.t:
            return self.value
        return self.value * math.exp(-(now - self.t) / self.tau)

    def add(self, x: float, now: float) -> None:
        self.value = self.read(now) + x
        self.t = max(self.t, now)


class SpaceSavingTopK:
    """Misra-Gries/space-saving heavy hitters over a bounded key table.

    Tracks the (approximately) top-``k`` keys by total weight using O(k)
    memory: an unseen key evicts the current minimum and inherits its
    count as the over-estimate error bound.
    """

    def __init__(self, k: int = 8) -> None:
        self.k = max(1, int(k))
        self.counts: Dict[str, float] = {}
        self.errors: Dict[str, float] = {}

    def add(self, key: str, weight: float = 1.0) -> None:
        if key in self.counts:
            self.counts[key] += weight
            return
        if len(self.counts) < self.k:
            self.counts[key] = weight
            self.errors[key] = 0.0
            return
        victim = min(self.counts, key=lambda kk: (self.counts[kk], kk))
        floor = self.counts.pop(victim)
        self.errors.pop(victim)
        self.counts[key] = floor + weight
        self.errors[key] = floor

    def top(self, n: Optional[int] = None) -> List[tuple]:
        """``(key, count, error)`` sorted by count descending."""
        rows = sorted(self.counts.items(), key=lambda kv: (-kv[1], kv[0]))
        if n is not None:
            rows = rows[:n]
        return [(k, c, self.errors[k]) for k, c in rows]


class CacheHealthMonitor:
    """Per-cache streaming health: decayed error/latency rates + hitters.

    ``observe`` feeds one transfer outcome; ``unhealthy`` answers whether
    the decayed error rate (errors / samples over the last ~``tau``
    seconds) or the latency EWMA has crossed its threshold, given enough
    recent samples to mean anything.  ``demand`` tracks per-tenant bytes
    in a space-saving sketch so operators can name the heavy hitters.

    This class only *measures*; acting on it (``mark_down(auto=True)``)
    is the job of :class:`repro.core.controlplane.ControlPlane`.
    """

    LATENCY_ALPHA = 0.3

    def __init__(self, tau: float = 60.0, topk: int = 8) -> None:
        self.tau = float(tau)
        self._errors: Dict[str, DecayGauge] = {}
        self._totals: Dict[str, DecayGauge] = {}
        self._latency: Dict[str, float] = {}
        self.hitters = SpaceSavingTopK(topk)

    def _gauge(self, table: Dict[str, DecayGauge], cache: str) -> DecayGauge:
        g = table.get(cache)
        if g is None:
            g = DecayGauge(self.tau)
            table[cache] = g
        return g

    def observe(self, cache: str, ok: bool, latency: float,
                now: float) -> None:
        self._gauge(self._totals, cache).add(1.0, now)
        if not ok:
            self._gauge(self._errors, cache).add(1.0, now)
        elif latency > 0:
            prev = self._latency.get(cache)
            if prev is None:
                self._latency[cache] = latency
            else:
                a = self.LATENCY_ALPHA
                self._latency[cache] = a * latency + (1 - a) * prev

    def demand(self, tenant: str, nbytes: float = 0.0) -> None:
        self.hitters.add(tenant, max(float(nbytes), 1.0))

    def samples(self, cache: str, now: float) -> float:
        g = self._totals.get(cache)
        return g.read(now) if g is not None else 0.0

    def error_rate(self, cache: str, now: float) -> float:
        total = self.samples(cache, now)
        if total <= 0:
            return 0.0
        g = self._errors.get(cache)
        errors = g.read(now) if g is not None else 0.0
        return min(1.0, errors / total)

    def latency(self, cache: str) -> float:
        return self._latency.get(cache, 0.0)

    def unhealthy(self, cache: str, now: float, error_threshold: float,
                  min_samples: float = 4.0,
                  latency_threshold: Optional[float] = None) -> bool:
        if self.samples(cache, now) < min_samples:
            return False
        if self.error_rate(cache, now) >= error_threshold:
            return True
        if (latency_threshold is not None
                and self.latency(cache) >= latency_threshold):
            return True
        return False

    def reset(self, cache: str) -> None:
        self._errors.pop(cache, None)
        self._totals.pop(cache, None)
        self._latency.pop(cache, None)

    def top_tenants(self, n: int = 5) -> List[tuple]:
        return self.hitters.top(n)
