"""StashCache — the paper's contribution as a composable library.

A distributed caching federation: data origins, redirectors, caches and
clients (paper §3), plus the site-HTTP-proxy baseline it is evaluated
against (§4.1), the monitoring pipeline (§3.2), write-back caching (§6
future work) and a fluid-flow discrete-event simulator for contended-
network evaluation.  The federation is accessed through one typed data
plane (``repro.core.api``): ``DataPlane`` with ``AnalyticPlane`` /
``SimulatedPlane`` engines and declarative ``ScenarioSpec`` +
``run_scenario``.  ``repro.data`` builds the JAX training data pipeline
on top of this package; ``repro.train.checkpoint`` uses it for
restart-storm checkpoint distribution.
"""
from .api import (AnalyticPlane, ClientPlane, DataPlane, FetchRequest,
                  FetchResult, ScenarioReport, ScenarioSpec, SimulatedPlane,
                  StatResult, SweepCell, SweepReport, SweepSpec,
                  WorkloadSpec, run_scenario, run_sweep)
from .cache import CacheServer, CacheStats
from .chunk import (DEFAULT_CHUNK_SIZE, ChunkRef, ObjectMeta, Payload,
                    chunk_object, fnv1a64, synthetic_object)
from .client import LocalCache, StashClient
from .controlplane import (AdmissionQueue, AnalyticQueue, CircuitBreaker,
                           ControlPlane, ControlPlaneSpec, ControlStats,
                           fair_shares)
from .federation import (Federation, FederationSpec, SiteSpec, TierSpec,
                         build_fleet_federation, build_osdf_federation,
                         build_osg_federation, site_tiers,
                         OSG_SITE_PROFILES)
from .indexer import Catalog, Indexer
from .monitoring import (CacheHealthMonitor, CacheUsagePacket, DecayGauge,
                         FetchRollup, FileClose, FileOpen, MessageBus,
                         MonitorCollector, SpaceSavingTopK, SweepAggregator,
                         TransferRecord, UsageAggregator, UserLogin,
                         consumer_table, experiment_of)
from .namespace import Namespace
from .origin import ChunkStore, Origin
from .planner import (PlannerSpec, PlanReport, apply_capacities,
                      groups_for_federation, plan_capacity, predict,
                      verify_plan)
from .policies import (AdmissionPolicy, EVICTION_POLICIES, EvictionPolicy,
                       FIFOPolicy, LFUPolicy, LRUPolicy, SizeAwareAdmission,
                       TTLPolicy, make_eviction_policy)
from .proxy import HTTPProxy
from .redirector import Redirector, RedirectorGroup, RedirectorPair
from .ring import CacheGroup, GroupStats, HashRing
from .routing import (RANKING_POLICIES, ProbeRankingPolicy, RankingPolicy,
                      StaticRankingPolicy, make_ranking_policy,
                      ranked_caches)
from .simclient import (OutageEvent, OutageSchedule, ScenarioEngine,
                        SimStashClient, apply_outage, first_of,
                        tier_tallies)
from .simulator import (DownloadResult, FluidFlowSim, direct_download,
                        fetch_chunks, proxy_download, sparse_flow_problem,
                        stash_download)
from .topology import BandwidthProfile, Coord, GeoIPService, Link, Node, Topology
from .transfer import NetworkModel, TransferStats
from .workload import (FILESIZE_PERCENTILES, PAPER_TABLE3, PROBE_10GB,
                       USAGE_BY_EXPERIMENT, AccessRequest, PercentileSampler,
                       abusive_workload, checkpoint_restart_workload,
                       dataloader_workload, evaluation_fileset,
                       flash_crowd_workload, generate_workload,
                       herd_workload, shard_serving_workload, split_bytes,
                       storm_workload)
from .writeback import WritebackCache

__all__ = [n for n in dir() if not n.startswith("_")]
