"""StashCache cache servers (paper §3).

Regional caches capture client data requests, check local storage, and on a
miss locate the data via the redirector and pull it from the origin before
serving the client.  Space is transient: the server may reclaim (evict) any
resident chunk without breaking workflows — that is the property that makes
opportunistic *storage* viable as a *cache*.

Design split:
  * pure state-machine methods (``lookup`` / ``admit`` / ``evict_until``)
    are reused verbatim by the discrete-event simulator, which supplies its
    own timing/contention; and
  * the networked path (``get_chunk`` / ``fetch_object``) uses the
    uncontended :class:`~repro.core.transfer.NetworkModel` and emits
    monitoring packets, serving the functional data loader.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict
from typing import Dict, Optional, Set, Tuple

from .chunk import ObjectMeta, Payload
from .monitoring import FileClose, FileOpen, MonitorCollector, UserLogin
from .redirector import RedirectorPair
from .topology import Node
from .transfer import NetworkModel, TransferStats


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_served: int = 0
    bytes_from_origin: int = 0
    bytes_evicted: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CacheServer:
    """An LRU, chunk-granular cache server."""

    _ids = itertools.count(1)

    def __init__(self, name: str, node: Node, capacity_bytes: int,
                 redirectors: Optional[RedirectorPair] = None,
                 net: Optional[NetworkModel] = None,
                 monitor: Optional[MonitorCollector] = None,
                 mem_object_max: float = 4e9,
                 disk_bw: float = 0.0) -> None:
        self.name = name
        self.node = node
        self.capacity_bytes = capacity_bytes
        self.mem_object_max = mem_object_max
        self.disk_bw = disk_bw
        self.redirectors = redirectors
        self.net = net
        self.monitor = monitor
        self.available = True  # failure injection point
        # (path, chunk_index) -> Payload, in LRU order (front = coldest).
        self._lru: "OrderedDict[Tuple[str, int], Payload]" = OrderedDict()
        self._pinned: Set[Tuple[str, int]] = set()
        self._metas: Dict[str, ObjectMeta] = {}
        self.usage_bytes = 0
        self.stats = CacheStats()
        self._file_ids = itertools.count(1)
        self._user_ids = itertools.count(1)

    # ------------------------------------------------------------------
    # Pure cache state machine (shared with the simulator)
    # ------------------------------------------------------------------
    def lookup(self, path: str, index: int) -> Optional[Payload]:
        key = (path, index)
        payload = self._lru.get(key)
        if payload is None:
            self.stats.misses += 1
            return None
        self._lru.move_to_end(key)
        self.stats.hits += 1
        return payload

    def resident(self, path: str, index: int) -> bool:
        """Peek without perturbing LRU order or counters."""
        return (path, index) in self._lru

    def object_resident(self, meta: ObjectMeta) -> bool:
        return all(self.resident(meta.path, i) for i in range(meta.num_chunks))

    def admit(self, path: str, index: int, payload: Payload) -> None:
        """Insert a chunk, evicting LRU chunks to make room.  In-flight
        (pinned) chunks are never evicted."""
        key = (path, index)
        if key in self._lru:
            self._lru.move_to_end(key)
            return
        self.evict_until(payload.size)
        self._lru[key] = payload
        self.usage_bytes += payload.size

    def evict_until(self, incoming: int) -> None:
        while self.usage_bytes + incoming > self.capacity_bytes and self._lru:
            victim = next((k for k in self._lru if k not in self._pinned), None)
            if victim is None:
                break  # everything pinned; over-commit rather than deadlock
            payload = self._lru.pop(victim)
            self.usage_bytes -= payload.size
            self.stats.evictions += 1
            self.stats.bytes_evicted += payload.size

    def serve_rate_cap(self, object_size: int) -> float:
        """xrootd disk caches stream large objects at disk speed."""
        if self.disk_bw and object_size > self.mem_object_max:
            return self.disk_bw
        return 0.0

    def pin(self, path: str, index: int) -> None:
        self._pinned.add((path, index))

    def unpin(self, path: str, index: int) -> None:
        self._pinned.discard((path, index))

    def drop(self, path: str, index: int) -> None:
        payload = self._lru.pop((path, index), None)
        if payload is not None:
            self.usage_bytes -= payload.size

    def corrupt(self, path: str, index: int) -> None:
        """Bit-flip a resident chunk (integrity tests)."""
        key = (path, index)
        if key in self._lru:
            self._lru[key] = self._lru[key].corrupted()

    # ------------------------------------------------------------------
    # Networked path (functional federation)
    # ------------------------------------------------------------------
    def locate_meta(self, path: str) -> Optional[ObjectMeta]:
        if path in self._metas:
            return self._metas[path]
        origin = self.redirectors.locate(path) if self.redirectors else None
        if origin is None:
            return None
        meta = origin.meta(path)
        self._metas[path] = meta
        return meta

    def get_chunk(self, client_node: str, path: str, index: int,
                  streams: int = 1) -> Tuple[Optional[Payload], TransferStats]:
        """Serve one chunk to a client; on miss, locate + pull from origin.

        Time accounting covers: (miss only) redirector RPC + origin→cache
        transfer, then cache→client transfer.
        """
        if not self.available:
            raise ConnectionError(f"cache {self.name} unavailable")
        stats = TransferStats(source=self.name)
        payload = self.lookup(path, index)
        if payload is None:
            origin = self.redirectors.locate(path) if self.redirectors else None
            if origin is None:
                return None, stats
            # redirector round-trip, then chunk pull over the WAN/DCN.
            redirector_node = self.redirectors.members[0].node.name
            stats.seconds += self.net.rpc_time(self.node.name, redirector_node)
            self.pin(path, index)
            try:
                payload = origin.read_chunk(path, index)
                stats.seconds += self.net.transfer_time(
                    origin.node.name, self.node.name, payload.size,
                    streams=max(streams, 4))
                stats.bytes_from_origin = 0  # tracked on CacheStats below
                self.stats.bytes_from_origin += payload.size
                self.admit(path, index, payload)
            finally:
                self.unpin(path, index)
            stats.cache_misses += 1
        else:
            stats.cache_hits += 1
        # cache → client hop (disk-bound for large objects).
        meta = self._metas.get(path)
        obj_size = meta.size if meta is not None else payload.size
        stats.seconds += self.net.transfer_time(
            self.node.name, client_node, payload.size, streams=streams,
            rate_cap=self.serve_rate_cap(obj_size))
        stats.bytes += payload.size
        stats.chunks += 1
        self.stats.bytes_served += payload.size
        return payload, stats

    # ------------------------------------------------------------------
    # Monitoring hooks (paper §3.2)
    # ------------------------------------------------------------------
    def open_session(self, client_host: str, protocol: str, now: float,
                     ipv6: bool = False) -> int:
        user_id = next(self._user_ids)
        if self.monitor:
            self.monitor.user_login(UserLogin(self.name, user_id, client_host,
                                              protocol, ipv6, now))
        return user_id

    def open_file(self, user_id: int, meta: ObjectMeta, now: float) -> int:
        file_id = next(self._file_ids)
        if self.monitor:
            self.monitor.file_open(FileOpen(self.name, file_id, user_id,
                                            meta.path, meta.size, now))
        return file_id

    def close_file(self, file_id: int, bytes_read: int, n_ops: int,
                   now: float, cache_hit: Optional[bool] = None,
                   bytes_written: int = 0) -> None:
        if self.monitor:
            self.monitor.file_close(
                FileClose(self.name, file_id, bytes_read, bytes_written,
                          n_ops, now), cache_hit=cache_hit)
