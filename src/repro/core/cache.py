"""StashCache cache servers (paper §3).

Regional caches capture client data requests, check local storage, and on a
miss locate the data via the redirector and pull it from the origin before
serving the client.  Space is transient: the server may reclaim (evict) any
resident chunk without breaking workflows — that is the property that makes
opportunistic *storage* viable as a *cache*.

Design split:
  * pure state-machine methods (``lookup`` / ``admit`` / ``evict_until``)
    are reused verbatim by the discrete-event simulator, which supplies its
    own timing/contention; and
  * the networked path (``get_chunk`` / ``fetch_object``) uses the
    uncontended :class:`~repro.core.transfer.NetworkModel` and emits
    monitoring packets, serving the functional data loader.

Eviction and admission are pluggable (:mod:`repro.core.policies`): the
seed's LRU remains the default, with LFU / TTL / FIFO variants and a
size-aware admission filter selectable per cache (and per site, via
:class:`~repro.core.federation.SiteSpec`).  Policy behaviour is surfaced
through the monitoring pipeline as :class:`~repro.core.monitoring.
CacheUsagePacket` gauges.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Set, Tuple, Union

from .chunk import ObjectMeta, Payload
from .monitoring import (CacheUsagePacket, FileClose, FileOpen,
                         MonitorCollector, UserLogin)
from .policies import (AdmissionPolicy, EvictionPolicy, make_eviction_policy)
from .redirector import RedirectorPair
from .topology import Node
from .transfer import NetworkModel, TransferStats


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_served: int = 0
    bytes_from_origin: int = 0
    bytes_from_parent: int = 0   # cache-to-cache fill (tiered federations)
    bytes_evicted: int = 0
    ttl_expired: int = 0
    admission_rejects: int = 0
    oversize_rejects: int = 0
    replacements: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CacheServer:
    """A chunk-granular cache server with a pluggable eviction policy."""

    _ids = itertools.count(1)

    def __init__(self, name: str, node: Node, capacity_bytes: int,
                 redirectors: Optional[RedirectorPair] = None,
                 net: Optional[NetworkModel] = None,
                 monitor: Optional[MonitorCollector] = None,
                 mem_object_max: float = 4e9,
                 disk_bw: float = 0.0,
                 policy: Union[str, EvictionPolicy] = "lru",
                 ttl_seconds: float = 3600.0,
                 admission: Optional[AdmissionPolicy] = None) -> None:
        self.name = name
        self.node = node
        self.capacity_bytes = capacity_bytes
        self.mem_object_max = mem_object_max
        self.disk_bw = disk_bw
        self.redirectors = redirectors
        self.net = net
        self.monitor = monitor
        self.available = True  # failure injection point
        # Cache hierarchy (multi-tier CDN): a cache with a parent group
        # fills misses from the parent tier's ring before the origin.
        # Wired by Federation._build from SiteSpec.parent; tier 1 = edge.
        self.parent_group = None  # Optional[repro.core.ring.CacheGroup]
        self.tier = 1
        self.policy = make_eviction_policy(policy, ttl_seconds)
        self.admission = admission or AdmissionPolicy()
        # (path, chunk_index) -> Payload.  Pure storage: victim ordering
        # lives entirely in the policy object.  (Kept under the historic
        # `_lru` name — external invariant checks sum over it.)
        self._lru: Dict[Tuple[str, int], Payload] = {}
        self._pinned: Set[Tuple[str, int]] = set()
        self._metas: Dict[str, ObjectMeta] = {}
        self.usage_bytes = 0
        self.stats = CacheStats()
        self.clock = 0.0  # advanced by callers (simulator / client `now`)
        self._file_ids = itertools.count(1)
        self._user_ids = itertools.count(1)

    # ------------------------------------------------------------------
    # Pure cache state machine (shared with the simulator)
    # ------------------------------------------------------------------
    def tick(self, now: float) -> None:
        """Advance the cache's notion of time (TTL policies use it)."""
        if now > self.clock:
            self.clock = now

    def lookup(self, path: str, index: int) -> Optional[Payload]:
        key = (path, index)
        payload = self._lru.get(key)
        if payload is None:
            self.stats.misses += 1
            return None
        if self.policy.expired(key, self.clock):
            self._remove(key)
            self.stats.ttl_expired += 1
            self.stats.misses += 1
            return None
        self.policy.on_access(key, self.clock)
        self.stats.hits += 1
        return payload

    def resident(self, path: str, index: int) -> bool:
        """Peek without perturbing victim order or counters."""
        key = (path, index)
        return key in self._lru and not self.policy.expired(key, self.clock)

    def object_resident(self, meta: ObjectMeta) -> bool:
        return all(self.resident(meta.path, i) for i in range(meta.num_chunks))

    def admit(self, path: str, index: int, payload: Payload,
              object_size: Optional[int] = None,
              force: bool = False) -> bool:
        """Insert a chunk, evicting cold chunks to make room.  In-flight
        (pinned) chunks are never evicted.  Returns False when the
        admission policy refuses the object (size-aware admission) or
        when the payload alone exceeds ``capacity_bytes`` (it can never
        fit); ``force`` bypasses both (write-back dirty data must land,
        even over-committed)."""
        key = (path, index)
        if key in self._lru:
            if self.policy.expired(key, self.clock):
                self._remove(key)  # stale entry: fall through to re-admit
                self.stats.ttl_expired += 1
            elif (self._lru[key].size == payload.size
                  and self._lru[key].digest == payload.digest):
                # Identical replica (collapsed-forwarding re-admit race):
                # a pure touch.
                self.policy.on_access(key, self.clock)
                return True
            else:
                # Re-published chunk: the resident copy is stale.  Serving
                # it would hand out old bytes and leave any size delta
                # unaccounted — replace it (the LocalCache.put fix):
                # remove without counting an eviction, then fall through
                # to a fresh admission of the new payload.
                self._remove(key)
                self.stats.replacements += 1
        if object_size is None:
            meta = self._metas.get(path)
            object_size = meta.size if meta is not None else payload.size
        if not force and not self.admission.admit(
                key, object_size, payload.size,
                self.capacity_bytes, self.usage_bytes):
            self.stats.admission_rejects += 1
            return False
        if not force and payload.size > self.capacity_bytes:
            # Refusing outright beats draining the whole cache and then
            # over-committing: the chunk can never fit, and inserting it
            # anyway would leave usage_bytes > capacity_bytes forever.
            self.stats.oversize_rejects += 1
            return False
        self.evict_until(payload.size)
        self._lru[key] = payload
        self.policy.on_admit(key, payload.size, self.clock)
        self.usage_bytes += payload.size
        return True

    def evict_until(self, incoming: int) -> None:
        while self.usage_bytes + incoming > self.capacity_bytes and self._lru:
            victim = self.policy.victim(self._pinned)
            if victim is None:
                break  # everything pinned; over-commit rather than deadlock
            payload = self._remove(victim)
            self.stats.evictions += 1
            self.stats.bytes_evicted += payload.size

    def _remove(self, key: Tuple[str, int]) -> Optional[Payload]:
        payload = self._lru.pop(key, None)
        if payload is not None:
            self.usage_bytes -= payload.size
            self.policy.on_remove(key)
        return payload

    def serve_rate_cap(self, object_size: int) -> float:
        """xrootd disk caches stream large objects at disk speed."""
        if self.disk_bw and object_size > self.mem_object_max:
            return self.disk_bw
        return 0.0

    def pin(self, path: str, index: int) -> None:
        self._pinned.add((path, index))

    def unpin(self, path: str, index: int) -> None:
        self._pinned.discard((path, index))

    def drop(self, path: str, index: int) -> None:
        self._remove((path, index))

    def clear(self) -> None:
        """Cold restart: lose every resident chunk (and pin) without
        counting evictions — the disk came back empty, nothing was
        *chosen* as a victim.  Hit/miss history and located metas keep
        their values; only storage state resets."""
        self._pinned.clear()
        for key in list(self._lru):
            self._remove(key)

    def corrupt(self, path: str, index: int) -> None:
        """Bit-flip a resident chunk (integrity tests)."""
        key = (path, index)
        if key in self._lru:
            self._lru[key] = self._lru[key].corrupted()

    # ------------------------------------------------------------------
    # Networked path (functional federation)
    # ------------------------------------------------------------------
    def locate_meta(self, path: str) -> Optional[ObjectMeta]:
        if path in self._metas:
            return self._metas[path]
        origin = self.redirectors.locate(path) if self.redirectors else None
        if origin is None:
            return None
        meta = origin.meta(path)
        self._metas[path] = meta
        return meta

    def parent_caches(self, path: str):
        """Live parent-tier fill targets for ``path``, in ring order."""
        if self.parent_group is None:
            return []
        return [c for c in self.parent_group.fill_chain(path)
                if c.available and c is not self]

    def _obtain(self, path: str, index: int, streams: int,
                object_size: Optional[int] = None
                ) -> Tuple[Optional[Payload], float, bool]:
        """Ensure one chunk is in hand, counting a hit or miss here.

        On a miss the chunk fills from the parent tier's ring owner when
        one is alive (cache-to-cache fill; the parent recursively resolves
        *its* miss, so only the top tier pays the redirector RPC + origin
        pull), falling back to the flat redirector → origin path when
        there is no live parent.  Returns ``(payload, upstream_seconds,
        hit)`` — upstream_seconds excludes the cache → client hop.
        """
        payload = self.lookup(path, index)
        if payload is not None:
            return payload, 0.0, True
        parents = self.parent_caches(path)
        if parents:
            parent = parents[0]
            parent.tick(self.clock)
            # The fill request carries the child's object-size knowledge
            # (size-aware admission at the parent sees what the child saw).
            meta = self._metas.get(path)
            up_size = object_size if object_size is not None else (
                meta.size if meta is not None else None)
            self.pin(path, index)
            try:
                payload, secs, _ = parent._obtain(path, index, streams,
                                                  object_size=up_size)
                if payload is None:
                    return None, secs, False
                secs += self.net.transfer_time(
                    parent.node.name, self.node.name, payload.size,
                    streams=max(streams, 4))
                parent.stats.bytes_served += payload.size
                self.stats.bytes_from_parent += payload.size
                self.admit(path, index, payload, object_size=object_size)
            finally:
                self.unpin(path, index)
            return payload, secs, False
        origin = self.redirectors.locate(path) if self.redirectors else None
        if origin is None:
            return None, 0.0, False
        # redirector round-trip, then chunk pull over the WAN/DCN.
        redirector_node = self.redirectors.members[0].node.name
        secs = self.net.rpc_time(self.node.name, redirector_node)
        self.pin(path, index)
        try:
            payload = origin.read_chunk(path, index)
            secs += self.net.transfer_time(
                origin.node.name, self.node.name, payload.size,
                streams=max(streams, 4))
            self.stats.bytes_from_origin += payload.size
            self.admit(path, index, payload, object_size=object_size)
        finally:
            self.unpin(path, index)
        return payload, secs, False

    def get_chunk(self, client_node: str, path: str, index: int,
                  streams: int = 1) -> Tuple[Optional[Payload], TransferStats]:
        """Serve one chunk to a client; on miss, locate + pull from the
        parent tier (if any) or the origin.

        Time accounting covers: (miss only) the upstream fill — parent →
        cache transfer, plus the parent tier's own redirector RPC +
        origin pull when the parent missed too — then the cache → client
        transfer.
        """
        if not self.available:
            raise ConnectionError(f"cache {self.name} unavailable")
        stats = TransferStats(source=self.name)
        payload, upstream, hit = self._obtain(path, index, streams)
        if payload is None:
            return None, stats
        if hit:
            stats.cache_hits += 1
        else:
            stats.seconds += upstream
            stats.cache_misses += 1
        # cache → client hop (disk-bound for large objects).
        meta = self._metas.get(path)
        obj_size = meta.size if meta is not None else payload.size
        stats.seconds += self.net.transfer_time(
            self.node.name, client_node, payload.size, streams=streams,
            rate_cap=self.serve_rate_cap(obj_size))
        stats.bytes += payload.size
        stats.chunks += 1
        self.stats.bytes_served += payload.size
        return payload, stats

    # ------------------------------------------------------------------
    # Monitoring hooks (paper §3.2)
    # ------------------------------------------------------------------
    def open_session(self, client_host: str, protocol: str, now: float,
                     ipv6: bool = False) -> int:
        user_id = next(self._user_ids)
        if self.monitor:
            self.monitor.user_login(UserLogin(self.name, user_id, client_host,
                                              protocol, ipv6, now))
        return user_id

    def open_file(self, user_id: int, meta: ObjectMeta, now: float) -> int:
        file_id = next(self._file_ids)
        if self.monitor:
            self.monitor.file_open(FileOpen(self.name, file_id, user_id,
                                            meta.path, meta.size, now))
        return file_id

    def close_file(self, file_id: int, bytes_read: int, n_ops: int,
                   now: float, cache_hit: Optional[bool] = None,
                   bytes_written: int = 0) -> None:
        if self.monitor:
            self.monitor.file_close(
                FileClose(self.name, file_id, bytes_read, bytes_written,
                          n_ops, now), cache_hit=cache_hit)

    def report_usage(self, now: Optional[float] = None) -> CacheUsagePacket:
        """Emit a policy/usage gauge to the monitoring collector.

        This is the per-policy counter surface: hit/miss/eviction totals,
        TTL expiries and admission rejects, keyed by policy name, so the
        aggregators can build the policy-comparison tables the fleet
        benches report.
        """
        pkt = CacheUsagePacket(
            server=self.name, policy=self.policy.name,
            usage_bytes=self.usage_bytes, capacity_bytes=self.capacity_bytes,
            hits=self.stats.hits, misses=self.stats.misses,
            evictions=self.stats.evictions,
            bytes_evicted=self.stats.bytes_evicted,
            ttl_expired=self.stats.ttl_expired,
            admission_rejects=self.stats.admission_rejects,
            oversize_rejects=self.stats.oversize_rejects,
            tier=self.tier,
            bytes_from_parent=self.stats.bytes_from_parent,
            time=self.clock if now is None else now)
        if self.monitor:
            self.monitor.cache_usage(pkt)
        return pkt
