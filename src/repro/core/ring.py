"""Consistent-hash HA cache groups (paper §2's redirector-pair idiom,
generalized).

The paper keeps the *redirector* highly available with a two-member
round-robin pair.  At fleet scale the caches themselves need the same
treatment: a site (or region) runs a *group* of cache servers, and clients
route each object to a group member with consistent hashing, so

* the working set is partitioned across members (no duplicate residency,
  N× the effective capacity), and
* a dead member degrades to the next server on the ring — only its ~1/N
  share of the keyspace remaps, and requests fail over to a server that
  is warm for the remapped keys' neighbours rather than to the origin.

``HashRing`` is the generic structure (FNV-1a over virtual nodes — the
same hash family as the chunk checksums); ``CacheGroup`` binds it to
:class:`~repro.core.cache.CacheServer` members with liveness-aware
routing and failover accounting.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

from .chunk import fnv1a64

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .cache import CacheServer

DEFAULT_VNODES = 64


class HashRing:
    """Consistent hashing with virtual nodes.

    Each member is hashed at ``vnodes`` points on a 64-bit ring; a key is
    owned by the first member clockwise of its hash.  ``successors``
    returns distinct members in ring order, which is the failover chain.
    """

    def __init__(self, members: Sequence[str] = (),
                 vnodes: int = DEFAULT_VNODES) -> None:
        self.vnodes = vnodes
        self._points: List[int] = []       # sorted vnode hashes
        self._owner: Dict[int, str] = {}   # vnode hash -> member
        self._members: List[str] = []
        for m in members:
            self.add(m)

    def __len__(self) -> int:
        return len(self._members)

    @property
    def members(self) -> List[str]:
        return list(self._members)

    @staticmethod
    def _hash(key: str) -> int:
        # FNV-1a alone clusters sequential keys (the trailing characters
        # barely reach the high bits, and ring placement *is* the high
        # bits); run it through a murmur3-style avalanche finalizer.
        h = fnv1a64(key.encode())
        h ^= h >> 33
        h = (h * 0xFF51AFD7ED558CCD) & 0xFFFFFFFFFFFFFFFF
        h ^= h >> 33
        h = (h * 0xC4CEB9FE1A85EC53) & 0xFFFFFFFFFFFFFFFF
        return h ^ (h >> 33)

    def add(self, member: str) -> None:
        if member in self._members:
            return
        self._members.append(member)
        for v in range(self.vnodes):
            h = self._hash(f"{member}#{v}")
            idx = bisect.bisect_left(self._points, h)
            self._points.insert(idx, h)
            self._owner[h] = member

    def remove(self, member: str) -> None:
        if member not in self._members:
            return
        self._members.remove(member)
        for v in range(self.vnodes):
            h = self._hash(f"{member}#{v}")
            idx = bisect.bisect_left(self._points, h)
            if idx < len(self._points) and self._points[idx] == h \
                    and self._owner.get(h) == member:
                self._points.pop(idx)
                del self._owner[h]

    def owner(self, key: str) -> Optional[str]:
        chain = self.successors(key, 1)
        return chain[0] if chain else None

    def successors(self, key: str, k: Optional[int] = None) -> List[str]:
        """First ``k`` distinct members clockwise of ``key`` (all by
        default) — the primary plus its failover chain."""
        if not self._points:
            return []
        want = len(self._members) if k is None else min(k, len(self._members))
        start = bisect.bisect_right(self._points, self._hash(key))
        out: List[str] = []
        for i in range(len(self._points)):
            m = self._owner[self._points[(start + i) % len(self._points)]]
            if m not in out:
                out.append(m)
                if len(out) == want:
                    break
        return out


@dataclasses.dataclass
class GroupStats:
    routes: int = 0
    failovers: int = 0    # primary dead → served by a ring successor
    remapped_keys: int = 0  # dead members skipped along the chain
    outages: int = 0      # members marked down (storm/blackout/upgrade)
    recoveries: int = 0   # members marked back up
    cold_restarts: int = 0  # recoveries that came back with empty storage
    auto_outages: int = 0   # subset of outages fired by health gauges
    auto_recoveries: int = 0  # subset of recoveries fired by health probes


class CacheGroup:
    """An HA group of cache servers behind one consistent-hash ring."""

    def __init__(self, name: str, members: Sequence["CacheServer"],
                 vnodes: int = DEFAULT_VNODES) -> None:
        self.name = name
        self.caches: Dict[str, "CacheServer"] = {c.name: c for c in members}
        self.ring = HashRing(list(self.caches), vnodes=vnodes)
        self.stats = GroupStats()

    @property
    def members(self) -> List["CacheServer"]:
        return list(self.caches.values())

    def add(self, cache: "CacheServer") -> None:
        self.caches[cache.name] = cache
        self.ring.add(cache.name)

    def remove(self, name: str) -> None:
        self.caches.pop(name, None)
        self.ring.remove(name)

    def alive(self) -> List["CacheServer"]:
        return [c for c in self.caches.values() if c.available]

    def route(self, path: str, exclude: Sequence[str] = (),
              live_only: bool = False,
              count_stats: bool = True) -> List["CacheServer"]:
        """Members in ring order for ``path`` — element 0 is the owner,
        the rest its failover chain.  A dead primary counts one failover
        (the key remaps to the next ring member).  Callers that do their
        own liveness handling (the client's retry loop) take the full
        chain; ``live_only`` pre-filters it.  Rankings that merely
        *include* this group without serving from it pass
        ``count_stats=False`` so fleet-wide reads don't inflate every
        group's counters."""
        if count_stats:
            self.stats.routes += 1
        chain = [self.caches[n] for n in self.ring.successors(path)
                 if n not in exclude]
        if count_stats and chain and not chain[0].available:
            # Failover depth: how many dead ring members the key skips
            # before reaching a live one (an outage storm can knock out
            # several consecutive successors at once).
            dead = 0
            for c in chain:
                if c.available:
                    break
                dead += 1
            self.stats.failovers += 1
            self.stats.remapped_keys += dead
        if live_only:
            return [c for c in chain if c.available]
        return chain

    def fill_chain(self, path: str) -> List["CacheServer"]:
        """Ring-ordered fill targets for a *child-tier* cache miss.

        Cache-to-cache fill uses the same consistent-hash ownership as
        client routing — so every child below this group funnels a given
        path to the same parent member (one parent copy per object, N×
        effective parent capacity) — but does not count route/failover
        stats: a fill is upstream traffic, not a client route.  Liveness
        filtering is the caller's job (it needs to see dead members to
        fall through to the origin deliberately).
        """
        return self.route(path, count_stats=False)

    def mark_down(self, name: str, auto: bool = False) -> None:
        """Outage injection: the member stays on the ring (its keyspace
        share fails over along the chain) but stops serving.  ``auto``
        tags gauge-driven demotions (health monitor) separately from
        scripted schedule entries; the available-guard already dedupes
        overlapping triggers — a member down is down once, whichever
        trigger fired first gets the counter."""
        cache = self.caches.get(name)
        if cache is not None and cache.available:
            cache.available = False
            self.stats.outages += 1
            if auto:
                self.stats.auto_outages += 1

    def mark_up(self, name: str, cold: bool = False,
                auto: bool = False) -> None:
        """Recovery; ``cold`` models a restart that lost its disk (the
        member returns owning its old keyspace but holding nothing)."""
        cache = self.caches.get(name)
        if cache is None:
            return
        if not cache.available:
            self.stats.recoveries += 1
            if cold:
                self.stats.cold_restarts += 1
                cache.clear()
            if auto:
                self.stats.auto_recoveries += 1
            cache.available = True

    def locus(self) -> Optional["CacheServer"]:
        """A representative member, for distance ranking of the group."""
        members = self.members
        return members[0] if members else None
