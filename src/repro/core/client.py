"""Federation clients: CVMFS and ``stashcp`` (paper §3.1).

CVMFS gives a read-only POSIX view: partial reads fetch only the 24 MB
chunks an application touches, each verified against the catalog checksum,
with a small (default 1 GB) local LRU cache — deliberately small because
the working set won't fit a worker's disk and the nearby cache is assumed
fast.  Its GeoIP locator is built in (no per-read discovery cost).

``stashcp`` copies whole files with a three-way fallback chain:
  (1) CVMFS if available on the host,
  (2) the XRootD client (efficient multi-stream transfers),
  (3) plain curl against the cache's HTTP endpoint (fewest features).
Its startup is *slower* than a proxy download because the nearest cache
must be discovered via a remote GeoIP query — the small-file penalty the
paper measures (Fig. 8).

Beyond the paper: hedged fetches — if the nearest cache is down (or a
deadline passes in simulator-driven runs) the client retries against the
next-nearest cache, which is our straggler-mitigation hook for restart
storms on a TPU fleet.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .cache import CacheServer
from .chunk import ObjectMeta, Payload
from .indexer import Catalog
from .ring import CacheGroup
from .routing import RankingPolicy, make_ranking_policy, ranked_caches
from .topology import GeoIPService, Node
from .transfer import NetworkModel, TransferStats


@dataclasses.dataclass
class ClientStats:
    reads: int = 0
    copies: int = 0
    local_hits: int = 0
    local_misses: int = 0
    checksum_failures: int = 0
    cache_failovers: int = 0
    hedged_fetches: int = 0
    origin_fallbacks: int = 0  # every ranked cache dead → direct pull


class LocalCache:
    """CVMFS's on-worker cache (default 1 GB, LRU at chunk granularity)."""

    def __init__(self, capacity_bytes: int = 1 * 2**30) -> None:
        self.capacity_bytes = capacity_bytes
        self._lru: "OrderedDict[Tuple[str, int], Payload]" = OrderedDict()
        self.usage_bytes = 0

    def get(self, path: str, index: int) -> Optional[Payload]:
        key = (path, index)
        p = self._lru.get(key)
        if p is not None:
            self._lru.move_to_end(key)
        return p

    def put(self, path: str, index: int, payload: Payload) -> None:
        key = (path, index)
        old = self._lru.pop(key, None)
        if old is not None:
            # Re-fetched chunk: the new payload supersedes whatever was
            # resident (a checksum failure + drop/re-pull race can leave a
            # stale copy here) — replace it and account the size delta
            # rather than touching the stale entry and returning.
            self.usage_bytes -= old.size
        if payload.size > self.capacity_bytes:
            # Refusing outright beats draining the whole cache and then
            # overcommitting: the chunk can never fit, and inserting it
            # anyway would leave usage_bytes > capacity_bytes forever.
            return
        while self.usage_bytes + payload.size > self.capacity_bytes and self._lru:
            _, victim = self._lru.popitem(last=False)
            self.usage_bytes -= victim.size
        self._lru[key] = payload
        self.usage_bytes += payload.size

    def drop(self, path: str, index: int) -> None:
        p = self._lru.pop((path, index), None)
        if p is not None:
            self.usage_bytes -= p.size


class StashClient:
    """A worker-side federation client (CVMFS + stashcp semantics)."""

    def __init__(self, node: Node, caches: Sequence[CacheServer],
                 geoip: GeoIPService, net: NetworkModel,
                 catalog: Optional[Catalog] = None,
                 cvmfs_available: bool = True,
                 xrootd_available: bool = True,
                 local_cache_bytes: int = 1 * 2**30,
                 groups: Optional[Sequence[CacheGroup]] = None,
                 now: float = 0.0,
                 ranking: Union[str, RankingPolicy, None] = None) -> None:
        self.node = node
        self.caches = {c.name: c for c in caches}
        self.groups = list(groups) if groups else []
        for g in self.groups:
            for c in g.members:
                self.caches.setdefault(c.name, c)
        self.geoip = geoip
        self.net = net
        self.catalog = catalog
        self.cvmfs_available = cvmfs_available
        self.xrootd_available = xrootd_available
        self.local = LocalCache(local_cache_bytes)
        self.stats = ClientStats()
        self.now = now
        # Pluggable cache ranking (static GeoIP by default; "probe"
        # re-ranks on observed latency/failures — see core/routing.py).
        self.ranking = make_ranking_policy(ranking)
        # Optional ControlPlane (set by the owning plane): per-cache
        # circuit breakers + retry backoff replace blind failover.
        self.control = None

    # ------------------------------------------------------------------
    def _ranked_caches(self, exclude: Sequence[str] = (),
                       path: Optional[str] = None,
                       limit: Optional[int] = None) -> List[CacheServer]:
        """Cache servers in preference order for ``path``.

        Without HA groups (the paper's deployment) this is pure GeoIP
        distance.  With groups, the *groups* are ranked by distance and
        each contributes its members in consistent-hash ring order for
        the path — so a given object always lands on the same member of
        the nearest group, and a dead member degrades to the next ring
        member instead of straight to the origin.

        ``limit`` truncates the failover tail: a fleet-scale ranking over
        1000+ single-member groups otherwise walks every group's ring per
        request even though only the first few entries are ever tried.

        The ordering itself is the client's :class:`RankingPolicy`
        (static GeoIP by default) via the shared
        :func:`repro.core.routing.ranked_caches` pipeline.
        """
        return ranked_caches(self.node.name, self.caches, self.groups,
                             self.geoip, policy=self.ranking, path=path,
                             exclude=exclude, limit=limit)

    def _meta(self, path: str, cache: Optional[CacheServer] = None
              ) -> Optional[ObjectMeta]:
        if self.catalog is not None and path in self.catalog:
            return self.catalog.lookup(path)
        if cache is not None:
            return cache.locate_meta(path)
        for c in self._ranked_caches(path=path):
            m = c.locate_meta(path)
            if m is not None:
                return m
        return None

    def _fetch_chunk(self, path: str, index: int, expected_digest: int,
                     streams: int, verify: bool
                     ) -> Tuple[Optional[Payload], TransferStats]:
        """Fetch one chunk with nearest-cache + failover + checksum retry.

        With a control plane attached, dead or erroring caches feed
        per-cache circuit breakers (an open breaker is skipped without
        paying the connect timeout) and each retry backs off
        exponentially — the backoff wall time lands in ``agg.seconds``
        so the caller's latency accounting sees it."""
        agg = TransferStats()
        tried: List[str] = []
        ctrl = self.control
        n_backoff = 0
        for cache in self._ranked_caches(path=path):
            if ctrl is not None:
                ctrl.maybe_recover(cache.name, self.now)
            if not cache.available:
                tried.append(cache.name)
                self.stats.cache_failovers += 1
                self.ranking.on_failure(cache.name)
                if ctrl is not None:
                    ctrl.on_failure(cache.name, self.now)
                continue
            if ctrl is not None and not ctrl.allow(cache.name, self.now):
                tried.append(cache.name)
                continue
            cache.tick(self.now)  # TTL policies expire against client time
            try:
                payload, st = cache.get_chunk(self.node.name, path, index,
                                              streams=streams)
            except ConnectionError:
                tried.append(cache.name)
                self.stats.cache_failovers += 1
                self.ranking.on_failure(cache.name)
                if ctrl is not None:
                    ctrl.on_failure(cache.name, self.now)
                    delay = ctrl.backoff(n_backoff)
                    n_backoff += 1
                    ctrl.stats.retries += 1
                    ctrl.stats.backoff_seconds += delay
                    agg.seconds += delay
                continue
            agg.add(st)
            agg.source = cache.name
            self.ranking.observe(cache.name, st.seconds)
            if ctrl is not None:
                ctrl.on_success(cache.name, self.now, seconds=st.seconds)
            if payload is None:
                return None, agg
            if verify and expected_digest and not payload.verify():
                # CVMFS consistency guarantee: drop the corrupt replica at
                # the cache, refetch once from upstream (§6).
                self.stats.checksum_failures += 1
                cache.drop(path, index)
                payload, st2 = cache.get_chunk(self.node.name, path, index,
                                               streams=streams)
                agg.add(st2)
                if payload is None or (expected_digest and not payload.verify()):
                    tried.append(cache.name)
                    continue
            return payload, agg
        return None, agg

    # ------------------------------------------------------------------
    # CVMFS: POSIX partial reads through the nearest cache
    # ------------------------------------------------------------------
    def read(self, path: str, offset: int = 0,
             length: Optional[int] = None
             ) -> Tuple[Optional[bytes], TransferStats]:
        """POSIX read: fetch only the chunks covering [offset, offset+len).

        Returns assembled bytes (None when payloads are synthetic) plus
        transfer accounting.  Verified against catalog chunk checksums.
        """
        if not self.cvmfs_available:
            raise RuntimeError("CVMFS not mounted on this host")
        meta = self._meta(path)
        if meta is None:
            raise FileNotFoundError(path)
        if length is None:
            length = meta.size - offset
        length = max(0, min(length, meta.size - offset))
        self.stats.reads += 1
        stats = TransferStats(method="cvmfs")
        pieces: List[Optional[bytes]] = []
        n_ops = 0
        ranked = self._ranked_caches(path=path) if self.caches else []
        cache_for_monitor = ranked[0] if ranked else None
        user_id = file_id = None
        if cache_for_monitor is not None:
            user_id = cache_for_monitor.open_session(
                self.node.name, "xrootd", self.now)
            file_id = cache_for_monitor.open_file(user_id, meta, self.now)
        for ref in meta.chunks_for_range(offset, length):
            n_ops += 1
            local = self.local.get(path, ref.index)
            if local is not None:
                self.stats.local_hits += 1
                stats.local_hits += 1
                payload = local
            else:
                self.stats.local_misses += 1
                payload, st = self._fetch_chunk(
                    path, ref.index, ref.digest, streams=2, verify=True)
                stats.add(st)
                if payload is None:
                    raise FileNotFoundError(f"{path}#{ref.index}")
                self.local.put(path, ref.index, payload)
            if payload.data is None:
                pieces.append(None)
            else:
                lo = max(offset, ref.offset) - ref.offset
                hi = min(offset + length, ref.offset + ref.length) - ref.offset
                pieces.append(payload.data[lo:hi])
        if cache_for_monitor is not None and file_id is not None:
            self.now += stats.seconds
            cache_for_monitor.close_file(
                file_id, stats.bytes, n_ops, self.now,
                cache_hit=stats.cache_misses == 0)
        if any(p is None for p in pieces):
            return None, stats
        return b"".join(pieces), stats

    # ------------------------------------------------------------------
    # stashcp: whole-file copy with the 3-way fallback chain
    # ------------------------------------------------------------------
    def copy(self, path: str, methods: Optional[Sequence[str]] = None
             ) -> Tuple[Optional[bytes], TransferStats]:
        """Whole-file copy through the fallback chain.  ``methods``
        restricts/reorders the chain (the unified data plane uses
        ``("xrootd", "http")`` so both engines fetch from the site cache
        rather than the worker-local CVMFS cache)."""
        chain: Tuple[str, ...] = (tuple(methods) if methods
                                  else ("cvmfs", "xrootd", "http"))
        unknown = set(chain) - {"cvmfs", "xrootd", "http"}
        if unknown:
            raise ValueError(f"unknown copy methods {sorted(unknown)}")
        self.stats.copies += 1
        errors: List[str] = []
        # stashcp pays a remote GeoIP lookup before anything moves (§5).
        startup = self.geoip.lookup_latency
        for method in chain:
            if method == "cvmfs" and not self.cvmfs_available:
                errors.append("cvmfs: not mounted")
                continue
            if method == "xrootd" and not self.xrootd_available:
                errors.append("xrootd: no client")
                continue
            try:
                data, stats = self._copy_via(path, method)
                stats.seconds += startup
                stats.method = f"stashcp/{method}"
                return data, stats
            except (FileNotFoundError, ConnectionError) as e:
                errors.append(f"{method}: {e}")
        raise FileNotFoundError(f"stashcp failed for {path}: {errors}")

    def _copy_via(self, path: str, method: str
                  ) -> Tuple[Optional[bytes], TransferStats]:
        if method == "cvmfs":
            return self.read(path)
        meta = self._meta(path)
        if meta is None:
            raise FileNotFoundError(path)
        # XRootD: multi-stream; curl/HTTP: single stream, no checksums.
        streams = 8 if method == "xrootd" else 1
        verify = method == "xrootd"
        stats = TransferStats(method=method)
        ranked = self._ranked_caches(path=path) if self.caches else []
        monitor_cache = ranked[0] if ranked else None
        user_id = file_id = None
        if monitor_cache is not None:
            user_id = monitor_cache.open_session(
                self.node.name, "xrootd" if method == "xrootd" else "http",
                self.now)
            file_id = monitor_cache.open_file(user_id, meta, self.now)
        pieces: List[Optional[bytes]] = []
        for ref in meta.chunk_refs():
            payload, st = self._fetch_chunk(path, ref.index, ref.digest,
                                            streams=streams, verify=verify)
            stats.add(st)
            if payload is None:
                raise FileNotFoundError(f"{path}#{ref.index}")
            pieces.append(payload.data)
        if monitor_cache is not None and file_id is not None:
            self.now += stats.seconds
            monitor_cache.close_file(file_id, stats.bytes, stats.chunks,
                                     self.now,
                                     cache_hit=stats.cache_misses == 0)
        if any(p is None for p in pieces):
            return None, stats
        return b"".join(pieces), stats
