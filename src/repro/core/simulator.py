"""Discrete-event, fluid-flow network simulator.

The paper's evaluation runs on a *contended* production network ("we have
no visibility into the resource contention of the network, caches, proxies,
or origin server").  To reproduce Table 3 / Figs 5–8 — and to project the
federation to a 1000+-node fleet — we simulate transfers as fluid flows
over shared links with **max-min fair sharing** plus a per-flow cap of
``streams × (tcp_window / rtt)`` (the same per-stream model as
:class:`~repro.core.transfer.NetworkModel`, so the functional path and the
simulator agree in the uncontended limit).

Scenario logic is written as generator coroutines: ``yield sim.delay(s)``
(RPCs, GeoIP lookups) and ``yield sim.flow(src, dst, nbytes, streams)``
(bulk transfers).  Cache/proxy *state machines* are the very same objects
used by the functional federation — only timing differs.

The max-min allocation is re-solved whenever the *active flow set*
changes — but only once per distinct event time: all arrivals,
completions and callbacks at one timestamp are drained first, then a
single waterfilling pass covers the whole batch (a 1000-flow restart
storm at t=0 is one solve, not ~1000).  Between solves the next flow
completion comes from a finish-time heap rebuilt on each reallocation,
so pure-delay events cost O(log n) instead of an O(active) winner scan.
Two solvers are provided: the original ``scalar`` waterfilling loop, and
a ``vector`` solver that batches the per-link waterfilling across all
flows as JAX array ops (``repro.kernels.maxmin``).  ``auto`` (default)
switches to the vector solver once enough flows are concurrently active
for the batching to pay for its dispatch — which is what lets one
``FluidFlowSim`` drive 1000+-site fleet scenarios.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, Generator, List, Optional, Tuple

from .cache import CacheServer
from .chunk import ObjectMeta, Payload
from .proxy import HTTPProxy
from .topology import Link, Topology
from .transfer import NetworkModel


def sparse_flow_problem(flow_specs) -> Tuple[List[float], List[List[int]],
                                             List[float]]:
    """Index a set of flows into the sparse max-min problem layout.

    ``flow_specs`` is an iterable of ``(links, cap)`` pairs — each a
    flow's traversed :class:`~repro.core.topology.Link` objects and its
    TCP ceiling.  Links are deduplicated by identity into a compact
    index space; the result ``(link_caps, flow_links, flow_caps)`` feeds
    ``repro.kernels.maxmin.maxmin_rates_sparse`` directly (one problem)
    or ``repro.kernels.batched_maxmin.maxmin_rates_batch`` (one problem
    per sweep cell).  Shared by the simulator's vector solver and the
    sweep engine's contention pricing so the two can never disagree on
    what a flow set means.
    """
    link_index: Dict[int, int] = {}
    link_caps: List[float] = []
    flow_links: List[List[int]] = []
    flow_caps: List[float] = []
    for links, cap in flow_specs:
        row = []
        for link in links:
            lid = id(link)
            idx = link_index.get(lid)
            if idx is None:
                idx = link_index[lid] = len(link_caps)
                link_caps.append(link.bandwidth)
            row.append(idx)
        flow_links.append(row)
        flow_caps.append(cap)
    return link_caps, flow_links, flow_caps


class _Waitable:
    pass


@dataclasses.dataclass
class _Delay(_Waitable):
    seconds: float


class Event(_Waitable):
    """One-shot condition (collapsed-forwarding waits, barriers...)."""

    def __init__(self, sim: "FluidFlowSim") -> None:
        self._sim = sim
        self.is_set = False
        self._waiters: List["_Proc"] = []

    def set(self) -> None:
        self.is_set = True
        for proc in self._waiters:
            self._sim._schedule(self._sim.t,
                                lambda p=proc: self._sim._step(p, None))
        self._waiters.clear()


class Flow(_Waitable):
    _ids = itertools.count()

    def __init__(self, src: str, dst: str, nbytes: float, streams: int,
                 links: List[Link], cap: float) -> None:
        self.id = next(Flow._ids)
        self.src, self.dst = src, dst
        self.remaining = float(max(nbytes, 1.0))
        self.nbytes = nbytes
        self.streams = streams
        self.links = links
        self.cap = cap            # streams × per-stream TCP ceiling
        self.rate = 0.0
        self.started_at: float = 0.0
        self.finished_at: Optional[float] = None
        self.waiter: Optional["_Proc"] = None


class _Proc:
    def __init__(self, gen: Generator, on_exit: Optional[Callable] = None):
        self.gen = gen
        self.on_exit = on_exit


class FluidFlowSim:
    """Event loop + max-min fair bandwidth allocation."""

    def __init__(self, topology: Topology,
                 net: Optional[NetworkModel] = None,
                 solver: str = "auto",
                 vector_threshold: int = 256) -> None:
        if solver not in ("auto", "scalar", "vector"):
            raise ValueError(f"unknown solver {solver!r}")
        self.topology = topology
        self.net = net or NetworkModel(topology)
        self.solver = solver
        self.vector_threshold = vector_threshold
        self.t = 0.0
        self._eventq: List[Tuple[float, int, Callable]] = []
        self._eid = itertools.count()
        self.active: List[Flow] = []
        self.completed_flows = 0
        self.reallocations = 0
        # Arrivals + completions: what a per-arrival solver would have
        # paid.  ``flow_events / reallocations`` is the coalescing win.
        self.flow_events = 0
        self._flows_dirty = True  # active set changed since last solve
        self._fin_heap: List[Tuple[float, int, Flow]] = []
        self.link_bytes: Dict[str, float] = {}
        # (cache name) -> {(path, chunk) -> Event}: collapsed-forwarding
        # registry, per cache server, owned by the sim so concurrent
        # scenarios on shared cache objects never cross-talk.
        self._inflight: Dict[str, Dict[Tuple[str, int], Event]] = {}

    # -- coroutine API -------------------------------------------------------
    def delay(self, seconds: float) -> _Delay:
        return _Delay(max(0.0, seconds))

    def event(self) -> Event:
        return Event(self)

    def inflight(self, server: str) -> Dict[Tuple[str, int], Event]:
        """Per-cache collapsed-forwarding table: (path, chunk) -> Event
        for pulls currently in flight at ``server``.  Shared by every
        download coroutine in this sim, whichever client issued it."""
        return self._inflight.setdefault(server, {})

    def flow(self, src: str, dst: str, nbytes: float,
             streams: int = 1, rate_cap: float = 0.0) -> Flow:
        links = self.topology.path(src, dst)
        rtt = self.topology.rtt(src, dst)
        cap = max(1, streams) * self.net.per_stream_cap(rtt)
        if rate_cap:
            cap = min(cap, rate_cap)
        return Flow(src, dst, nbytes, streams, links, cap)

    def spawn(self, gen: Generator, at: Optional[float] = None,
              on_exit: Optional[Callable] = None) -> None:
        proc = _Proc(gen, on_exit)
        self._schedule(self.t if at is None else at,
                       lambda: self._step(proc, None))

    def _schedule(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._eventq, (t, next(self._eid), fn))

    def _step(self, proc: _Proc, value) -> None:
        try:
            waitable = proc.gen.send(value)
        except StopIteration:
            if proc.on_exit:
                proc.on_exit(self.t)
            return
        if isinstance(waitable, _Delay):
            self._schedule(self.t + waitable.seconds,
                           lambda: self._step(proc, None))
        elif isinstance(waitable, Flow):
            waitable.waiter = proc
            waitable.started_at = self.t
            self.active.append(waitable)
            self.flow_events += 1
            self._flows_dirty = True
        elif isinstance(waitable, Event):
            if waitable.is_set:
                self._schedule(self.t, lambda: self._step(proc, None))
            else:
                waitable._waiters.append(proc)
        else:  # pragma: no cover - defensive
            raise TypeError(f"cannot wait on {waitable!r}")

    # -- max-min fair allocation ----------------------------------------------
    def _reallocate(self) -> None:
        """One waterfilling pass over the current active set.  Called once
        per distinct event time at which the set changed, however many
        arrivals/completions that time coalesced."""
        self.reallocations += 1
        if self.solver == "vector" or (
                self.solver == "auto"
                and len(self.active) >= self.vector_threshold):
            self._reallocate_vector()
        else:
            self._reallocate_scalar()

    def _reallocate_vector(self) -> None:
        """Batched waterfilling over sparse flow→link rows, solved by
        ``repro.kernels.maxmin`` as JAX array ops."""
        from repro.kernels.maxmin import maxmin_rates_sparse

        flows = self.active
        if not flows:
            return
        link_caps, flow_links, flow_caps = sparse_flow_problem(
            (f.links, f.cap) for f in flows)
        rates = maxmin_rates_sparse(link_caps, flow_links, flow_caps)
        for f, r in zip(flows, rates):
            f.rate = float(r)

    def _reallocate_scalar(self) -> None:
        unfixed = set(range(len(self.active)))
        cap_left: Dict[int, float] = {}
        link_flows: Dict[int, List[int]] = {}
        links: Dict[int, Link] = {}
        for fi in unfixed:
            for link in self.active[fi].links:
                lid = id(link)
                links[lid] = link
                cap_left.setdefault(lid, link.bandwidth)
                link_flows.setdefault(lid, []).append(fi)
        for f in self.active:
            f.rate = 0.0
        while unfixed:
            # Most-constrained link's equal share.
            best_share, best_lid = float("inf"), None
            for lid, flows in link_flows.items():
                n = sum(1 for fi in flows if fi in unfixed)
                if n == 0:
                    continue
                share = cap_left[lid] / n
                if share < best_share:
                    best_share, best_lid = share, lid
            # Flows whose own TCP cap binds before the link share.
            capped = [fi for fi in unfixed if self.active[fi].cap < best_share]
            if capped:
                for fi in capped:
                    f = self.active[fi]
                    f.rate = f.cap
                    unfixed.discard(fi)
                    for link in f.links:
                        cap_left[id(link)] = max(
                            0.0, cap_left[id(link)] - f.rate)
                continue
            if best_lid is None:
                for fi in unfixed:
                    self.active[fi].rate = self.active[fi].cap
                break
            fixed_now = [fi for fi in link_flows[best_lid] if fi in unfixed]
            for fi in fixed_now:
                f = self.active[fi]
                f.rate = best_share
                unfixed.discard(fi)
                for link in f.links:
                    if id(link) != best_lid:
                        cap_left[id(link)] = max(
                            0.0, cap_left[id(link)] - f.rate)
            cap_left[best_lid] = 0.0

    def _rebuild_finish_heap(self) -> None:
        """Absolute finish times for the current rates.  Valid until the
        active set (and hence the allocation) next changes — rates are
        static in between, so absolute times stay correct as t advances."""
        heap = [(self.t + f.remaining / f.rate, f.id, f)
                for f in self.active if f.rate > 0]
        heapq.heapify(heap)
        self._fin_heap = heap

    # -- event loop -------------------------------------------------------------
    def run(self, until: Optional[float] = None) -> float:
        if until is not None and until < self.t:
            return self.t  # guard: resuming must never move time backward
        # Benchmarks poke the solvers directly between run() calls, which
        # rewrites rates out-of-band: always re-derive finish times on entry.
        self._rebuild_finish_heap()
        while self._eventq or self.active:
            # Rates only change when the active flow set does (links and
            # per-flow caps are static): solve once per distinct event
            # time, after *all* of that instant's arrivals/completions
            # have been drained, instead of re-waterfilling the fleet
            # between same-timestamp events.
            if self._flows_dirty:
                if self.active:
                    self._reallocate()
                self._flows_dirty = False
                self._rebuild_finish_heap()
            t_finish = self._fin_heap[0][0] if self._fin_heap else float("inf")
            t_event = self._eventq[0][0] if self._eventq else float("inf")
            t_next = min(t_finish, t_event)
            if until is not None and t_next > until:
                self._advance(until - self.t)
                self.t = until
                return self.t
            if t_next == float("inf"):
                break
            self._advance(t_next - self.t)
            self.t = t_next
            if t_finish <= t_next:
                # Drain every completion at this instant (ties are exact
                # for symmetric flows: identical arithmetic → identical
                # finish times), then compact the active list once.
                while self._fin_heap and self._fin_heap[0][0] <= self.t:
                    _, _, f = heapq.heappop(self._fin_heap)
                    f.remaining = 0.0
                    f.finished_at = self.t
                    self.completed_flows += 1
                    self.flow_events += 1
                    self._flows_dirty = True
                    if f.waiter is not None:
                        self._step(f.waiter, f)
                self.active = [f for f in self.active
                               if f.finished_at is None]
            while self._eventq and self._eventq[0][0] <= self.t:
                _, _, fn = heapq.heappop(self._eventq)
                fn()
        return self.t

    def _advance(self, dt: float) -> None:
        if dt <= 0:
            return
        for f in self.active:
            moved = f.rate * dt
            f.remaining = max(0.0, f.remaining - moved)
            for link in f.links:
                self.link_bytes[link.name] = \
                    self.link_bytes.get(link.name, 0.0) + moved


# ---------------------------------------------------------------------------
# Paper scenarios (used by benchmarks/bench_proxy_vs_stash.py etc.)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DownloadResult:
    path: str
    size: int
    method: str
    seconds: float = 0.0
    cache_hit: bool = False
    start: float = 0.0
    source: str = ""      # cache/proxy that served the final hop
    failovers: int = 0    # dead caches skipped before one answered
    hedged: bool = False  # a backup fetch was raced against the primary
    waited: bool = False  # collapsed-forwarding wait (paid miss latency)
    shed: bool = False    # refused by an admission queue (load shedding)
    queue_seconds: float = 0.0  # time parked in admission queues


def fetch_chunks(sim: FluidFlowSim, cache: CacheServer, meta: ObjectMeta,
                 origin_node: str, redirector_node: str,
                 origin=None, pull_streams: int = 4,
                 refs=None) -> Generator:
    """Ensure ``meta``'s chunks (or the ``refs`` subset) are resident at
    ``cache``: redirector RPC + origin→cache pull on miss, collapsed
    forwarding on in-flight chunks (concurrent requests wait rather than
    re-pull).  Shared by ``stash_download`` and the routed simclient
    downloads so the two paths can never diverge on cache accounting.

    In a tiered federation a miss fills *cache-to-cache* first: the
    missing chunks are ensured at the parent tier's owning member (a
    recursive call — so the parent's own inflight registry collapses
    concurrent child fills, and an L2 miss recurses on up or pulls from
    the origin), then move over one parent→child flow.  Only the top
    tier pays the redirector RPC; a child with a live parent never asks
    the redirector.  A dead parent tier falls back to the flat
    origin-pull path.

    Returns "hit" (fully resident), "miss" (pulled from upstream),
    "waited" (collapsed-forwarding wait: full miss latency, no duplicate
    pull), or None when the cache died while we pulled/waited.  Passing
    the :class:`~repro.core.origin.Origin` object counts its egress.
    """
    cache.tick(sim.t)  # TTL policies expire against simulated time
    inflight = sim.inflight(cache.name)
    missing, wait_for = [], []
    for r in (meta.chunk_refs() if refs is None else refs):
        key = (meta.path, r.index)
        if cache.resident(meta.path, r.index):
            cache.lookup(meta.path, r.index)          # counts the hit
        elif key in inflight:
            wait_for.append((r, inflight[key]))        # collapsed forwarding
        else:
            cache.stats.misses += 1
            inflight[key] = sim.event()
            missing.append(r)
    if missing:
        miss_bytes = sum(r.length for r in missing)
        parent = next(iter(cache.parent_caches(meta.path)), None)
        if parent is not None:
            status = yield from fetch_chunks(
                sim, parent, meta, origin_node, redirector_node,
                origin=origin, pull_streams=pull_streams, refs=missing)
            if status is None:
                parent = None  # parent died mid-fill: origin fallback
        if parent is not None:
            yield sim.flow(parent.node.name, cache.node.name, miss_bytes,
                           streams=pull_streams)
            parent.stats.bytes_served += miss_bytes
            cache.stats.bytes_from_parent += miss_bytes
        else:
            yield sim.delay(sim.net.rpc_time(cache.node.name,
                                             redirector_node))
            yield sim.flow(origin_node, cache.node.name, miss_bytes,
                           streams=pull_streams)
            cache.stats.bytes_from_origin += miss_bytes
            if origin is not None:
                origin.stats.egress_bytes += miss_bytes
                origin.stats.chunk_requests += len(missing)
        cache.tick(sim.t)
        for r in missing:
            cache.admit(meta.path, r.index,
                        Payload.synthetic(r.length, meta.path, r.index),
                        object_size=meta.size)
            ev = inflight.pop((meta.path, r.index), None)
            if ev is not None:
                ev.set()
    for r, ev in wait_for:
        if not ev.is_set:
            yield ev
        cache.tick(sim.t)
        # A waiter is only a hit if the pull actually landed — admission
        # may have rejected the chunk, in which case the cache never held
        # it and the read is a miss for the hit/miss latency splits.
        if cache.resident(meta.path, r.index):
            cache.stats.hits += 1
        else:
            cache.stats.misses += 1
    if not cache.available:
        return None
    if missing:
        return "miss"
    return "waited" if wait_for else "hit"


def stash_download(sim: FluidFlowSim, client_node: str, cache: CacheServer,
                   origin_node: str, redirector_node: str, meta: ObjectMeta,
                   geoip_latency: float, streams: int = 8,
                   result: Optional[DownloadResult] = None) -> Generator:
    """stashcp against one pre-chosen cache: GeoIP lookup →
    :func:`fetch_chunks` → cache→client multi-stream transfer.  (The
    routed, failover-aware variant lives in ``repro.core.simclient``.)"""
    t0 = sim.t
    yield sim.delay(geoip_latency)
    status = yield from fetch_chunks(sim, cache, meta, origin_node,
                                     redirector_node)
    yield sim.flow(cache.node.name, client_node, meta.size, streams=streams,
                   rate_cap=cache.serve_rate_cap(meta.size))
    cache.stats.bytes_served += meta.size
    if result is not None:
        result.seconds = sim.t - t0
        # Collapsed-forwarding waiters paid full miss latency: only an
        # entirely-resident object counts as a cache hit.
        result.cache_hit = status == "hit"
        result.waited = status == "waited"
        result.source = cache.name
        result.start = t0


def proxy_download(sim: FluidFlowSim, client_node: str, proxy: HTTPProxy,
                   origin_node: str, meta: ObjectMeta,
                   result: Optional[DownloadResult] = None) -> Generator:
    """curl via the site squid: zero discovery cost, single-stream HTTP,
    whole-object granularity, TTL + size-cap admission."""
    t0 = sim.t
    entry = proxy.lookup(meta.path, sim.t)
    if entry is None:
        yield sim.flow(origin_node, proxy.node.name, meta.size, streams=1)
        proxy.stats.bytes_from_origin += meta.size
        proxy.origin.stats.egress_bytes += meta.size
        proxy.admit(meta.path, meta.size, sim.t)
    yield sim.flow(proxy.node.name, client_node, meta.size, streams=1,
                   rate_cap=proxy.serve_rate_cap(meta.size))
    proxy.stats.bytes_served += meta.size
    if result is not None:
        result.seconds = sim.t - t0
        result.cache_hit = entry is not None
        result.source = proxy.name
        result.start = t0


def direct_download(sim: FluidFlowSim, client_node: str, origin_node: str,
                    meta: ObjectMeta, streams: int = 1,
                    result: Optional[DownloadResult] = None) -> Generator:
    """No caching layer at all: every worker pulls from the origin (the
    WAN-saturating counterfactual behind paper Fig. 5)."""
    t0 = sim.t
    yield sim.flow(origin_node, client_node, meta.size, streams=streams)
    if result is not None:
        result.seconds = sim.t - t0
        result.start = t0
