"""The CVMFS indexer (paper §3.1).

To give CVMFS a POSIX view of an origin, an indexer scans the remote origin
and gathers metadata: file names/directory structure, sizes, permissions and
*checksums along the chunk boundaries*.  Changes are detected by (mtime,
size); a changed file is re-indexed.  The paper notes the indexer "must scan
the entire filesystem each iteration, causing a delay proportional to the
number of files" — we model that cost explicitly (it is the reason stashcp
exists for indexing-latency-sensitive users).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .chunk import ObjectMeta
from .origin import Origin


@dataclasses.dataclass
class Catalog:
    """The published filesystem image CVMFS clients mount."""

    entries: Dict[str, ObjectMeta] = dataclasses.field(default_factory=dict)
    generation: int = 0

    def lookup(self, path: str) -> Optional[ObjectMeta]:
        return self.entries.get(path)

    def listdir(self, prefix: str) -> list[str]:
        prefix = prefix.rstrip("/")
        out = set()
        for p in self.entries:
            if p.startswith(prefix + "/"):
                rest = p[len(prefix) + 1:]
                out.add(rest.split("/")[0])
        return sorted(out)

    def __contains__(self, path: str) -> bool:
        return path in self.entries


@dataclasses.dataclass
class IndexStats:
    files_scanned: int = 0
    files_reindexed: int = 0
    files_removed: int = 0
    scan_seconds: float = 0.0


class Indexer:
    """Scans an origin, publishing a fresh catalog each iteration."""

    def __init__(self, origin: Origin, scan_cost_per_file: float = 1e-3,
                 reindex_cost_per_byte: float = 1e-9) -> None:
        self.origin = origin
        self.scan_cost_per_file = scan_cost_per_file
        self.reindex_cost_per_byte = reindex_cost_per_byte
        self.catalog = Catalog()

    def scan(self) -> IndexStats:
        """Full-filesystem scan (the paper's proportional-delay behaviour)."""
        stats = IndexStats()
        seen = set()
        for meta in self.origin.list_objects():
            stats.files_scanned += 1
            stats.scan_seconds += self.scan_cost_per_file
            seen.add(meta.path)
            prev = self.catalog.entries.get(meta.path)
            changed = (prev is None or prev.mtime != meta.mtime
                       or prev.size != meta.size)
            if changed:
                # Re-index: re-read the file to recompute chunk checksums.
                stats.files_reindexed += 1
                stats.scan_seconds += meta.size * self.reindex_cost_per_byte
                self.catalog.entries[meta.path] = dataclasses.replace(
                    meta, chunk_digests=list(meta.chunk_digests))
        for stale in set(self.catalog.entries) - seen:
            del self.catalog.entries[stale]
            stats.files_removed += 1
        self.catalog.generation += 1
        return stats
