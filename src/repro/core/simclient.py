"""Simulator-native federation clients + outage-storm scenario engine.

The paper's headline numbers (Table 3, Figs 5–8) measure the *whole*
client chain — GeoIP ranking, redirector lookup, failover — on a
contended network.  ``stash_download`` (the PR-1 scenario coroutine)
hard-wires one pre-chosen cache, so none of the routing machinery is
ever exercised under contention.  This module closes that gap:

* :class:`SimStashClient` — a coroutine ``stashcp`` whose cache choice
  goes through the real :meth:`StashClient._ranked_caches` /
  :meth:`CacheGroup.route` machinery (consistent-hash ring ownership,
  dead-member failover chains, stray-cache geo tails) with per-cache
  collapsed forwarding (:meth:`FluidFlowSim.inflight`) and optional
  **hedged fetches**: if the chosen cache hasn't delivered within a
  deadline, a backup fetch is raced against it via the next ranked
  cache, first finisher wins (straggler mitigation for restart storms).
* :class:`OutageSchedule` — mid-run cache failure/recovery timelines:
  restart storms, regional blackouts, rolling upgrades (cold restarts
  lose their disk; warm ones keep it).
* :class:`ScenarioEngine` — replays :func:`~repro.core.workload.
  generate_workload` / :func:`storm_workload` traces across a
  multi-site federation under an outage schedule, one simulator-driven
  client per (site, worker), and aggregates the result into a
  :class:`ScenarioReport`.

``router="modulo"`` swaps the consistent-hash routing for a
hash-mod-alive-caches baseline, which is what lets the fleet benches
compare ring vs modulo *with* link contention instead of the
functional-path approximation.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, Generator, Iterable, List, Optional, Sequence,
                    Tuple)

from .cache import CacheServer
from .chunk import ObjectMeta, fnv1a64
from .client import StashClient
from .controlplane import ControlPlane, ControlPlaneSpec
from .federation import Federation
from .origin import Origin
from .simulator import DownloadResult, Event, FluidFlowSim, fetch_chunks
from .workload import AccessRequest


# ---------------------------------------------------------------------------
# Coroutine combinators (timer races for hedged fetches)
# ---------------------------------------------------------------------------
def first_of(sim: FluidFlowSim, *events: Event) -> Event:
    """An event that fires when any of ``events`` fires (or now, if one
    already has).  Watchers are plain sim coroutines, so the combinator
    composes with flows/delays without special-casing the event loop."""
    trigger = sim.event()
    if any(ev.is_set for ev in events):
        trigger.set()
        return trigger

    def watch(ev: Event) -> Generator:
        yield ev
        trigger.set()  # idempotent: late watchers find no waiters

    for ev in events:
        sim.spawn(watch(ev))
    return trigger


# ---------------------------------------------------------------------------
# The simulator-native stashcp
# ---------------------------------------------------------------------------
class SimStashClient:
    """One worker's federation client, driven by the fluid-flow sim.

    Wraps a functional :class:`StashClient` purely for its *routing*
    brain (ring-aware `_ranked_caches`); all timing — GeoIP lookup,
    redirector RPC, origin pull, cache→client serve — happens as
    simulator delays and contended flows.
    """

    def __init__(self, sim: FluidFlowSim, client: StashClient,
                 origin: Origin, redirector_node: str,
                 streams: int = 8,
                 hedge_after: Optional[float] = None,
                 max_attempts: int = 4,
                 rank_limit: Optional[int] = 8,
                 router: str = "ring",
                 redirectors=None,
                 control: Optional[ControlPlane] = None) -> None:
        if router not in ("ring", "modulo"):
            raise ValueError(f"unknown router {router!r}")
        self.sim = sim
        self.client = client
        self.origin = origin
        self.redirector_node = redirector_node
        self.streams = streams
        self.hedge_after = hedge_after
        self.max_attempts = max_attempts
        self.rank_limit = rank_limit
        self.router = router
        self.control = control
        # Namespace-first path resolution: with a RedirectorGroup the
        # owning origin comes from longest-prefix match over the global
        # namespace (multi-origin federations); ``origin`` is only the
        # fallback when no export claims the path.
        self.redirectors = redirectors

    @property
    def node_name(self) -> str:
        return self.client.node.name

    @property
    def stats(self):
        return self.client.stats

    # -- routing ------------------------------------------------------------
    def _route(self, path: str,
               exclude: Sequence[str] = ()) -> List[CacheServer]:
        if self.router == "modulo":
            # Non-consistent baseline: hash mod the *alive* member count.
            # Any membership change renumbers nearly every key — the
            # origin-storm failure mode the ring exists to avoid.
            alive = sorted(c.name for c in self.client.caches.values()
                           if c.available and c.name not in exclude)
            if not alive:
                return []
            start = fnv1a64(path.encode()) % len(alive)
            return [self.client.caches[alive[(start + i) % len(alive)]]
                    for i in range(len(alive))]
        return self.client._ranked_caches(path=path, exclude=exclude,
                                          limit=self.rank_limit)

    def _owner(self, path: str) -> Origin:
        """The origin serving ``path`` — resolved through the
        redirectors' namespace (longest-prefix), not a held reference."""
        if self.redirectors is not None:
            try:
                origin = self.redirectors.locate(path)
            except ConnectionError:
                origin = None
            if origin is not None:
                return origin
        return self.origin

    def _meta(self, path: str) -> Optional[ObjectMeta]:
        owner = self._owner(path)
        if path in owner.store:
            return owner.meta(path)
        return self.client._meta(path)

    # -- the download coroutine ---------------------------------------------
    def download(self, path: str, meta: Optional[ObjectMeta] = None,
                 result: Optional[DownloadResult] = None,
                 tenant: str = "") -> Generator:
        """stashcp under contention: GeoIP → ranked caches → (failover as
        needed) → collapsed-forwarding fetch → (hedged) multi-stream
        serve.  Falls back to a direct origin pull only when every
        ranked cache is down (regional blackout).

        With a control plane attached, each per-cache attempt first
        passes the cache's circuit breaker and admission queue (which
        may park this coroutine or shed the request outright — a shed
        terminates the download, it does *not* fall through to the
        origin), and failed attempts retry with exponential backoff
        instead of hammering the next ranked cache immediately."""
        sim = self.sim
        ctrl = self.control
        t0 = sim.t
        self.stats.copies += 1
        yield sim.delay(self.client.geoip.lookup_latency)
        # One namespace resolution per download: every fetch arm (and
        # the blackout fallback) pulls from the same resolved owner.
        owner = self._owner(path)
        if meta is None:
            meta = (owner.meta(path) if path in owner.store
                    else self.client._meta(path))
        if meta is None:
            raise FileNotFoundError(path)
        failovers = 0
        attempts = 0
        n_backoff = 0
        for cache in self._route(path):
            if attempts >= self.max_attempts:
                break
            if ctrl is not None:
                ctrl.maybe_recover(cache.name, sim.t)
            if not cache.available:
                failovers += 1
                self.stats.cache_failovers += 1
                if ctrl is not None:
                    ctrl.on_failure(cache.name, sim.t)
                self.client.ranking.on_failure(cache.name)
                continue
            if ctrl is not None and not ctrl.allow(cache.name, sim.t):
                continue  # breaker open: skip without burning an attempt
            attempts += 1
            if self.hedge_after is None:
                kind, status, queued = yield from self._attempt(
                    cache, meta, owner, tenant)
                if kind == "shed":
                    self._finish_shed(result, t0, cache.name, failovers)
                    return
                if kind == "fail":
                    # died mid-pull: the key remaps down the ring chain
                    failovers += 1
                    self.stats.cache_failovers += 1
                    if attempts < self.max_attempts:
                        yield from self._backoff(n_backoff)
                        n_backoff += 1
                    continue
                outcome = {"winner": cache.name, "status": status,
                           "hedged": False, "queue_seconds": queued}
            else:
                outcome = yield from self._hedged_attempt(cache, meta,
                                                          owner, tenant)
                if outcome["winner"] is None:
                    if outcome.get("sheds"):
                        self._finish_shed(result, t0, cache.name,
                                          failovers)
                        return
                    failovers += 1
                    self.stats.cache_failovers += 1
                    if attempts < self.max_attempts:
                        yield from self._backoff(n_backoff)
                        n_backoff += 1
                    continue
            if result is not None:
                result.seconds = sim.t - t0
                result.start = t0
                result.cache_hit = outcome["status"] == "hit"
                result.waited = outcome["status"] == "waited"
                result.hedged = outcome["hedged"]
                result.source = outcome["winner"]
                result.failovers = failovers
                result.queue_seconds = outcome.get("queue_seconds", 0.0)
            return
        # Every ranked cache is dead (or attempts exhausted): the
        # federation degrades to the WAN-saturating direct pull.
        self.stats.origin_fallbacks += 1
        yield sim.flow(owner.node.name, self.node_name, meta.size,
                       streams=self.streams)
        owner.stats.egress_bytes += meta.size
        if result is not None:
            result.seconds = sim.t - t0
            result.start = t0
            result.cache_hit = False
            result.source = owner.name
            result.failovers = failovers
            result.method = "origin-direct"

    def _fetch_chunks(self, cache: CacheServer, meta: ObjectMeta,
                      owner: Origin) -> Generator:
        """Shared collapsed-forwarding fetch (see
        :func:`~repro.core.simulator.fetch_chunks`), pulling from the
        namespace-resolved owner so its egress counters see the pull."""
        status = yield from fetch_chunks(
            self.sim, cache, meta, owner.node.name,
            self.redirector_node, origin=owner)
        return status

    def _serve_flow(self, cache: CacheServer, meta: ObjectMeta) -> Generator:
        yield self.sim.flow(cache.node.name, self.node_name, meta.size,
                            streams=self.streams,
                            rate_cap=cache.serve_rate_cap(meta.size))
        cache.stats.bytes_served += meta.size

    def _attempt(self, cache: CacheServer, meta: ObjectMeta,
                 owner: Origin, tenant: str = "") -> Generator:
        """One full attempt through ``cache``: admission (may queue this
        coroutine, or shed), collapsed-forwarding fetch, serve.

        Returns ``(kind, status, queue_seconds)`` where kind is "ok"
        (served; status is the fetch status), "shed" (refused by the
        admission queue) or "fail" (cache died mid-attempt).  With no
        control plane attached this is exactly the old fetch+serve
        path — byte-identical accounting."""
        sim = self.sim
        ctrl = self.control
        queued = 0.0
        if ctrl is not None:
            t_q = sim.t
            admitted = yield from ctrl.acquire(cache.name, tenant,
                                               meta.size)
            if not admitted:
                return ("shed", None, 0.0)
            queued = sim.t - t_q
        t_service = sim.t
        try:
            status = yield from self._fetch_chunks(cache, meta, owner)
            if status is None or not cache.available:
                if ctrl is not None:
                    ctrl.on_failure(cache.name, sim.t)
                self.client.ranking.on_failure(cache.name)
                return ("fail", None, queued)
            yield from self._serve_flow(cache, meta)
            if ctrl is not None:
                ctrl.on_success(cache.name, sim.t,
                                seconds=sim.t - t_service,
                                tenant=tenant, nbytes=meta.size)
            self.client.ranking.observe(cache.name, sim.t - t_service)
            return ("ok", status, queued)
        finally:
            if ctrl is not None:
                ctrl.release(cache.name, tenant)

    def _backoff(self, attempt: int) -> Generator:
        """Exponential pause between retries (no-op without control)."""
        ctrl = self.control
        if ctrl is None:
            return
        delay = ctrl.backoff(attempt)
        ctrl.stats.retries += 1
        ctrl.stats.backoff_seconds += delay
        if delay > 0:
            yield self.sim.delay(delay)

    def _finish_shed(self, result: Optional[DownloadResult], t0: float,
                     source: str, failovers: int) -> None:
        """Record an admission-queue refusal: the request terminates —
        seconds stays 0 (not completed), and it must NOT degrade into an
        origin-direct pull (shedding exists to protect the origin)."""
        if result is not None:
            result.start = t0
            result.shed = True
            result.source = source
            result.failovers = failovers
            result.method = "shed"

    def _attempt_arm(self, cache: CacheServer, meta: ObjectMeta,
                     owner: Origin, outcome: Dict,
                     done: Event, tenant: str = "") -> Generator:
        """One arm of a (possibly hedged) attempt: full fetch through
        ``cache`` (origin pull included) then serve.  Signals ``done``
        whether it won, lost, or failed; a losing arm's bytes still
        move — hedging is modeled as load, not magic.  Each arm holds
        its own admission slot; a shed arm records itself in
        ``outcome`` so the caller can tell "all arms shed" from "all
        arms failed"."""
        kind, status, queued = yield from self._attempt(cache, meta,
                                                        owner, tenant)
        if kind == "ok":
            if outcome["winner"] is None:
                outcome["winner"] = cache.name
                outcome["status"] = status
                outcome["queue_seconds"] = queued
        elif kind == "shed":
            outcome["sheds"] = outcome.get("sheds", 0) + 1
        done.set()

    def _hedged_attempt(self, cache: CacheServer, meta: ObjectMeta,
                        owner: Origin, tenant: str = "") -> Generator:
        """Timer race over the whole per-cache attempt: if ``cache``
        hasn't delivered within ``hedge_after`` seconds — origin pull
        and serve included, that's where stragglers come from — a
        backup attempt via the next ranked cache runs in parallel and
        the first finisher wins."""
        sim = self.sim
        outcome: Dict = {"winner": None, "status": None, "hedged": False,
                         "queue_seconds": 0.0}
        primary_done = sim.event()
        sim.spawn(self._attempt_arm(cache, meta, owner, outcome,
                                    primary_done, tenant))
        timer = sim.event()

        def alarm() -> Generator:
            yield sim.delay(self.hedge_after)
            timer.set()

        sim.spawn(alarm())
        yield first_of(sim, primary_done, timer)
        pending = [primary_done]
        if outcome["winner"] is None and not primary_done.is_set:
            # deadline passed with the primary still in flight: hedge
            backup = next(
                (c for c in self._route(meta.path, exclude=(cache.name,))
                 if c.available), None)
            if backup is not None:
                outcome["hedged"] = True
                self.stats.hedged_fetches += 1
                backup_done = sim.event()
                sim.spawn(self._attempt_arm(backup, meta, owner, outcome,
                                            backup_done, tenant))
                pending.append(backup_done)
        pending = [ev for ev in pending if not ev.is_set]
        while outcome["winner"] is None and pending:
            yield first_of(sim, *pending)
            pending = [ev for ev in pending if not ev.is_set]
        return outcome


# ---------------------------------------------------------------------------
# Outage schedules: restart storms, blackouts, rolling upgrades
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OutageEvent:
    """One liveness transition: ``cache`` goes down or comes back up at
    ``time``.  ``cold`` recoveries lose all resident data (the restart
    wiped the disk); warm ones keep it (a network partition healing).

    ``kind="link"`` repurposes the event as a *network* transition: the
    ``cache`` field names a topology link (``backbone/eu-us-east``,
    ``region/us-west``, a site uplink, ...), "down" degrades its
    bandwidth to ``factor`` × nominal and "up" restores it.  Cache and
    link events interleave freely on one schedule."""

    time: float
    cache: str
    action: str  # "down" | "up"
    cold: bool = False
    kind: str = "cache"  # "cache" | "link"
    factor: float = 1.0  # link degradation multiplier (kind="link")

    def __post_init__(self) -> None:
        if self.action not in ("down", "up"):
            raise ValueError(f"unknown outage action {self.action!r}")
        if self.kind not in ("cache", "link"):
            raise ValueError(f"unknown outage kind {self.kind!r}")


class OutageSchedule:
    """A time-ordered list of :class:`OutageEvent`, with constructors
    for the three storm shapes the ROADMAP's 1000+-site north star
    cares about."""

    def __init__(self, events: Iterable[OutageEvent] = ()) -> None:
        self.events: Tuple[OutageEvent, ...] = tuple(sorted(
            events, key=lambda e: (e.time, e.cache, e.action)))

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __eq__(self, other: object) -> bool:
        # Value equality lets the sweep executor share one routing/
        # stream computation across cells whose schedules are merely
        # equal-by-construction (e.g. the same outage_rate axis value).
        if not isinstance(other, OutageSchedule):
            return NotImplemented
        return self.events == other.events

    def __hash__(self) -> int:
        # Consistent with __eq__ (the canonical sorted event tuple):
        # lets schedules graduate from the linear sharing-key scan to
        # dict/set keys without the silent identity-fallback bug PR 5
        # fixed for equality.
        return hash(self.events)

    def merge(self, other: "OutageSchedule") -> "OutageSchedule":
        return OutageSchedule([*self.events, *other.events])

    @staticmethod
    def restart_storm(caches: Sequence[str], at: float,
                      downtime: float = 30.0, stagger: float = 0.0,
                      cold: bool = True) -> "OutageSchedule":
        """Every listed cache restarts around ``at`` (``stagger`` spaces
        the kills), coming back ``downtime`` later — cold by default."""
        ev: List[OutageEvent] = []
        for i, name in enumerate(caches):
            t = at + i * stagger
            ev.append(OutageEvent(t, name, "down"))
            ev.append(OutageEvent(t + downtime, name, "up", cold=cold))
        return OutageSchedule(ev)

    @staticmethod
    def regional_blackout(caches: Sequence[str], at: float,
                          duration: float) -> "OutageSchedule":
        """All listed caches vanish together (a region's uplink died)
        and return together, warm — the data survived, the path didn't."""
        ev = [OutageEvent(at, n, "down") for n in caches]
        ev += [OutageEvent(at + duration, n, "up", cold=False)
               for n in caches]
        return OutageSchedule(ev)

    @staticmethod
    def rolling_upgrade(caches: Sequence[str], start: float,
                        downtime: float = 30.0, gap: float = 10.0,
                        cold: bool = True) -> "OutageSchedule":
        """One cache at a time: down, upgrade, back (cold), ``gap``
        seconds of full strength between members."""
        ev: List[OutageEvent] = []
        t = start
        for name in caches:
            ev.append(OutageEvent(t, name, "down"))
            ev.append(OutageEvent(t + downtime, name, "up", cold=cold))
            t += downtime + gap
        return OutageSchedule(ev)

    @staticmethod
    def link_degradation(links: Sequence[str], at: float, duration: float,
                         factor: float = 0.1) -> "OutageSchedule":
        """The listed topology links (backbone segments, regional nets,
        site uplinks — by :meth:`Topology.find_link` name) drop to
        ``factor`` × nominal bandwidth at ``at`` and recover ``duration``
        later.  The caches stay up: this is the backbone-degradation
        scenario, where tiered fill and origin traffic slow down but
        nothing fails over."""
        ev = [OutageEvent(at, n, "down", kind="link", factor=factor)
              for n in links]
        ev += [OutageEvent(at + duration, n, "up", kind="link")
               for n in links]
        return OutageSchedule(ev)


def apply_outage(fed: Federation, ev: OutageEvent,
                 group_of: Optional[Dict[str, "object"]] = None) -> None:
    """Apply one liveness transition to a federation.

    Group members go through :meth:`~repro.core.ring.CacheGroup.mark_down`
    / ``mark_up`` so group stats track the storm; stray caches toggle
    ``available`` directly (cold recoveries wipe storage).  Shared by the
    simulated engine's outage controller and the analytic engine's
    request-time replay, so both planes agree on what an
    :class:`OutageSchedule` means.
    """
    if ev.kind == "link":
        link = fed.topology.find_link(ev.cache)
        if link is None:
            raise KeyError(f"no topology link named {ev.cache!r}")
        if ev.action == "down":
            link.degrade(ev.factor)
        else:
            link.restore()
        return
    if group_of is None:
        group_of = {c.name: g for g in fed.groups.values()
                    for c in g.members}
    group = group_of.get(ev.cache)
    if group is not None:
        if ev.action == "down":
            group.mark_down(ev.cache)
        else:
            group.mark_up(ev.cache, cold=ev.cold)
        return
    cache = fed.caches[ev.cache]
    if ev.action == "down":
        cache.available = False
    else:
        if ev.cold:
            cache.clear()
        cache.available = True


# ---------------------------------------------------------------------------
# Scenario engine: trace replay under contention + outages
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ScenarioReport:
    """What one scenario produced, for benches and tests.

    The one report type for *both* execution planes: per-request rows
    (``DownloadResult`` from :meth:`ScenarioEngine.replay`,
    :class:`~repro.core.api.FetchResult` from
    :func:`~repro.core.api.run_scenario` — both carry ``seconds`` /
    ``cache_hit``) plus federation-level aggregates.  The simulator's
    event-loop telemetry (``reallocations`` / ``flow_events`` /
    ``completed_flows``) is zeroed on the analytic engine.
    """

    name: str = ""
    engine: str = "sim"
    results: List = dataclasses.field(default_factory=list)
    sim_seconds: float = 0.0
    bytes_moved: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    origin_egress_bytes: int = 0
    # cache hierarchy (collapses to tier 1 / zero on flat federations)
    parent_fill_bytes: int = 0   # bytes moved cache-to-cache (tier fills)
    tier_hits: Dict[int, int] = dataclasses.field(default_factory=dict)
    tier_misses: Dict[int, int] = dataclasses.field(default_factory=dict)
    tier_fill_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    evictions: int = 0
    bytes_evicted: int = 0
    admission_rejects: int = 0
    cache_failovers: int = 0
    hedged_fetches: int = 0
    origin_fallbacks: int = 0
    group_failovers: int = 0
    outages: int = 0
    recoveries: int = 0
    reallocations: int = 0
    flow_events: int = 0
    completed_flows: int = 0
    # control plane (all zero when no ControlPlaneSpec was attached)
    sheds: int = 0
    queue_waits: int = 0
    queue_wait_seconds: float = 0.0
    retries: int = 0
    breaker_opens: int = 0
    breaker_skips: int = 0
    auto_downs: int = 0
    auto_ups: int = 0

    @property
    def hit_rate(self) -> float:
        done = [r for r in self.results if r.seconds > 0]
        return (sum(1 for r in done if r.cache_hit) / len(done)
                if done else 0.0)

    @property
    def coalescing_ratio(self) -> float:
        """Per-arrival solves the old loop would have run, over solves
        actually run."""
        return self.flow_events / max(self.reallocations, 1)

    def seconds_percentile(self, pct: float) -> float:
        done = sorted(r.seconds for r in self.results if r.seconds > 0)
        if not done:
            return 0.0
        idx = min(len(done) - 1, int(pct / 100.0 * len(done)))
        return done[idx]

    def summary(self) -> Dict:
        done = [r.seconds for r in self.results if r.seconds > 0]
        return {
            "name": self.name,
            "engine": self.engine,
            "requests": len(self.results),
            "completed": len(done),
            "sim_seconds": self.sim_seconds,
            "hit_rate": self.hit_rate,
            "mean_seconds": sum(done) / len(done) if done else 0.0,
            "p50_seconds": self.seconds_percentile(50),
            "p95_seconds": self.seconds_percentile(95),
            "p99_seconds": self.seconds_percentile(99),
            "bytes_moved": self.bytes_moved,
            "goodput": (self.bytes_moved / self.sim_seconds
                        if self.sim_seconds > 0 else 0.0),
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "evictions": self.evictions,
            "bytes_evicted": self.bytes_evicted,
            "admission_rejects": self.admission_rejects,
            "cache_failovers": self.cache_failovers,
            "hedged_fetches": self.hedged_fetches,
            "origin_fallbacks": self.origin_fallbacks,
            "group_failovers": self.group_failovers,
            "outages": self.outages,
            "recoveries": self.recoveries,
            "origin_egress_bytes": self.origin_egress_bytes,
            "parent_fill_bytes": self.parent_fill_bytes,
            "tier_hits": {str(k): v for k, v in sorted(self.tier_hits.items())},
            "tier_misses": {str(k): v
                            for k, v in sorted(self.tier_misses.items())},
            "tier_fill_bytes": {str(k): v for k, v
                                in sorted(self.tier_fill_bytes.items())},
            "reallocations": self.reallocations,
            "flow_events": self.flow_events,
            "coalescing_ratio": self.coalescing_ratio,
            "sheds": self.sheds,
            "shed_rate": (self.sheds / len(self.results)
                          if self.results else 0.0),
            "queue_waits": self.queue_waits,
            "queue_wait_seconds": self.queue_wait_seconds,
            "retries": self.retries,
            "breaker_opens": self.breaker_opens,
            "breaker_skips": self.breaker_skips,
            "auto_downs": self.auto_downs,
            "auto_ups": self.auto_ups,
        }


def tier_tallies(caches: Iterable[CacheServer]
                 ) -> Tuple[Dict[int, int], Dict[int, int],
                            Dict[int, int], int]:
    """Per-tier (hits, misses, fill_bytes) plus total cache-to-cache
    fill bytes, from the caches' own counters.  ``fill_bytes`` is what a
    tier pulled from *upstream* (parent tier or origin) — the quantity
    split-sizing sweeps minimize at the top tier.  Shared by both
    engines' report builders so tier accounting is parity-checkable."""
    hits: Dict[int, int] = {}
    misses: Dict[int, int] = {}
    fills: Dict[int, int] = {}
    parent_fill = 0
    for c in caches:
        t = c.tier
        hits[t] = hits.get(t, 0) + c.stats.hits
        misses[t] = misses.get(t, 0) + c.stats.misses
        fills[t] = (fills.get(t, 0) + c.stats.bytes_from_parent
                    + c.stats.bytes_from_origin)
        parent_fill += c.stats.bytes_from_parent
    return hits, misses, fills, parent_fill


class ScenarioEngine:
    """Replay an access trace through simulator-native clients, with an
    optional outage schedule running concurrently."""

    def __init__(self, fed: Federation, solver: str = "auto",
                 streams: int = 8, hedge_after: Optional[float] = None,
                 max_attempts: int = 4, rank_limit: Optional[int] = 8,
                 router: str = "ring", ranking: object = None,
                 control: Optional[ControlPlaneSpec] = None) -> None:
        self.fed = fed
        self.sim = FluidFlowSim(fed.topology, fed.net, solver=solver)
        self.streams = streams
        self.hedge_after = hedge_after
        self.max_attempts = max_attempts
        self.rank_limit = rank_limit
        self.router = router
        # "static" | "probe" | a RankingPolicy instance; string specs
        # mint a fresh policy per client (per-client probe state).
        self.ranking = ranking
        self.redirector_node = fed.redirectors.members[0].node.name
        self._clients: Dict[Tuple[str, int], SimStashClient] = {}
        self._hosts = {s.name: max(1, s.workers) for s in fed.sites}
        self._group_of = {c.name: g for g in fed.groups.values()
                          for c in g.members}
        # One shared control plane per scenario: clients share breakers,
        # queues and health gauges, as a site-local sidecar would.
        self.control = (ControlPlane(control, sim=self.sim,
                                     group_of=self._group_of)
                        if control is not None else None)

    # -- clients ------------------------------------------------------------
    def client(self, site: str, worker: int = 0) -> SimStashClient:
        key = (site, worker)
        sc = self._clients.get(key)
        if sc is None:
            sc = SimStashClient(
                self.sim, self.fed.client(site, worker,
                                          ranking=self.ranking),
                self.fed.origins[0], self.redirector_node,
                streams=self.streams, hedge_after=self.hedge_after,
                max_attempts=self.max_attempts, rank_limit=self.rank_limit,
                router=self.router, redirectors=self.fed.redirectors,
                control=self.control)
            self._clients[key] = sc
        return sc

    # -- outages ------------------------------------------------------------
    def apply_outage(self, ev: OutageEvent) -> None:
        apply_outage(self.fed, ev, group_of=self._group_of)
        if ev.kind == "link":
            # Bandwidth just changed under active flows: force a max-min
            # re-solve at the next loop step.
            self.sim._flows_dirty = True

    def _outage_controller(self, schedule: OutageSchedule) -> Generator:
        for ev in schedule:
            if ev.time > self.sim.t:
                yield self.sim.delay(ev.time - self.sim.t)
            self.apply_outage(ev)

    # -- replay -------------------------------------------------------------
    def replay(self, requests: Sequence[AccessRequest],
               schedule: Optional[OutageSchedule] = None) -> ScenarioReport:
        origin = self.fed.origins[0]
        for r in requests:
            if r.path not in origin.store:
                origin.put_object(r.path, r.size)  # synthetic payloads
        results: List[DownloadResult] = []
        for r in requests:
            sc = self.client(r.site, r.worker % self._hosts.get(r.site, 1))
            res = DownloadResult(r.path, r.size, "simclient")
            results.append(res)
            self.sim.spawn(
                sc.download(r.path, result=res,
                            tenant=getattr(r, "tenant", "") or r.experiment),
                at=r.time)
        if schedule is not None and len(schedule):
            self.sim.spawn(self._outage_controller(schedule))
        self.sim.run()
        return self.report(results)

    def report(self, results: List[DownloadResult],
               name: str = "") -> ScenarioReport:
        cstats = [sc.stats for sc in self._clients.values()]
        gstats = [g.stats for g in self.fed.groups.values()]
        # Rows may be DownloadResult (no per-row byte counter: a
        # completed download moved its whole object) or FetchResult
        # (carries ``bytes`` directly).
        bytes_moved = sum(
            getattr(r, "bytes", 0) or (r.size if r.seconds > 0 else 0)
            for r in results)
        cp = self.control.stats if self.control is not None else None
        t_hits, t_misses, t_fills, parent_fill = tier_tallies(
            self.fed.caches.values())
        return ScenarioReport(
            name=name,
            engine="sim",
            results=results,
            sim_seconds=self.sim.t,
            bytes_moved=bytes_moved,
            cache_hits=sum(c.stats.hits for c in self.fed.caches.values()),
            cache_misses=sum(c.stats.misses
                             for c in self.fed.caches.values()),
            evictions=sum(c.stats.evictions
                          for c in self.fed.caches.values()),
            bytes_evicted=sum(c.stats.bytes_evicted
                              for c in self.fed.caches.values()),
            admission_rejects=sum(c.stats.admission_rejects
                                  for c in self.fed.caches.values()),
            reallocations=self.sim.reallocations,
            flow_events=self.sim.flow_events,
            completed_flows=self.sim.completed_flows,
            cache_failovers=sum(s.cache_failovers for s in cstats),
            hedged_fetches=sum(s.hedged_fetches for s in cstats),
            origin_fallbacks=sum(s.origin_fallbacks for s in cstats),
            group_failovers=sum(s.failovers for s in gstats),
            outages=sum(s.outages for s in gstats),
            recoveries=sum(s.recoveries for s in gstats),
            origin_egress_bytes=sum(o.stats.egress_bytes
                                    for o in self.fed.origins),
            parent_fill_bytes=parent_fill,
            tier_hits=t_hits, tier_misses=t_misses,
            tier_fill_bytes=t_fills,
            sheds=sum(1 for r in results if getattr(r, "shed", False)),
            queue_waits=cp.queue_waits if cp else 0,
            queue_wait_seconds=cp.queue_wait_seconds if cp else 0.0,
            retries=cp.retries if cp else 0,
            breaker_opens=cp.breaker_opens if cp else 0,
            breaker_skips=cp.breaker_skips if cp else 0,
            auto_downs=cp.auto_downs if cp else 0,
            auto_ups=cp.auto_ups if cp else 0,
        )
