"""Site HTTP forward proxies — the baseline StashCache is evaluated against.

The paper's §4.1/§5 observations, reproduced here as behaviour:

* proxies are optimised for small files (software, conditions data): they
  have near-zero client startup cost (the nearest proxy arrives via the
  environment, no discovery round-trip);
* proxies are configured **not to cache large files**: in all paper tests
  the 2.3 GB and 10 GB files were never cached (``max_cacheable_bytes``);
* proxy entries **expire rapidly** — while looping over the paper's file
  list, the first files were already gone by the end of one pass (small
  capacity + TTL);
* transfers are single-stream HTTP (window-limited on the WAN), and a miss
  goes straight to the origin — there is no redirector/federation;
* no checksums: a corrupted cached object is served silently (§6 notes
  CVMFS's checksums as a differentiator).

Objects are cached whole (HTTP granularity), not chunked.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from .chunk import ObjectMeta, Payload
from .origin import Origin
from .topology import Node
from .transfer import NetworkModel, TransferStats


@dataclasses.dataclass
class ProxyEntry:
    payload_bytes: int
    inserted_at: float
    corrupt: bool = False


@dataclasses.dataclass
class ProxyStats:
    hits: int = 0
    misses: int = 0
    uncacheable: int = 0
    expirations: int = 0
    evictions: int = 0
    bytes_served: int = 0
    bytes_from_origin: int = 0


class HTTPProxy:
    """A squid-like site forward proxy (whole-object, TTL, size-capped)."""

    def __init__(self, name: str, node: Node, origin: Origin,
                 net: NetworkModel,
                 capacity_bytes: int = 10 * 2**30,
                 max_cacheable_bytes: int = 1 * 2**30,
                 ttl_seconds: float = 3600.0,
                 mem_object_max: float = 4e9,
                 disk_bw: float = 0.9e9) -> None:
        self.name = name
        self.node = node
        self.origin = origin
        self.net = net
        self.capacity_bytes = capacity_bytes
        self.max_cacheable_bytes = max_cacheable_bytes
        self.ttl_seconds = ttl_seconds
        self.mem_object_max = mem_object_max
        self.disk_bw = disk_bw
        self._entries: "OrderedDict[str, ProxyEntry]" = OrderedDict()
        self.usage_bytes = 0
        self.stats = ProxyStats()

    # -- state machine (shared with the simulator) --------------------------
    def lookup(self, path: str, now: float) -> Optional[ProxyEntry]:
        entry = self._entries.get(path)
        if entry is None:
            self.stats.misses += 1
            return None
        if now - entry.inserted_at > self.ttl_seconds:
            # Rapid expiry: the behaviour that bit the paper's first
            # experiment design (§5).
            self._evict(path, expired=True)
            self.stats.misses += 1
            return None
        self._entries.move_to_end(path)
        self.stats.hits += 1
        return entry

    def cacheable(self, size: int) -> bool:
        return size <= self.max_cacheable_bytes

    def admit(self, path: str, size: int, now: float) -> bool:
        if not self.cacheable(size):
            self.stats.uncacheable += 1
            return False
        while self.usage_bytes + size > self.capacity_bytes and self._entries:
            self._evict(next(iter(self._entries)))
        self._entries[path] = ProxyEntry(size, now)
        self.usage_bytes += size
        return True

    def _evict(self, path: str, expired: bool = False) -> None:
        entry = self._entries.pop(path, None)
        if entry is not None:
            self.usage_bytes -= entry.payload_bytes
            if expired:
                self.stats.expirations += 1
            else:
                self.stats.evictions += 1

    def serve_rate_cap(self, object_size: int) -> float:
        if self.disk_bw and object_size > self.mem_object_max:
            return self.disk_bw
        return 0.0

    def corrupt(self, path: str) -> None:
        if path in self._entries:
            self._entries[path].corrupt = True

    def resident(self, path: str, now: float) -> bool:
        e = self._entries.get(path)
        return e is not None and (now - e.inserted_at) <= self.ttl_seconds

    # -- networked path ------------------------------------------------------
    def get_object(self, client_node: str, meta: ObjectMeta,
                   now: float = 0.0) -> Tuple[bool, TransferStats]:
        """Serve a whole object over single-stream HTTP.

        Returns (corrupt, stats).  A hit streams proxy→client; a miss
        streams origin→proxy→client (store-and-forward at HTTP granularity)
        and admits the object if it is under the cacheable size cap.
        """
        stats = TransferStats(method="http_proxy", source=self.name)
        entry = self.lookup(meta.path, now)
        corrupt = False
        if entry is None:
            # Miss: origin → proxy (single stream over the WAN), then serve.
            stats.seconds += self.net.transfer_time(
                self.origin.node.name, self.node.name, meta.size, streams=1)
            self.stats.bytes_from_origin += meta.size
            # Pull through the origin's real read path so its egress /
            # request counters see the proxy arm's load — otherwise
            # proxy-vs-stash comparisons under-report origin traffic.
            for ref in meta.chunk_refs():
                self.origin.read_chunk(meta.path, ref.index)
            self.admit(meta.path, meta.size, now)
            stats.cache_misses += 1
        else:
            corrupt = entry.corrupt
            stats.cache_hits += 1
        stats.seconds += self.net.transfer_time(
            self.node.name, client_node, meta.size, streams=1,
            rate_cap=self.serve_rate_cap(meta.size))
        stats.bytes += meta.size
        stats.chunks += 1
        self.stats.bytes_served += meta.size
        return corrupt, stats
