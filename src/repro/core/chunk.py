"""Chunking, checksums and payloads — the unit of transfer in the federation.

StashCache's CVMFS client downloads data in 24 MB chunks and stores a
checksum *along the chunk boundaries* (paper §3.1).  Every object in our
federation is therefore decomposed into fixed-size chunks, each with a
64-bit FNV-1a digest.  A chunk digest is the integrity guarantee the paper
contrasts against HTTP proxies ("CVMFS calculates checksums of the data,
which guarantees consistency ... which HTTP proxies do not provide").

Payloads may be *real* (backed by bytes — used by the data loader and
checkpoint paths) or *synthetic* (size-only — used by the discrete-event
simulator where multi-GB files must not be materialised).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

# CVMFS chunk size used by the StashCache federation (paper §3.1).
DEFAULT_CHUNK_SIZE = 24 * 2**20

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = 0xFFFFFFFFFFFFFFFF


def fnv1a64(data: bytes, seed: int = _FNV_OFFSET) -> int:
    """64-bit FNV-1a over ``data``.  Pure-python oracle for the Pallas
    ``chunk_checksum`` kernel (see ``repro.kernels.chunk_checksum``)."""
    h = seed
    for b in data:
        h = ((h ^ b) * _FNV_PRIME) & _MASK64
    return h


def synthetic_digest(path: str, index: int, size: int) -> int:
    """Deterministic digest for size-only payloads (simulator mode)."""
    return fnv1a64(f"{path}#{index}:{size}".encode())


@dataclasses.dataclass(frozen=True)
class Payload:
    """A transferable block.  ``data is None`` marks a synthetic payload."""

    size: int
    data: Optional[bytes] = None
    digest: int = 0

    @staticmethod
    def from_bytes(data: bytes) -> "Payload":
        return Payload(size=len(data), data=data, digest=fnv1a64(data))

    @staticmethod
    def synthetic(size: int, path: str = "", index: int = 0) -> "Payload":
        return Payload(size=size, data=None,
                       digest=synthetic_digest(path, index, size))

    def verify(self) -> bool:
        """Checksum validation at the chunk boundary (CVMFS behaviour)."""
        if self.data is None:
            return True
        return fnv1a64(self.data) == self.digest

    def corrupted(self) -> "Payload":
        """Return a bit-flipped copy (for integrity tests); keeps digest."""
        if self.data is None:
            return self
        flipped = bytes([self.data[0] ^ 0xFF]) + self.data[1:]
        return Payload(size=self.size, data=flipped, digest=self.digest)


@dataclasses.dataclass(frozen=True)
class ChunkRef:
    """Reference to one chunk of an object in the global namespace."""

    path: str
    index: int
    offset: int
    length: int
    digest: int

    @property
    def key(self) -> str:
        return f"{self.path}#{self.index}"


@dataclasses.dataclass
class ObjectMeta:
    """Catalog entry produced by the indexer (paper §3.1): name, size,
    permissions, mtime and checksums along chunk boundaries."""

    path: str
    size: int
    mtime: float
    mode: int = 0o644
    chunk_size: int = DEFAULT_CHUNK_SIZE
    chunk_digests: List[int] = dataclasses.field(default_factory=list)

    @property
    def num_chunks(self) -> int:
        if self.size == 0:
            return 1
        return -(-self.size // self.chunk_size)

    def chunk_refs(self) -> List[ChunkRef]:
        refs = []
        for i in range(self.num_chunks):
            off = i * self.chunk_size
            length = min(self.chunk_size, self.size - off) if self.size else 0
            refs.append(ChunkRef(self.path, i, off, length,
                                 self.chunk_digests[i]
                                 if i < len(self.chunk_digests) else 0))
        return refs

    def chunks_for_range(self, offset: int, length: int) -> List[ChunkRef]:
        """Chunks covering ``[offset, offset+length)`` — CVMFS partial
        reads download only the portions an application touches."""
        if length <= 0:
            return []
        first = offset // self.chunk_size
        last = (offset + length - 1) // self.chunk_size
        return [r for r in self.chunk_refs() if first <= r.index <= last]


def chunk_object(path: str, data: bytes,
                 chunk_size: int = DEFAULT_CHUNK_SIZE,
                 mtime: float = 0.0) -> tuple[ObjectMeta, List[Payload]]:
    """Split real bytes into chunk payloads + catalog metadata."""
    payloads: List[Payload] = []
    digests: List[int] = []
    if len(data) == 0:
        p = Payload.from_bytes(b"")
        payloads.append(p)
        digests.append(p.digest)
    else:
        for off in range(0, len(data), chunk_size):
            p = Payload.from_bytes(data[off:off + chunk_size])
            payloads.append(p)
            digests.append(p.digest)
    meta = ObjectMeta(path=path, size=len(data), mtime=mtime,
                      chunk_size=chunk_size, chunk_digests=digests)
    return meta, payloads


def synthetic_object(path: str, size: int,
                     chunk_size: int = DEFAULT_CHUNK_SIZE,
                     mtime: float = 0.0) -> tuple[ObjectMeta, List[Payload]]:
    """Size-only object for the simulator (no bytes materialised)."""
    payloads: List[Payload] = []
    digests: List[int] = []
    n = max(1, -(-size // chunk_size)) if size else 1
    for i in range(n):
        length = min(chunk_size, size - i * chunk_size) if size else 0
        p = Payload.synthetic(length, path, i)
        payloads.append(p)
        digests.append(p.digest)
    meta = ObjectMeta(path=path, size=size, mtime=mtime,
                      chunk_size=chunk_size, chunk_digests=digests)
    return meta, payloads
