"""Pluggable cache eviction and admission policies.

The paper's caches are plain LRU ("the cache may evict any resident
chunk"), but follow-on studies of the same infrastructure — the OSG data
federation (arXiv:2007.01408) and the SoCal repo lifecycle study
(arXiv:2205.05598) — show that at fleet scale the eviction policy and the
admission rule are the levers that decide hit rate and origin offload.
This module makes both pluggable on :class:`~repro.core.cache.CacheServer`
without touching its pure state-machine API.

Eviction policies rank resident chunks for victim selection:

* ``lru``  — least-recently-used (the seed behaviour, still the default);
* ``lfu``  — least-frequently-used with LRU tie-break, which protects the
  hot head of a Zipf working set from long scan-like tails;
* ``ttl``  — LRU plus a freshness bound: chunks older than ``ttl_seconds``
  are expired on access (squid-style, matching the HTTP-proxy baseline);
* ``fifo`` — insertion order, the cheapest possible bookkeeping.

Admission policies decide whether a fetched chunk is cached at all.
``SizeAwareAdmission`` refuses objects whose size exceeds a fraction of
cache capacity — one multi-TB dataset must not flush a whole site cache
(the "hot-object storm" failure mode at fleet scale).
"""
from __future__ import annotations

import copy
from collections import OrderedDict
from typing import Dict, Optional, Set, Tuple

Key = Tuple[str, int]


class EvictionPolicy:
    """Victim-selection strategy over resident chunk keys.

    The cache owns payloads and byte accounting; the policy only maintains
    the ordering metadata it needs to answer :meth:`victim`.
    """

    name = "base"

    def on_admit(self, key: Key, size: int, now: float) -> None:
        raise NotImplementedError

    def on_access(self, key: Key, now: float) -> None:
        raise NotImplementedError

    def on_remove(self, key: Key) -> None:
        raise NotImplementedError

    def victim(self, pinned: Set[Key]) -> Optional[Key]:
        """Coldest non-pinned key, or None if everything is pinned."""
        raise NotImplementedError

    def expired(self, key: Key, now: float) -> bool:
        """TTL hook: True if the entry is stale and must be refetched."""
        return False


class LRUPolicy(EvictionPolicy):
    """Least-recently-used — the seed cache's behaviour."""

    name = "lru"

    def __init__(self) -> None:
        self._order: "OrderedDict[Key, None]" = OrderedDict()

    def on_admit(self, key: Key, size: int, now: float) -> None:
        self._order[key] = None

    def on_access(self, key: Key, now: float) -> None:
        self._order.move_to_end(key)

    def on_remove(self, key: Key) -> None:
        self._order.pop(key, None)

    def victim(self, pinned: Set[Key]) -> Optional[Key]:
        if not pinned:
            return next(iter(self._order), None)
        return next((k for k in self._order if k not in pinned), None)


class FIFOPolicy(LRUPolicy):
    """Insertion order, never promoted on access."""

    name = "fifo"

    def on_access(self, key: Key, now: float) -> None:
        pass


class LFUPolicy(EvictionPolicy):
    """Least-frequently-used, LRU tie-break.

    Keys live in an OrderedDict per access count; victim selection scans
    occupied frequency buckets coldest-first, so it is O(occupied
    buckets), not O(resident keys).
    """

    name = "lfu"

    def __init__(self) -> None:
        self._count: Dict[Key, int] = {}
        self._buckets: Dict[int, "OrderedDict[Key, None]"] = {}

    def _move(self, key: Key, src: int, dst: int) -> None:
        bucket = self._buckets[src]
        bucket.pop(key, None)
        if not bucket:
            del self._buckets[src]
        self._buckets.setdefault(dst, OrderedDict())[key] = None

    def on_admit(self, key: Key, size: int, now: float) -> None:
        self._count[key] = 1
        self._buckets.setdefault(1, OrderedDict())[key] = None

    def on_access(self, key: Key, now: float) -> None:
        c = self._count[key]
        self._count[key] = c + 1
        self._move(key, c, c + 1)

    def on_remove(self, key: Key) -> None:
        c = self._count.pop(key, None)
        if c is None:
            return
        bucket = self._buckets.get(c)
        if bucket is not None:
            bucket.pop(key, None)
            if not bucket:
                del self._buckets[c]

    def victim(self, pinned: Set[Key]) -> Optional[Key]:
        if not self._count:
            return None
        for c in sorted(self._buckets):
            for k in self._buckets[c]:
                if k not in pinned:
                    return k
        return None


class TTLPolicy(LRUPolicy):
    """LRU with a freshness bound (squid-style HTTP semantics).

    A chunk older than ``ttl_seconds`` is treated as a miss on lookup and
    evicted — the consistency story of the proxy baseline, expressed as a
    cache policy so the simulator can compare it against checksummed LRU.
    """

    name = "ttl"

    def __init__(self, ttl_seconds: float = 3600.0) -> None:
        super().__init__()
        self.ttl_seconds = ttl_seconds
        self._admitted: Dict[Key, float] = {}

    def on_admit(self, key: Key, size: int, now: float) -> None:
        super().on_admit(key, size, now)
        self._admitted[key] = now

    def on_remove(self, key: Key) -> None:
        super().on_remove(key)
        self._admitted.pop(key, None)

    def expired(self, key: Key, now: float) -> bool:
        t0 = self._admitted.get(key)
        return t0 is not None and (now - t0) > self.ttl_seconds


class AdmissionPolicy:
    """Decide whether a fetched chunk enters the cache at all."""

    name = "always"

    def admit(self, key: Key, object_size: int, chunk_size: int,
              capacity: int, usage: int) -> bool:
        return True


class SizeAwareAdmission(AdmissionPolicy):
    """Refuse objects larger than ``max_object_fraction`` of capacity.

    ``object_size`` is the whole logical object (not the chunk): one
    scan of a dataset comparable to the cache must not evict the hot set.
    """

    name = "size-aware"

    def __init__(self, max_object_fraction: float = 0.1) -> None:
        self.max_object_fraction = max_object_fraction

    def admit(self, key: Key, object_size: int, chunk_size: int,
              capacity: int, usage: int) -> bool:
        return object_size <= self.max_object_fraction * capacity


EVICTION_POLICIES = {
    "lru": LRUPolicy,
    "lfu": LFUPolicy,
    "ttl": TTLPolicy,
    "fifo": FIFOPolicy,
}


def make_eviction_policy(spec, ttl_seconds: float = 3600.0) -> EvictionPolicy:
    """Build a policy from a name (``"lru"``...) or copy an instance.

    An *instance* spec is deep-copied, never passed through: one policy
    object handed to ``SiteSpec``/``build_*_federation`` with
    ``cache_replicas > 1`` would otherwise be silently shared across
    every cache server of the site, cross-contaminating victim order
    (an access on replica A reordering replica B's LRU stack).
    """
    if isinstance(spec, EvictionPolicy):
        return copy.deepcopy(spec)
    try:
        cls = EVICTION_POLICIES[spec]
    except KeyError:
        raise ValueError(
            f"unknown eviction policy {spec!r}; "
            f"choose from {sorted(EVICTION_POLICIES)}") from None
    if cls is TTLPolicy:
        return TTLPolicy(ttl_seconds)
    return cls()
