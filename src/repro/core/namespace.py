"""The federation's global namespace (paper §3).

Every origin "is registered to serve a subset of the global namespace".
Resolution is longest-prefix match, so ``/ligo`` and ``/ligo/frames`` may be
exported by different origins.  The namespace itself holds no data — it is
the registry the redirector consults.
"""
from __future__ import annotations

from typing import Dict, List, Optional


def _norm(path: str) -> str:
    if not path.startswith("/"):
        path = "/" + path
    while "//" in path:
        path = path.replace("//", "/")
    return path.rstrip("/") or "/"


class Namespace:
    """Global namespace: prefix → origin-id registry."""

    def __init__(self) -> None:
        self._prefixes: Dict[str, str] = {}

    def register(self, prefix: str, origin_id: str) -> None:
        prefix = _norm(prefix)
        existing = self._prefixes.get(prefix)
        if existing is not None and existing != origin_id:
            raise ValueError(
                f"prefix {prefix!r} already exported by {existing!r}")
        self._prefixes[prefix] = origin_id

    def unregister(self, prefix: str) -> None:
        self._prefixes.pop(_norm(prefix), None)

    def resolve(self, path: str) -> Optional[str]:
        """Longest-prefix-match owner of ``path`` (None if unclaimed)."""
        path = _norm(path)
        best: Optional[str] = None
        best_len = -1
        for prefix, origin in self._prefixes.items():
            if path == prefix or path.startswith(prefix + "/") or prefix == "/":
                if len(prefix) > best_len:
                    best, best_len = origin, len(prefix)
        return best

    def exports(self, origin_id: str) -> List[str]:
        return sorted(p for p, o in self._prefixes.items() if o == origin_id)

    def __contains__(self, path: str) -> bool:
        return self.resolve(path) is not None
