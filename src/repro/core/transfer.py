"""Network/transfer model shared by the functional path and the simulator.

The functional federation (the one that actually feeds the JAX training
loop) moves real bytes instantly but *accounts* transfer time with an
uncontended model: per-path latency plus bytes over the effective
bandwidth.  Effective bandwidth honours two facts the paper leans on:

* the bottleneck link (NIC, site uplink or WAN backbone) caps throughput;
* a single TCP stream on a long-RTT path is window-limited, which is why
  XRootD's multi-stream transfers beat single-stream HTTP for large files
  over the WAN (§3.1), while on a LAN the proxy's single stream is fine.

Contention (many flows sharing a link) is modelled only by the
discrete-event simulator (``repro.core.simulator``), which reuses this
module's per-stream cap.
"""
from __future__ import annotations

import dataclasses
from typing import List

from .topology import Link, Topology

# Default TCP window for the per-stream throughput cap.
DEFAULT_TCP_WINDOW = 16 * 2**20  # 16 MiB


@dataclasses.dataclass
class TransferStats:
    """Accounting for one logical transfer (possibly many chunks).

    ``local_hits`` counts chunks served from the *worker-local* CVMFS
    cache — those never reach the site cache tier, so they are kept out
    of ``cache_hits`` (which the engine-parity tests hold equal across
    planes) but still matter to consumers like the data loader whose
    hit-rate includes the best hit of all.
    """

    bytes: int = 0
    seconds: float = 0.0
    chunks: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    local_hits: int = 0
    method: str = ""
    source: str = ""

    def add(self, other: "TransferStats") -> "TransferStats":
        self.bytes += other.bytes
        self.seconds += other.seconds
        self.chunks += other.chunks
        self.cache_hits += other.cache_hits
        self.cache_misses += other.cache_misses
        self.local_hits += other.local_hits
        if other.source:
            self.source = other.source
        return self

    @property
    def mbps(self) -> float:
        if self.seconds <= 0:
            return float("inf")
        return self.bytes / self.seconds / 1e6


class NetworkModel:
    """Uncontended latency + bandwidth accounting over topology paths."""

    def __init__(self, topology: Topology,
                 tcp_window: int = DEFAULT_TCP_WINDOW) -> None:
        self.topology = topology
        self.tcp_window = tcp_window

    def per_stream_cap(self, rtt: float) -> float:
        """TCP window / RTT: the single-stream ceiling on long paths."""
        return self.tcp_window / max(rtt, 1e-6)

    def effective_bandwidth(self, src: str, dst: str, streams: int = 1) -> float:
        rtt = self.topology.rtt(src, dst)
        bottleneck = self.topology.bottleneck_bandwidth(src, dst)
        return min(bottleneck, max(1, streams) * self.per_stream_cap(rtt))

    def transfer_time(self, src: str, dst: str, nbytes: int,
                      streams: int = 1, handshakes: int = 1,
                      rate_cap: float = 0.0) -> float:
        """Seconds to move ``nbytes`` from src to dst, uncontended.
        ``rate_cap`` (bytes/s, 0=∞) models endpoint limits (disk)."""
        rtt = self.topology.rtt(src, dst)
        bw = self.effective_bandwidth(src, dst, streams)
        if rate_cap:
            bw = min(bw, rate_cap)
        return handshakes * rtt + nbytes / bw

    def rpc_time(self, src: str, dst: str) -> float:
        """A small request/response (redirector locate, GeoIP lookup...)."""
        return self.topology.rtt(src, dst)
