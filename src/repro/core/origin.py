"""Data origins — the authoritative source of data in the federation (§3).

An origin is installed on the researcher's (or, in the TPU mapping, the
dataset/checkpoint) storage and exports a subset of the global namespace.
Caches contact the origin to retrieve data on a miss; the origin never
pushes.  Egress accounting on the origin is what the paper's WAN-offload
argument (Fig. 5) is measured against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple, Union

from .chunk import (DEFAULT_CHUNK_SIZE, ObjectMeta, Payload, chunk_object,
                    synthetic_object)
from .topology import Node


class ChunkStore:
    """Content store: object catalog + chunk payloads."""

    def __init__(self) -> None:
        self.objects: Dict[str, ObjectMeta] = {}
        self.chunks: Dict[Tuple[str, int], Payload] = {}

    def put(self, meta: ObjectMeta, payloads: Iterable[Payload]) -> None:
        self.objects[meta.path] = meta
        for i, p in enumerate(payloads):
            self.chunks[(meta.path, i)] = p

    def delete(self, path: str) -> None:
        meta = self.objects.pop(path, None)
        if meta is not None:
            for i in range(meta.num_chunks):
                self.chunks.pop((path, i), None)

    def get_chunk(self, path: str, index: int) -> Optional[Payload]:
        return self.chunks.get((path, index))

    def __contains__(self, path: str) -> bool:
        return path in self.objects

    @property
    def total_bytes(self) -> int:
        return sum(m.size for m in self.objects.values())


@dataclasses.dataclass
class OriginStats:
    chunk_requests: int = 0
    egress_bytes: int = 0
    locate_queries: int = 0


class Origin:
    """Authoritative data source exporting namespace prefixes."""

    def __init__(self, name: str, node: Node,
                 exports: Iterable[str] = ("/",),
                 chunk_size: int = DEFAULT_CHUNK_SIZE) -> None:
        self.name = name
        self.node = node
        self.exports = list(exports)
        self.chunk_size = chunk_size
        self.store = ChunkStore()
        self.stats = OriginStats()
        self.available = True  # failure injection point

    # -- data management ---------------------------------------------------
    def put_object(self, path: str, data: Union[bytes, int],
                   mtime: float = 0.0) -> ObjectMeta:
        """Store real bytes, or a synthetic object when given an int size."""
        if isinstance(data, (bytes, bytearray)):
            meta, payloads = chunk_object(path, bytes(data),
                                          self.chunk_size, mtime)
        else:
            meta, payloads = synthetic_object(path, int(data),
                                              self.chunk_size, mtime)
        self.store.put(meta, payloads)
        return meta

    def delete_object(self, path: str) -> None:
        self.store.delete(path)

    def touch(self, path: str, mtime: float,
              new_size: Optional[int] = None) -> None:
        """Modify an object in place (drives indexer re-index detection)."""
        meta = self.store.objects[path]
        if new_size is not None and new_size != meta.size:
            if self.store.get_chunk(path, 0) is not None and \
                    self.store.get_chunk(path, 0).data is not None:
                self.put_object(path, b"\x00" * new_size, mtime)
            else:
                self.put_object(path, new_size, mtime)
        else:
            meta.mtime = mtime

    # -- federation-facing API ----------------------------------------------
    def has(self, path: str) -> bool:
        """Redirector query: does this origin hold ``path``?"""
        self.stats.locate_queries += 1
        return self.available and path in self.store

    def meta(self, path: str) -> ObjectMeta:
        return self.store.objects[path]

    def read_chunk(self, path: str, index: int) -> Payload:
        if not self.available:
            raise ConnectionError(f"origin {self.name} unavailable")
        payload = self.store.get_chunk(path, index)
        if payload is None:
            raise FileNotFoundError(f"{path}#{index} not at origin {self.name}")
        self.stats.chunk_requests += 1
        self.stats.egress_bytes += payload.size
        return payload

    def list_objects(self) -> List[ObjectMeta]:
        return list(self.store.objects.values())
