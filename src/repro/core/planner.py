"""Predictive capacity planner: invert fitted cache models by autodiff.

The sweep engine *describes* configurations it has exactly replayed;
this module *prescribes*.  Given the per-cache differentiable models a
``fit=`` sweep produced (:mod:`repro.kernels.cache_model`), it answers
both directions:

* **forward** (:func:`predict`) — hit rate / origin egress at capacity
  points no sweep cell ever replayed, straight from the smoothed
  Mattson curves;
* **inverse** (:func:`plan_capacity`) — minimize total fleet capacity
  subject to a target fleet hit rate (and optionally an origin-egress
  budget), with one capacity variable per *site* (every cache of a
  site shares the ``SiteSpec.cache_capacity`` knob, including the
  backbone sites of an L1×L2 hierarchy).

The inverse solve is an augmented-Lagrangian gradient descent in
log-capacity, fully jitted — inner Adam rounds inside
``lax.fori_loop``, outer dual updates with a geometrically rising
penalty weight, zero host round-trips — then
a monotone *repair* bisection rescales the solution onto the
constraint surface (the smoothed curves are monotone in capacity, so
feasibility-by-scaling is exact on the model).  The same jitted solve
also bisects the minimal *uniform* capacity meeting the target, which
seeds the descent and prices the ``savings_vs_uniform`` headline.

Model-level feasibility is not replay-level feasibility (bucketing and
smoothing error, FIFO columns fitted by spline): recommendations are
**verified** by replaying the recommended point through the exact
batched kernels (:func:`verify_plan` → :func:`~repro.core.api.
run_sweep` with a single cell), scaling capacities up by a bounded
backoff until the exact replay meets the target — so a returned plan's
``verification`` block is ground truth, not model output.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.kernels.cache_model import (CacheModel, StackedModels,
                                       fleet_hit_rate, fleet_origin_egress,
                                       predict_hit_rate, predict_miss_bytes,
                                       stack_models)


@dataclasses.dataclass(frozen=True)
class PlannerSpec:
    """One inverse-planning problem.

    ``models`` maps cache-server name → fitted :class:`CacheModel`
    (histogram-backed kinds; what ``run_sweep(fit=True)`` returns).
    ``groups`` maps capacity-variable name → the cache names sharing
    that variable; by default every cache is its own variable, and
    :func:`groups_for_federation` builds the per-site grouping that
    matches the ``SiteSpec.cache_capacity`` knob.
    """

    models: Dict[str, CacheModel]
    target_hit_rate: float = 0.95
    target_egress_bytes: Optional[float] = None
    groups: Optional[Dict[str, List[str]]] = None
    min_capacity: float = 64e6
    max_capacity: float = 1e16
    steps: int = 600
    lr: float = 0.05
    penalty: float = 10.0           # initial augmented-Lagrangian weight ρ
    penalty_growth: float = 100.0   # final ρ = penalty * growth
    margin: float = 0.002           # plan for target + margin (smoothing slack)


@dataclasses.dataclass
class PlanReport:
    """What the planner recommends, plus how it got there.

    ``capacities`` are per group (per site under
    :func:`groups_for_federation`); ``per_cache`` expands groups to
    cache-server names.  ``verification`` is ``None`` until
    :func:`verify_plan` has replayed the point through the exact
    kernels."""

    capacities: Dict[str, float]
    per_cache: Dict[str, float]
    predicted_hit_rate: float
    predicted_egress_bytes: float
    total_capacity: float
    uniform_capacity: float
    uniform_total: float
    savings_vs_uniform: float
    target_hit_rate: float
    target_egress_bytes: Optional[float] = None
    wall_seconds: float = 0.0
    telemetry: Dict[str, float] = dataclasses.field(default_factory=dict)
    verification: Optional[Dict] = None

    def summary(self) -> Dict:
        """JSON-safe form — the ``plan.json`` artifact schema."""
        return {
            "capacities": {k: float(v) for k, v in self.capacities.items()},
            "per_cache": {k: float(v) for k, v in self.per_cache.items()},
            "predicted_hit_rate": float(self.predicted_hit_rate),
            "predicted_egress_bytes": float(self.predicted_egress_bytes),
            "total_capacity": float(self.total_capacity),
            "uniform_capacity": float(self.uniform_capacity),
            "uniform_total": float(self.uniform_total),
            "savings_vs_uniform": float(self.savings_vs_uniform),
            "target_hit_rate": float(self.target_hit_rate),
            "target_egress_bytes": (float(self.target_egress_bytes)
                                    if self.target_egress_bytes is not None
                                    else None),
            "wall_seconds": float(self.wall_seconds),
            "telemetry": {k: float(v) for k, v in self.telemetry.items()},
            "verification": dict(self.verification)
            if self.verification is not None else None,
        }


def groups_for_federation(fed, models: Dict[str, CacheModel]
                          ) -> Dict[str, List[str]]:
    """Site-name → cache-names grouping matching the per-site
    ``SiteSpec.cache_capacity`` knob (only caches with a fitted model
    count; a site whose caches saw no traffic gets no variable)."""
    out: Dict[str, List[str]] = {}
    for s in fed.sites:
        names = [n for n in s.cache_names() if n in models]
        if names:
            out[s.name] = names
    return out


def predict(models: Dict[str, CacheModel], capacities) -> Dict:
    """Forward mode: hit rate / egress at an *unswept* capacity point.

    ``capacities`` is a scalar (uniform) or a dict of cache name →
    bytes.  Works for every model kind (interp included), weighting
    per-cache curves by reference counts — so a fleet at heterogeneous
    capacities prices in one call, no replay."""
    names = sorted(models)
    caps = {n: float(capacities[n] if isinstance(capacities, dict)
                     else capacities) for n in names}
    hits = refs = egress = 0.0
    per_cache: Dict[str, float] = {}
    for n in names:
        mdl = models[n]
        h = float(predict_hit_rate(mdl, caps[n]))
        per_cache[n] = h
        w = max(mdl.total_refs, 1.0)
        hits += h * w
        refs += w
        egress += mdl.origin_fraction * float(predict_miss_bytes(mdl,
                                                                 caps[n]))
    return {"hit_rate": hits / max(refs, 1.0),
            "origin_egress_bytes": egress,
            "per_cache_hit_rate": per_cache}


def _solve(stacked: StackedModels, gidx: np.ndarray, gsize: np.ndarray,
           spec: PlannerSpec):
    """The jitted inverse solve.  Returns per-group capacities plus the
    uniform baseline and end-point telemetry, all computed on-device:
    bisection → augmented-Lagrangian Adam rounds → repair bisection."""
    target = spec.target_hit_rate + spec.margin
    budget = spec.target_egress_bytes
    lo, hi = np.log(spec.min_capacity), np.log(spec.max_capacity)
    G = len(gsize)
    gidx_j = jnp.asarray(gidx)
    gsize_j = jnp.asarray(gsize, jnp.float64)

    def hit_at(u):
        return fleet_hit_rate(stacked, jnp.exp(u)[gidx_j])

    def egress_at(u):
        return fleet_origin_egress(stacked, jnp.exp(u)[gidx_j])

    def feasible(u):
        ok = hit_at(u) >= target
        if budget is not None:
            ok = ok & (egress_at(u) <= budget)
        return ok

    def bisect(pred, ulo, uhi, iters=64):
        """Smallest scalar ``u`` in [ulo, uhi] with pred(u) true —
        pred monotone (hit rises, egress falls with capacity)."""
        def body(_, carry):
            a, b = carry
            mid = 0.5 * (a + b)
            good = pred(mid)
            return jnp.where(good, a, mid), jnp.where(good, mid, b)
        _, b = jax.lax.fori_loop(0, iters, body,
                                 (jnp.asarray(lo), jnp.asarray(hi))
                                 if ulo is None else (ulo, uhi))
        return b

    rounds = 8
    inner = max(spec.steps // rounds, 1)
    rho_growth = spec.penalty_growth ** (1.0 / max(rounds - 1, 1))

    @jax.jit
    def run():
        # uniform baseline: minimal single capacity meeting the target
        u_uni = bisect(lambda u: feasible(jnp.full(G, u)), None, None)
        u0 = jnp.full(G, u_uni)
        # normalize cost by the uniform total so its gradient is O(1/G)
        # — commensurate with the constraint term, which is what lets
        # Adam traverse *along* the constraint surface instead of
        # freezing at the first feasible point it touches
        scale = jnp.maximum((gsize_j * jnp.exp(u0)).sum(), 1.0)

        def cost(u):
            return (gsize_j * jnp.exp(u)).sum() / scale

        # augmented Lagrangian for the inequality constraints: the
        # multiplier term keeps a smooth restoring gradient even when
        # feasible (a one-sided quadratic penalty goes flat there, so
        # descent just slides back to uniform); at the stationary point
        # cost' = ν·h' per coordinate — the KKT marginal-value balance
        # that prices saturated caches down and hot caches up.
        def lagrangian(u, nu, nu2, rho):
            c = target - hit_at(u)
            aug = jnp.maximum(nu + rho * c, 0.0)
            val = cost(u) + (aug ** 2 - nu ** 2) / (2.0 * rho)
            if budget is not None:
                c2 = (egress_at(u) - budget) / max(budget, 1.0)
                aug2 = jnp.maximum(nu2 + rho * c2, 0.0)
                val = val + (aug2 ** 2 - nu2 ** 2) / (2.0 * rho)
            return val

        grad_fn = jax.grad(lagrangian)

        def outer(r, carry):
            u, mom, vel, nu, nu2, rho = carry

            def step(i, inner_carry):
                u, mom, vel = inner_carry
                g = grad_fn(u, nu, nu2, rho)
                mom = 0.9 * mom + 0.1 * g
                # β2=0.99: short second-moment memory, so one round's
                # constraint spike can't damp the next round's steps
                vel = 0.99 * vel + 0.01 * g * g
                t = r * inner + i + 1.0
                u = u - spec.lr * (mom / (1 - 0.9 ** t)) / (
                    jnp.sqrt(vel / (1 - 0.99 ** t)) + 1e-8)
                return jnp.clip(u, lo, hi), mom, vel

            u, mom, vel = jax.lax.fori_loop(0, inner, step, (u, mom, vel))
            nu = jnp.maximum(nu + rho * (target - hit_at(u)), 0.0)
            if budget is not None:
                nu2 = jnp.maximum(
                    nu2 + rho * (egress_at(u) - budget) / max(budget, 1.0),
                    0.0)
            return u, mom, vel, nu, nu2, rho * rho_growth

        u, _, _, _, _, _ = jax.lax.fori_loop(
            0, rounds, outer,
            (u0, jnp.zeros(G), jnp.zeros(G), jnp.asarray(0.0),
             jnp.asarray(0.0), jnp.asarray(float(spec.penalty))))
        # repair: rescale onto the constraint surface (monotone in the
        # global multiplier, so bisection is exact on the model)
        m = bisect(lambda s: feasible(u + s), jnp.asarray(-8.0),
                   jnp.asarray(8.0))
        u = jnp.clip(u + m, lo, hi)
        gnorm = jnp.linalg.norm(jax.grad(hit_at)(u))
        return (jnp.exp(u), jnp.exp(u_uni), hit_at(u), egress_at(u),
                gnorm)

    return run()


def plan_capacity(spec: PlannerSpec, federation=None) -> PlanReport:
    """Inverse planning: minimal total fleet capacity meeting
    ``spec.target_hit_rate`` (and the egress budget, if set).

    ``federation`` (a :class:`~repro.core.federation.FederationSpec`)
    switches the variables to per-site grouping via
    :func:`groups_for_federation` when ``spec.groups`` is unset.
    The returned report is model-level; chase it with
    :func:`verify_plan` for exact-replay ground truth."""
    t0 = time.perf_counter()
    groups = spec.groups
    if groups is None:
        groups = (groups_for_federation(federation, spec.models)
                  if federation is not None
                  else {n: [n] for n in spec.models})
    gnames = sorted(groups)
    stacked = stack_models(spec.models)
    pos = {n: i for i, n in enumerate(stacked.names)}
    gidx = np.zeros(len(stacked.names), np.int64)
    gsize = np.zeros(len(gnames))
    for gi, g in enumerate(gnames):
        for cache in groups[g]:
            gidx[pos[cache]] = gi
        gsize[gi] = len(groups[g])
    with enable_x64():
        caps, uni, pred_hit, pred_egress, gnorm = (
            np.asarray(x, np.float64) for x in _solve(
                stacked, gidx, gsize, spec))
    capacities = {g: float(caps[gi]) for gi, g in enumerate(gnames)}
    per_cache = {cache: capacities[g]
                 for g in gnames for cache in groups[g]}
    total = float((gsize * caps).sum())
    uniform_total = float(gsize.sum() * uni)
    return PlanReport(
        capacities=capacities, per_cache=per_cache,
        predicted_hit_rate=float(pred_hit),
        predicted_egress_bytes=float(pred_egress),
        total_capacity=total, uniform_capacity=float(uni),
        uniform_total=uniform_total,
        savings_vs_uniform=1.0 - total / max(uniform_total, 1.0),
        target_hit_rate=spec.target_hit_rate,
        target_egress_bytes=spec.target_egress_bytes,
        wall_seconds=time.perf_counter() - t0,
        telemetry={"hit_grad_norm": float(gnorm),
                   "groups": float(len(gnames)),
                   "caches": float(len(stacked.names)),
                   "steps": float(spec.steps)})


def apply_capacities(fed, capacities: Dict[str, float]):
    """``fed`` with every named site's ``cache_capacity`` replaced —
    the bridge from a plan (per-site bytes) back to a runnable
    :class:`~repro.core.federation.FederationSpec`."""
    sites = [dataclasses.replace(s, cache_capacity=capacities[s.name])
             if s.name in capacities else s for s in fed.sites]
    return dataclasses.replace(fed, sites=sites)


def _exact_point(base, capacities: Dict[str, float]) -> Dict:
    """Replay one capacity point through the exact batched kernels."""
    from repro.core.api import SweepSpec, run_sweep
    cspec = dataclasses.replace(
        base, federation=apply_capacities(base.federation, capacities))
    report = run_sweep(SweepSpec(name="verify", base=cspec, axes={}))
    cell = report.cells[0]
    s = cell.summary
    refs = s["cache_hits"] + s["cache_misses"]
    return {"hit_rate": s["cache_hits"] / max(refs, 1),
            "origin_egress_bytes": s["origin_egress_bytes"],
            "executor": cell.executor}


def verify_plan(report: PlanReport, base, max_attempts: int = 6,
                scale: float = 1.25) -> PlanReport:
    """Ground-truth a plan against the exact batched kernels.

    Replays ``base`` (a :class:`~repro.core.api.ScenarioSpec`; its
    federation's site names must match the plan's group names) at the
    recommended capacities.  If the exact replay falls short of the
    target — model smoothing error — capacities scale up by ``scale``
    and replay again, at most ``max_attempts`` times, so the returned
    plan is *always* feasible when any capacity in range is (the
    property suite asserts this).  Returns the report with
    ``capacities``/``totals`` updated to the verified point and a
    ``verification`` block recording the evidence."""
    caps = dict(report.capacities)
    attempts = 0
    applied = 1.0
    exact: Dict = {}
    while True:
        attempts += 1
        exact = _exact_point(base, caps)
        ok = exact["hit_rate"] >= report.target_hit_rate
        if report.target_egress_bytes is not None:
            ok = ok and (exact["origin_egress_bytes"]
                         <= report.target_egress_bytes)
        if ok or attempts >= max_attempts:
            break
        caps = {k: v * scale for k, v in caps.items()}
        applied *= scale
    per_cache = {c: v * applied for c, v in report.per_cache.items()}
    total = sum(per_cache.values())
    return dataclasses.replace(
        report, capacities=caps, per_cache=per_cache,
        total_capacity=total,
        savings_vs_uniform=1.0 - total / max(report.uniform_total, 1.0),
        verification={
            "achieved_hit_rate": float(exact["hit_rate"]),
            "achieved_egress_bytes": float(exact["origin_egress_bytes"]),
            "target_hit_rate": float(report.target_hit_rate),
            "feasible": bool(exact["hit_rate"] >= report.target_hit_rate),
            "attempts": attempts,
            "scale_applied": applied,
            "executor": exact["executor"],
        })
