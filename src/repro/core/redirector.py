"""Redirectors — the data-discovery service (paper §3).

Caches query the redirector for the location of data; the redirector polls
its subscribed origins and returns the hostname of the one that holds the
path.  StashCache runs *two* redirectors in a round-robin, high-availability
configuration; ``RedirectorPair`` reproduces that: requests alternate
between the two, and a dead redirector is skipped transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

from .namespace import Namespace
from .origin import Origin
from .topology import Node


@dataclasses.dataclass
class RedirectorStats:
    locate_requests: int = 0
    origin_polls: int = 0
    not_found: int = 0


class Redirector:
    """A single redirector instance."""

    def __init__(self, name: str, node: Node) -> None:
        self.name = name
        self.node = node
        self.namespace = Namespace()
        self.origins: Dict[str, Origin] = {}
        self.stats = RedirectorStats()
        self.available = True  # failure injection point

    def subscribe(self, origin: Origin) -> None:
        """Origins subscribe to the redirector (paper §3)."""
        self.origins[origin.name] = origin
        for prefix in origin.exports:
            self.namespace.register(prefix, origin.name)

    def unsubscribe(self, origin: Union[Origin, str]) -> None:
        """Unregister an origin *and* its namespace prefixes.

        Without the prefix cleanup, multi-origin scenarios that retire an
        origin leave dangling namespace entries whose longest-prefix match
        makes ``locate`` poll a dead owner forever.  Prefixes are taken
        from the namespace (not ``origin.exports``) so prefixes registered
        after subscription are cleaned up too.
        """
        name = origin.name if isinstance(origin, Origin) else origin
        self.origins.pop(name, None)
        for prefix in self.namespace.exports(name):
            self.namespace.unregister(prefix)

    def locate(self, path: str) -> Optional[Origin]:
        """Find the origin that holds ``path``.

        The namespace gives the candidate by longest-prefix match; the
        redirector then *asks the origin* whether it really has the file
        (the paper's query-the-origins step), falling back to polling all
        subscribed origins if the prefix owner denies it.
        """
        if not self.available:
            raise ConnectionError(f"redirector {self.name} unavailable")
        self.stats.locate_requests += 1
        owner = self.namespace.resolve(path)
        if owner is not None:
            self.stats.origin_polls += 1
            origin = self.origins[owner]
            if origin.has(path):
                return origin
        for origin in self.origins.values():
            if origin.name == owner:
                continue
            self.stats.origin_polls += 1
            if origin.has(path):
                return origin
        self.stats.not_found += 1
        return None


class RedirectorGroup:
    """N redirectors in round-robin, high-availability configuration.

    The paper runs exactly two; fleet deployments want the same idiom at
    arbitrary width (and the cache tier reuses the generalized failover
    semantics via :mod:`repro.core.ring`): requests rotate across live
    members, dead members are skipped transparently and counted as
    failovers, and only when *every* member is down does the group raise.
    """

    def __init__(self, members: List[Redirector]) -> None:
        if not members:
            raise ValueError("a redirector group needs at least one member")
        self.members = list(members)
        self._next = 0
        self.failovers = 0

    def subscribe(self, origin: Origin) -> None:
        for r in self.members:
            r.subscribe(origin)

    def unsubscribe(self, origin: Union[Origin, str]) -> None:
        for r in self.members:
            r.unsubscribe(origin)

    def locate(self, path: str) -> Optional[Origin]:
        for attempt in range(len(self.members)):
            r = self.members[self._next % len(self.members)]
            self._next += 1
            if not r.available:
                self.failovers += 1
                continue
            return r.locate(path)
        raise ConnectionError("all redirectors unavailable")

    @property
    def stats(self) -> RedirectorStats:
        agg = RedirectorStats()
        for r in self.members:
            agg.locate_requests += r.stats.locate_requests
            agg.origin_polls += r.stats.origin_polls
            agg.not_found += r.stats.not_found
        return agg


class RedirectorPair(RedirectorGroup):
    """The paper's two-member deployment (§3)."""

    def __init__(self, primary: Redirector, secondary: Redirector) -> None:
        super().__init__([primary, secondary])
