"""One federation access API — the unified data plane (paper §3).

The paper's value proposition is the *federation interface*: clients name
data by path, and the federation (redirectors, namespace, caches) resolves
and serves it.  This module is that interface as a typed protocol with two
interchangeable engines:

* :class:`AnalyticPlane` — instant execution over the functional
  federation (:class:`~repro.core.client.StashClient` /
  :class:`~repro.core.proxy.HTTPProxy`): transfers move real or synthetic
  bytes immediately and *account* time with the uncontended
  :class:`~repro.core.transfer.NetworkModel`.
* :class:`SimulatedPlane` — the same requests replayed as coroutines on
  the fluid-flow discrete-event simulator
  (:class:`~repro.core.simclient.SimStashClient` /
  :class:`~repro.core.simulator.FluidFlowSim`), with max-min link
  contention, collapsed forwarding, hedged fetches and outage schedules.

Callers write ``plane.fetch("/ospool/file")`` identically on either plane
and get a :class:`FetchResult` back — the type that unifies the old
``TransferStats`` (analytic) and ``DownloadResult`` (simulated) shapes.
Path resolution is namespace-first: the owning origin comes from
longest-prefix match through :class:`~repro.core.redirector.Redirector` /
:class:`~repro.core.namespace.Namespace`, never from a held origin or
cache reference.

On top of the planes sits the declarative layer: a
:class:`ScenarioSpec` names a federation
(:class:`~repro.core.federation.FederationSpec`), a workload
(:class:`WorkloadSpec` or an explicit request list), an optional
:class:`~repro.core.simclient.OutageSchedule`, the solver and the engine;
:func:`run_scenario` builds a fresh federation, publishes the workload's
objects, executes every request on the chosen engine and aggregates a
:class:`ScenarioReport`.  Because the spec is inert data, the *same*
scenario runs on both engines — which is what the engine-parity tests
and the CI smoke assert.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import re
import time
from typing import (Dict, Generator, List, Optional, Protocol, Sequence,
                    Set, Tuple, Union, runtime_checkable)

import numpy as np

from .client import StashClient
from .controlplane import ControlPlane, ControlPlaneSpec
from .federation import Federation, FederationSpec, SiteSpec
from .routing import RankingPolicy
from .simclient import (OutageSchedule, ScenarioEngine, ScenarioReport,
                        apply_outage, tier_tallies)
from .simulator import direct_download, proxy_download, sparse_flow_problem
from .topology import Coord
from .transfer import TransferStats
from .workload import (AccessRequest, abusive_workload,
                       checkpoint_restart_workload, dataloader_workload,
                       flash_crowd_workload, generate_workload,
                       herd_workload, shard_serving_workload, split_bytes,
                       storm_workload)

GB = 10**9


# ---------------------------------------------------------------------------
# Typed request/response models
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FetchRequest:
    """One named-data fetch: *what* (path), *where from* (site/worker),
    *how* (method) and *when* (arrival time, simulated plane).

    ``offset``/``length`` select a byte range (``length=-1`` = to EOF);
    only the analytic ``cvmfs`` method moves partial objects — the
    simulated plane and the whole-file methods account the full object.
    ``want_data=True`` asks for the assembled bytes on
    :attr:`FetchResult.data` (analytic plane; the simulator moves no
    real bytes).  ``avoid`` names a cache to skip for this request —
    the hedging hook consumers use to force the next-nearest replica.
    """

    path: str
    site: str = ""          # requesting site; "" = first worker-bearing site
    worker: int = 0
    method: str = "stash"   # "stash" | "cvmfs" | "proxy" | "direct"
    at: float = 0.0         # arrival time (sim clock; analytic outage clock)
    size: int = 0           # size hint for publishing synthetic objects
    streams: int = 0        # 0 = plane default
    tenant: str = ""        # fair-share / quota accounting unit
    offset: int = 0         # byte-range start (cvmfs partial reads)
    length: int = -1        # byte-range length; -1 = through EOF
    want_data: bool = False  # attach assembled bytes to the result
    avoid: str = ""         # cache name to skip (hedged refetch)

    METHODS = ("stash", "cvmfs", "proxy", "direct")

    def __post_init__(self) -> None:
        if self.method not in self.METHODS:
            raise ValueError(f"unknown fetch method {self.method!r}")
        if self.offset < 0:
            raise ValueError(f"negative offset {self.offset}")
        if self.length < -1:
            raise ValueError(f"bad length {self.length} (use -1 for EOF)")


@dataclasses.dataclass
class FetchResult:
    """What one fetch did — the unification of the analytic path's
    ``TransferStats`` and the simulator's ``DownloadResult``.

    ``seconds`` is accounted (analytic) or simulated (sim) wall time;
    ``bytes`` is what crossed the last hop to the worker; chunk-level
    ``cache_hits``/``cache_misses`` are exact on the analytic plane and
    derived from the hit/miss status on the simulated plane (per-chunk
    splits under concurrency live in the federation's ``CacheStats``).
    """

    path: str
    size: int = 0
    method: str = ""
    plane: str = ""         # "analytic" | "sim"
    seconds: float = 0.0
    bytes: int = 0
    chunks: int = 0
    cache_hit: bool = False
    cache_hits: int = 0
    cache_misses: int = 0
    waited: bool = False    # collapsed-forwarding wait (sim)
    hedged: bool = False    # a backup fetch was raced (sim)
    source: str = ""        # cache/proxy/origin that served the last hop
    failovers: int = 0
    start: float = 0.0
    ok: bool = True
    error: str = ""
    shed: bool = False      # refused by an admission queue (load shedding)
    queue_seconds: float = 0.0  # time parked in admission queues
    local_hits: int = 0     # chunks served by the worker-local CVMFS cache
    data: Optional[bytes] = None  # assembled bytes (want_data, analytic)

    @classmethod
    def from_transfer(cls, path: str, stats: TransferStats, *,
                      method: str, start: float = 0.0) -> "FetchResult":
        """Analytic-plane constructor: fold a ``TransferStats``."""
        return cls(path=path, size=stats.bytes, method=method,
                   plane="analytic", seconds=stats.seconds,
                   bytes=stats.bytes, chunks=stats.chunks,
                   cache_hit=(stats.cache_misses == 0
                              and stats.cache_hits > 0),
                   cache_hits=stats.cache_hits,
                   cache_misses=stats.cache_misses,
                   local_hits=stats.local_hits,
                   source=stats.source, start=start)


@dataclasses.dataclass
class StatResult:
    """Namespace-first metadata lookup: does the federation know the
    path, how big is it, and which origin exports it."""

    path: str
    found: bool
    size: int = 0
    num_chunks: int = 0
    chunk_size: int = 0
    origin: str = ""


# ---------------------------------------------------------------------------
# The protocol both engines implement
# ---------------------------------------------------------------------------
@runtime_checkable
class DataPlane(Protocol):
    """The one federation access API.

    Implementations hold a :class:`Federation`; callers hold only paths.
    ``fetch`` accepts a bare path (all defaults) or a
    :class:`FetchRequest`; ``fetch_all`` executes a workload — under
    contention with an optional outage schedule on the simulated plane,
    in request-time order with outage events interleaved on the analytic
    plane.  ``publish``/``stat`` route through the redirectors'
    namespace (longest-prefix), so multi-origin federations work without
    the caller ever naming an origin.
    """

    name: str
    fed: Federation

    def stat(self, path: str) -> StatResult: ...

    def publish(self, path: str, data: Union[bytes, int],
                mtime: float = 0.0) -> StatResult: ...

    def fetch(self, request: Union[str, FetchRequest]) -> FetchResult: ...

    def fetch_all(self, requests: Sequence[FetchRequest],
                  schedule: Optional[OutageSchedule] = None,
                  sequential: bool = False) -> List[FetchResult]: ...

    def store(self, path: str, data: Union[bytes, int], site: str = "",
              worker: int = 0) -> FetchResult: ...

    def drain(self, max_objects: Optional[int] = None) -> FetchResult: ...

    def paths(self, prefix: str = "/") -> List[str]: ...


class _PlaneBase:
    """Namespace-first resolution shared by both engines."""

    name = ""

    def __init__(self, fed: Federation) -> None:
        self.fed = fed
        # Per-cache write-back overlays, minted on first store() to that
        # cache (the write path of the unified API).
        self._writebacks: Dict[str, "WritebackCache"] = {}

    def stat(self, path: str) -> StatResult:
        try:
            origin = self.fed.redirectors.locate(path)
        except ConnectionError:
            origin = None
        if origin is None:
            return StatResult(path=path, found=False)
        meta = origin.meta(path)
        return StatResult(path=path, found=True, size=meta.size,
                          num_chunks=meta.num_chunks,
                          chunk_size=meta.chunk_size, origin=origin.name)

    def publish(self, path: str, data: Union[bytes, int],
                mtime: float = 0.0) -> StatResult:
        origin = self.fed.resolve_origin(path)
        if origin is None:
            raise KeyError(f"no origin exports a prefix of {path!r}")
        meta = origin.put_object(path, data, mtime=mtime)
        return StatResult(path=path, found=True, size=meta.size,
                          num_chunks=meta.num_chunks,
                          chunk_size=meta.chunk_size, origin=origin.name)

    def _default_site(self) -> str:
        for s in self.fed.sites:
            if s.workers > 0:
                return s.name
        return self.fed.sites[0].name

    def _req(self, request: Union[str, FetchRequest]) -> FetchRequest:
        req = (FetchRequest(path=request) if isinstance(request, str)
               else request)
        if not req.site:
            req = dataclasses.replace(req, site=self._default_site())
        return req

    # -- the write path ------------------------------------------------------
    def store(self, path: str, data: Union[bytes, int], site: str = "",
              worker: int = 0) -> FetchResult:
        """Write an object through the *write-back cache tier*: bytes land
        (pinned, dirty) in the cache nearest the requesting worker and the
        write acks against cache residency; :meth:`drain` pushes dirty
        objects to their owning origin under the drain rate limit.

        Writes are accounted with the uncontended network model on both
        engines (the simulator contends reads, not writes).
        """
        site = site or self._default_site()
        node = _worker_node(self.fed, site, worker)
        cache = self.fed.nearest_cache(node, path)
        wb = self._writebacks.get(cache.name)
        if wb is None:
            wb = self._writebacks[cache.name] = self.fed.writeback(cache.name)
        meta, st = wb.write(node, path, data)
        return FetchResult(path=path, size=meta.size, method="writeback",
                           plane=self.name, seconds=st.seconds,
                           bytes=st.bytes, chunks=st.chunks,
                           source=cache.name)

    def drain(self, max_objects: Optional[int] = None) -> FetchResult:
        """Flush every dirty write-back object to its origin."""
        agg = FetchResult(path="", method="writeback-drain",
                          plane=self.name)
        for name in sorted(self._writebacks):
            st = self._writebacks[name].drain(max_objects)
            agg.seconds += st.seconds
            agg.bytes += st.bytes
            agg.chunks += st.chunks
        agg.size = agg.bytes
        return agg

    def paths(self, prefix: str = "/") -> List[str]:
        """Every federation path under ``prefix``: origin catalogs plus
        dirty (not-yet-drained) write-back objects — read-your-writes."""
        out: Set[str] = set()
        for origin in self.fed.origins:
            for meta in origin.list_objects():
                if meta.path.startswith(prefix):
                    out.add(meta.path)
        for wb in self._writebacks.values():
            for p in wb.dirty_paths():
                if p.startswith(prefix):
                    out.add(p)
        return sorted(out)


# ---------------------------------------------------------------------------
# Engine 1: analytic (functional federation, uncontended accounting)
# ---------------------------------------------------------------------------
class AnalyticPlane(_PlaneBase):
    """Instant execution with :class:`NetworkModel` time accounting.

    ``stash`` fetches go through the real :class:`StashClient` fallback
    chain restricted to the cache-served methods (``xrootd``/``http``) —
    the worker-local CVMFS cache is *not* consulted, so the cache tier
    sees the same lookups the simulated plane produces (engine parity).
    ``cvmfs`` exposes the POSIX read path (worker-local chunk cache
    included); ``proxy`` is the squid baseline; ``direct`` bypasses the
    cache tier entirely.
    """

    name = "analytic"

    def __init__(self, fed: Federation, streams: int = 8,
                 ranking: Union[str, RankingPolicy, None] = None,
                 control: Optional[ControlPlaneSpec] = None) -> None:
        super().__init__(fed)
        self.streams = streams
        # string specs mint a fresh policy per client (per-client probe
        # state); a policy instance is shared deliberately.
        self.ranking = ranking
        self.clients: Dict[Tuple[str, int], StashClient] = {}
        group_of = {c.name: g for g in fed.groups.values()
                    for c in g.members}
        self.control = (ControlPlane(control, group_of=group_of)
                        if control is not None else None)

    def client(self, site: str, worker: int = 0) -> StashClient:
        key = (site, worker)
        c = self.clients.get(key)
        if c is None:
            c = self.fed.client(site, worker, ranking=self.ranking)
            c.control = self.control
            self.clients[key] = c
        return c

    # -- the one entry point -------------------------------------------------
    def fetch(self, request: Union[str, FetchRequest]) -> FetchResult:
        req = self._req(request)
        try:
            if req.avoid:
                return self._fetch_avoiding(req)
            return self._fetch(req)
        except (FileNotFoundError, ConnectionError, KeyError) as e:
            return FetchResult(path=req.path, method=req.method,
                               plane=self.name, start=req.at,
                               ok=False, error=f"{type(e).__name__}: {e}")

    def _fetch_avoiding(self, req: FetchRequest) -> FetchResult:
        """Serve ``req`` as if ``req.avoid`` were down — the hedged-
        refetch hook: consumers race a straggler against the
        next-nearest replica without reaching into the cache tier."""
        cache = self.fed.caches.get(req.avoid)
        if cache is None or not cache.available:
            return self._fetch(req)
        cache.available = False
        try:
            return self._fetch(req)
        finally:
            cache.available = True

    def _fetch(self, req: FetchRequest) -> FetchResult:
        client = self.client(req.site, req.worker)
        client.now = max(client.now, req.at)
        # Admission control happens at the cache the request would be
        # served from (the first live ranked cache).  ``reserve`` is
        # side-effect free, so a shed terminates the request without
        # touching the cache tier; the measured service time is
        # committed into the queue model after the transfer.
        queue_name = None
        queue_start = None
        if (self.control is not None and client.caches
                and req.method in ("stash", "cvmfs")):
            queue_name = next(
                (c.name for c in client._ranked_caches(path=req.path)
                 if c.available), None)
            if queue_name is not None:
                q = self.control.queue(queue_name)
                queue_start = q.reserve(req.at, req.tenant)
                if queue_start is None:
                    return FetchResult(
                        path=req.path, method="shed", plane=self.name,
                        start=req.at, ok=False, shed=True,
                        source=queue_name,
                        error="shed: admission queue full")
        data: Optional[bytes] = None
        if req.method == "stash":
            try:
                data, stats = client.copy(req.path,
                                          methods=("xrootd", "http"))
            except (FileNotFoundError, ConnectionError):
                # Every ranked cache failed: like the simulated client,
                # the federation degrades to a direct origin pull — but
                # only if the path actually exists.
                if not self.stat(req.path).found:
                    raise
                client.stats.origin_fallbacks += 1
                res = self._fetch_direct(req, client)
                res.method = "origin-direct"
                res.start = req.at
                return res
        elif req.method == "cvmfs":
            data, stats = client.read(
                req.path, offset=req.offset,
                length=req.length if req.length >= 0 else None)
        elif req.method == "proxy":
            res = self._fetch_proxy(req, client)
            res.start = req.at
            return res
        else:  # direct
            res = self._fetch_direct(req, client)
            res.start = req.at
            return res
        res = FetchResult.from_transfer(req.path, stats, method=req.method,
                                        start=req.at)
        if req.want_data:
            res.data = data
        if queue_name is not None and queue_start is not None:
            wait = self.control.queue(queue_name).commit(
                req.at, queue_start, res.seconds, req.tenant)
            res.queue_seconds = wait
            res.seconds += wait
        return res

    def _fetch_proxy(self, req: FetchRequest,
                     client: StashClient) -> FetchResult:
        proxy = self.fed.proxies.get(req.site)
        if proxy is None:
            raise KeyError(f"site {req.site!r} has no HTTP proxy")
        origin = self.fed.redirectors.locate(req.path)
        if origin is None:
            raise FileNotFoundError(req.path)
        meta = origin.meta(req.path)
        _, stats = proxy.get_object(client.node.name, meta, now=req.at)
        return FetchResult(
            path=req.path, size=meta.size, method="proxy",
            plane=self.name, seconds=stats.seconds, bytes=stats.bytes,
            chunks=stats.chunks, cache_hit=stats.cache_hits > 0,
            cache_hits=stats.cache_hits, cache_misses=stats.cache_misses,
            source=stats.source)

    def _fetch_direct(self, req: FetchRequest,
                      client: StashClient) -> FetchResult:
        origin = self.fed.redirectors.locate(req.path)
        if origin is None:
            raise FileNotFoundError(req.path)
        meta = origin.meta(req.path)
        streams = req.streams or self.streams
        seconds = self.fed.net.transfer_time(
            origin.node.name, client.node.name, meta.size, streams=streams)
        for ref in meta.chunk_refs():
            origin.read_chunk(req.path, ref.index)  # egress accounting
        return FetchResult(
            path=req.path, size=meta.size, method="direct",
            plane=self.name, seconds=seconds, bytes=meta.size,
            chunks=meta.num_chunks, cache_misses=meta.num_chunks,
            source=origin.name)

    def fetch_all(self, requests: Sequence[FetchRequest],
                  schedule: Optional[OutageSchedule] = None,
                  sequential: bool = False) -> List[FetchResult]:
        """Requests in arrival order, outage events interleaved by time.

        The analytic plane is sequential by construction (transfers are
        instantaneous), so ``sequential`` is accepted for protocol
        symmetry and ignored.
        """
        events = list(schedule) if schedule is not None else []
        group_of = {c.name: g for g in self.fed.groups.values()
                    for c in g.members} if events else {}
        results: List[Optional[FetchResult]] = [None] * len(requests)
        order = sorted(range(len(requests)),
                       key=lambda i: self._req(requests[i]).at)
        ei = 0
        for i in order:
            req = self._req(requests[i])
            while ei < len(events) and events[ei].time <= req.at:
                apply_outage(self.fed, events[ei], group_of=group_of)
                ei += 1
            results[i] = self.fetch(req)
        while ei < len(events):
            apply_outage(self.fed, events[ei], group_of=group_of)
            ei += 1
        return [r for r in results if r is not None]


# ---------------------------------------------------------------------------
# Engine 2: simulated (fluid-flow DES, contention + outages)
# ---------------------------------------------------------------------------
class SimulatedPlane(_PlaneBase):
    """The same API, replayed as coroutines under max-min contention.

    Wraps a :class:`~repro.core.simclient.ScenarioEngine` for its sim,
    per-(site, worker) :class:`SimStashClient` pool and outage
    controller.  ``fetch`` runs one request to completion; ``fetch_all``
    spawns the whole workload (concurrently by arrival time, or
    ``sequential`` for protocols like the paper's 4-download experiment
    where requests must not compete) and runs the sim once.
    """

    name = "sim"

    def __init__(self, fed: Federation, solver: str = "auto",
                 streams: int = 8, hedge_after: Optional[float] = None,
                 max_attempts: int = 4, rank_limit: Optional[int] = 8,
                 router: str = "ring",
                 ranking: Union[str, RankingPolicy, None] = None,
                 control: Optional[ControlPlaneSpec] = None) -> None:
        super().__init__(fed)
        self.engine = ScenarioEngine(
            fed, solver=solver, streams=streams, hedge_after=hedge_after,
            max_attempts=max_attempts, rank_limit=rank_limit, router=router,
            ranking=ranking, control=control)
        self.streams = streams

    @property
    def control(self) -> Optional[ControlPlane]:
        return self.engine.control

    @property
    def sim(self):
        return self.engine.sim

    @property
    def clients(self):
        return self.engine._clients

    # -- coroutines ----------------------------------------------------------
    def _download(self, req: FetchRequest, res: FetchResult) -> Generator:
        sim = self.sim
        origin = self.fed.redirectors.locate(req.path)
        if origin is None:
            res.ok = False
            res.error = f"FileNotFoundError: {req.path}"
            return
        meta = origin.meta(req.path)
        res.size = meta.size
        res.chunks = meta.num_chunks
        if req.method in ("stash", "cvmfs"):
            # The simulator models no worker-local cache; cvmfs degrades
            # to the cache-served path (same chunks, same accounting).
            # Byte ranges and want_data degrade likewise: the fluid-flow
            # sim moves whole synthetic objects, never real bytes.
            sc = self.engine.client(req.site, req.worker)
            yield from sc.download(req.path, meta=meta, result=res,
                                   tenant=req.tenant)
            if res.shed:
                res.ok = False
                res.error = res.error or "shed: admission queue full"
        elif req.method == "proxy":
            proxy = self.fed.proxies.get(req.site)
            if proxy is None:
                res.ok = False
                res.error = f"KeyError: site {req.site!r} has no HTTP proxy"
                return
            wnode = self.engine.client(req.site, req.worker).node_name
            yield from proxy_download(sim, wnode, proxy, origin.node.name,
                                      meta, result=res)
            res.method = "proxy"
        else:  # direct
            wnode = self.engine.client(req.site, req.worker).node_name
            yield from direct_download(sim, wnode, origin.node.name, meta,
                                       streams=req.streams or self.streams,
                                       result=res)
            origin.stats.egress_bytes += meta.size
            res.source = origin.name
        if res.seconds > 0:
            res.bytes = meta.size
            if res.cache_hit:
                res.cache_hits = res.chunks
            else:
                res.cache_misses = res.chunks

    def _chain(self, pairs: List[Tuple[FetchRequest, FetchResult]]
               ) -> Generator:
        for req, res in pairs:
            if req.at > self.sim.t:
                yield self.sim.delay(req.at - self.sim.t)
            yield from self._download(req, res)

    # -- the one entry point -------------------------------------------------
    def fetch(self, request: Union[str, FetchRequest]) -> FetchResult:
        return self.fetch_all([self._req(request)], sequential=True)[0]

    def fetch_all(self, requests: Sequence[FetchRequest],
                  schedule: Optional[OutageSchedule] = None,
                  sequential: bool = False) -> List[FetchResult]:
        reqs = [self._req(r) for r in requests]
        results = [FetchResult(path=r.path, method=r.method,
                               plane=self.name) for r in reqs]
        if sequential:
            self.sim.spawn(self._chain(list(zip(reqs, results))))
        else:
            for req, res in zip(reqs, results):
                # A reused plane's clock has advanced past early arrival
                # times; never schedule into the past (the sim clock is
                # monotonic).
                self.sim.spawn(self._download(req, res),
                               at=max(req.at, self.sim.t))
        if schedule is not None and len(schedule):
            self.sim.spawn(self.engine._outage_controller(schedule))
        self.sim.run()
        return results


# ---------------------------------------------------------------------------
# Legacy adapter: a DataPlane facade over bare client/writeback objects
# ---------------------------------------------------------------------------
class ClientPlane:
    """Deprecation adapter: the :class:`DataPlane` surface over a bare
    :class:`~repro.core.client.StashClient` and/or
    :class:`~repro.core.writeback.WritebackCache`.

    Exists only so pre-redesign call sites
    (``FederatedDataLoader(client=...)``,
    ``FederatedCheckpointer(writeback=..., client=...)``) keep working;
    new code should build an :class:`AnalyticPlane` /
    :class:`SimulatedPlane` from a :class:`Federation` and let the plane
    mint clients.  The adapter serves ``cvmfs``/``stash`` fetches through
    the held client, stores through the held write-back cache, and has no
    federation (``fed is None``) — ``publish`` is unsupported.
    """

    name = "client"

    def __init__(self, client: Optional[StashClient] = None,
                 writeback=None) -> None:
        if client is None and writeback is None:
            raise ValueError("ClientPlane needs a client or a writeback")
        self.client = client
        self.writeback = writeback
        self.fed = None

    # -- reads ---------------------------------------------------------------
    def stat(self, path: str) -> StatResult:
        meta = None
        if self.client is not None:
            meta = self.client._meta(path)
        if meta is None and self.writeback is not None:
            meta = self.writeback.cache.locate_meta(path)
        if meta is None:
            return StatResult(path=path, found=False)
        return StatResult(path=path, found=True, size=meta.size,
                          num_chunks=meta.num_chunks,
                          chunk_size=meta.chunk_size)

    def publish(self, path: str, data: Union[bytes, int],
                mtime: float = 0.0) -> StatResult:
        raise NotImplementedError(
            "the legacy ClientPlane adapter holds no federation; "
            "publish through an AnalyticPlane/SimulatedPlane")

    def fetch(self, request: Union[str, FetchRequest]) -> FetchResult:
        req = (FetchRequest(path=request) if isinstance(request, str)
               else request)
        if self.client is None:
            return FetchResult(path=req.path, method=req.method,
                               plane=self.name, ok=False,
                               error="RuntimeError: adapter holds no client")
        try:
            if req.avoid:
                cache = self.client.caches.get(req.avoid)
                if cache is not None and cache.available:
                    cache.available = False
                    try:
                        return self._fetch(req)
                    finally:
                        cache.available = True
            return self._fetch(req)
        except (FileNotFoundError, ConnectionError, KeyError,
                RuntimeError) as e:
            return FetchResult(path=req.path, method=req.method,
                               plane=self.name, start=req.at,
                               ok=False, error=f"{type(e).__name__}: {e}")

    def _fetch(self, req: FetchRequest) -> FetchResult:
        if req.method == "cvmfs":
            data, stats = self.client.read(
                req.path, offset=req.offset,
                length=req.length if req.length >= 0 else None)
        elif req.method == "stash":
            data, stats = self.client.copy(req.path,
                                           methods=("xrootd", "http"))
        else:
            raise RuntimeError(
                f"legacy adapter serves stash/cvmfs only, not "
                f"{req.method!r}")
        res = FetchResult.from_transfer(req.path, stats, method=req.method,
                                        start=req.at)
        if req.want_data:
            res.data = data
        res.plane = self.name
        return res

    def fetch_all(self, requests: Sequence[FetchRequest],
                  schedule: Optional[OutageSchedule] = None,
                  sequential: bool = False) -> List[FetchResult]:
        if schedule is not None and len(schedule):
            raise NotImplementedError(
                "the legacy ClientPlane adapter cannot apply outages")
        return [self.fetch(r) for r in requests]

    # -- writes --------------------------------------------------------------
    def store(self, path: str, data: Union[bytes, int], site: str = "",
              worker: int = 0) -> FetchResult:
        if self.writeback is None:
            raise RuntimeError("adapter holds no write-back cache")
        node = (self.client.node.name if self.client is not None
                else self.writeback.cache.node.name)
        meta, st = self.writeback.write(node, path, data)
        return FetchResult(path=path, size=meta.size, method="writeback",
                           plane=self.name, seconds=st.seconds,
                           bytes=st.bytes, chunks=st.chunks,
                           source=self.writeback.cache.name)

    def drain(self, max_objects: Optional[int] = None) -> FetchResult:
        if self.writeback is None:
            raise RuntimeError("adapter holds no write-back cache")
        st = self.writeback.drain(max_objects)
        return FetchResult(path="", size=st.bytes, method="writeback-drain",
                           plane=self.name, seconds=st.seconds,
                           bytes=st.bytes, chunks=st.chunks)

    def paths(self, prefix: str = "/") -> List[str]:
        if self.writeback is None:
            raise RuntimeError("adapter holds no write-back cache")
        out: Set[str] = set()
        for r in self.writeback.redirectors.members:
            for origin in r.origins.values():
                for meta in origin.list_objects():
                    if meta.path.startswith(prefix):
                        out.add(meta.path)
        for p in self.writeback.dirty_paths():
            if p.startswith(prefix):
                out.add(p)
        return sorted(out)


# ---------------------------------------------------------------------------
# Declarative scenarios
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A declarative workload: a restart ``storm`` (every worker pulls
    the same object) or a production-shaped ``zipf`` trace (Table 2
    sizes, Table 1 experiment mix).  ``sites=None`` targets every
    worker-bearing site of the federation.

    The model-traffic kinds turn LM training/serving into federation
    workloads (see :meth:`from_model_config`): ``restart`` — every
    worker re-fetches a sharded checkpoint's manifest plus its
    model-parallel rank's shards; ``serve`` — Zipf-popular reads over a
    model's weight shards; ``dataloader`` — sequential striped dataset
    reads.  For those, ``path`` is the object prefix, ``n_objects`` the
    shard count and ``total_bytes`` the exact byte total the shard
    sizes sum to.
    """

    kind: str = "zipf"   # "zipf" | "storm" | "herd" | "abusive" |
    #                      "flash_crowd" | "restart" | "serve" | "dataloader"
    sites: Optional[Sequence[str]] = None
    # zipf trace knobs
    n_requests: int = 100
    duration: float = 3600.0
    working_set: int = 64
    zipf_a: float = 1.2
    seed: int = 0
    # storm / herd knobs
    path: str = "/ckpt/step/params"
    size: int = 2 * GB
    at: float = 0.0
    workers_per_site: int = 1
    jitter: float = 0.0
    # herd knobs (repeated synchronized waves on hot objects)
    waves: int = 1
    wave_gap: float = 30.0
    n_objects: int = 1
    # tenant mix (zipf/abusive): tenant name -> weight; None = tenant
    # defaults to the owning experiment
    tenants: Optional[Dict[str, float]] = None
    tenant: str = ""                 # fixed tenant for storm/herd traces
    # abusive-client knobs (zipf background + one cache-busting tenant)
    abusive_tenant: str = "abuser"
    abuse_factor: float = 4.0
    abuse_at: float = 0.0
    abuse_duration: float = 60.0
    # flash-crowd knobs (zipf background + one region hammering a small
    # hot set; ``size`` doubles as the hot-object size, ``n_objects`` as
    # the hot-set cardinality)
    hot_sites: Optional[Sequence[str]] = None
    crowd_factor: float = 3.0
    crowd_at: float = 0.0
    crowd_duration: float = 120.0
    # model-traffic knobs (restart/serve/dataloader; ``path`` is the
    # object prefix, ``n_objects`` the shard count, ``waves`` doubles as
    # the dataloader epoch count)
    total_bytes: int = 0             # exact checkpoint/model/dataset bytes
    manifest_bytes: int = 64_000     # restart: the shard manifest object
    tp_degree: int = 1               # restart: model-parallel shard fan-out
    step_gap: float = 1.0            # dataloader: seconds between shards
    model: str = ""                  # provenance (from_model_config)

    KINDS = ("zipf", "storm", "herd", "abusive", "flash_crowd",
             "restart", "serve", "dataloader")

    def __post_init__(self) -> None:
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}")

    @classmethod
    def from_model_config(cls, cfg, kind: str = "restart", *,
                          dataset=None, shard_bytes: int = GB,
                          **overrides) -> "WorkloadSpec":
        """Build a model-traffic workload from an
        :class:`~repro.configs.base.ArchConfig` — scenario authors never
        hand-compute shard sizes.

        ``restart``/``serve`` size the shard set from
        ``cfg.param_count()`` × the parameter dtype width (bfloat16 = 2
        bytes), split into ``ceil(total / shard_bytes)`` shards;
        ``dataloader`` sizes it from a
        :class:`~repro.data.dataset.DatasetSpec` (a default one is
        derived from the config when not given).  The generated shard
        sizes are validated to sum *exactly* to the byte total, and a
        restart workload is additionally checked for full checkpoint
        coverage per site.
        """
        if kind not in ("restart", "serve", "dataloader"):
            raise ValueError(
                f"from_model_config builds restart/serve/dataloader "
                f"workloads, not {kind!r}")
        if kind == "dataloader":
            if dataset is None:
                from ..data.dataset import DatasetSpec
                dataset = DatasetSpec(cfg.name, vocab_size=cfg.vocab_size)
            total = dataset.shard_bytes * dataset.num_shards
            defaults = dict(kind=kind, path=dataset.prefix,
                            total_bytes=total,
                            n_objects=dataset.num_shards, model=cfg.name)
        else:
            width = {"bfloat16": 2, "float16": 2, "float32": 4,
                     "float64": 8, "int8": 1}.get(cfg.dtype)
            if width is None:
                raise ValueError(f"unknown parameter dtype {cfg.dtype!r}")
            total = cfg.param_count() * width
            n_shards = max(1, -(-total // int(shard_bytes)))
            prefix = (f"/ckpt/{cfg.name}/step_00000000" if kind == "restart"
                      else f"/models/{cfg.name}")
            defaults = dict(kind=kind, path=prefix, total_bytes=total,
                            n_objects=n_shards, model=cfg.name)
        defaults.update(overrides)
        spec = cls(**defaults)
        # The invariant the satellite asks for: generated request sizes
        # reconcile against the config's byte totals, exactly.
        sizes = split_bytes(spec.total_bytes, max(spec.n_objects, 1))
        if sum(sizes) != spec.total_bytes:
            raise ValueError(
                f"shard sizes sum to {sum(sizes)}, expected "
                f"{spec.total_bytes}")
        if spec.kind == "restart" and \
                spec.workers_per_site >= spec.tp_degree:
            per_site = sum(sz for p, sz in spec.object_bytes().items()
                           if not p.endswith("manifest.json"))
            if per_site != spec.total_bytes:
                raise ValueError(
                    f"restart workload covers {per_site} bytes per site, "
                    f"expected the full checkpoint ({spec.total_bytes})")
        return spec

    def object_bytes(self) -> Dict[str, int]:
        """Distinct object sizes this workload touches (single-site dry
        run; paths and sizes are site-independent) — what the byte-total
        validation and synthetic publishing reconcile against."""
        out: Dict[str, int] = {}
        for r in self._trace(["probe-site"]):
            out[r.path] = max(out.get(r.path, 0), r.size)
        return out

    def build(self, fed: Federation, method: str = "stash"
              ) -> List[FetchRequest]:
        sites = (list(self.sites) if self.sites
                 else [s.name for s in fed.sites if s.workers > 0])
        trace = self._trace(sites)
        hosts = {s.name: max(1, s.workers) for s in fed.sites}
        return [FetchRequest(path=r.path, site=r.site,
                             worker=r.worker % hosts.get(r.site, 1),
                             method=method, at=r.time, size=r.size,
                             tenant=(self.tenant or r.tenant
                                     or r.experiment))
                for r in trace]

    def _trace(self, sites: Sequence[str]) -> List[AccessRequest]:
        if self.kind == "restart":
            return checkpoint_restart_workload(
                sites, prefix=self.path, total_bytes=self.total_bytes,
                n_shards=max(self.n_objects, 1),
                workers_per_site=self.workers_per_site,
                tp_degree=self.tp_degree, at=self.at, jitter=self.jitter,
                seed=self.seed, manifest_bytes=self.manifest_bytes,
                tenant=self.tenant or "restart")
        if self.kind == "serve":
            return shard_serving_workload(
                sites, prefix=self.path, total_bytes=self.total_bytes,
                n_shards=max(self.n_objects, 1),
                n_requests=self.n_requests, duration=self.duration,
                zipf_a=self.zipf_a, seed=self.seed,
                tenant=self.tenant or "serving")
        if self.kind == "dataloader":
            return dataloader_workload(
                sites, prefix=self.path, total_bytes=self.total_bytes,
                n_shards=max(self.n_objects, 1),
                workers_per_site=self.workers_per_site,
                epochs=max(self.waves, 1), at=self.at,
                step_gap=self.step_gap,
                tenant=self.tenant or "dataloader")
        if self.kind == "storm":
            trace = storm_workload(sites, path=self.path, size=self.size,
                                   at=self.at,
                                   workers_per_site=self.workers_per_site,
                                   jitter=self.jitter, seed=self.seed)
        elif self.kind == "herd":
            trace = herd_workload(sites, path=self.path, size=self.size,
                                  at=self.at,
                                  workers_per_site=self.workers_per_site,
                                  jitter=self.jitter, seed=self.seed,
                                  waves=self.waves, wave_gap=self.wave_gap,
                                  n_objects=self.n_objects,
                                  tenant=self.tenant or "herd")
        elif self.kind == "abusive":
            trace = abusive_workload(sites, self.n_requests,
                                     duration=self.duration, seed=self.seed,
                                     working_set=self.working_set,
                                     zipf_a=self.zipf_a,
                                     tenants=self.tenants,
                                     abusive_tenant=self.abusive_tenant,
                                     abuse_factor=self.abuse_factor,
                                     abuse_at=self.abuse_at,
                                     abuse_duration=self.abuse_duration,
                                     abuse_size=self.size)
        elif self.kind == "flash_crowd":
            hot = (list(self.hot_sites) if self.hot_sites
                   else sites[:1])
            trace = flash_crowd_workload(sites, hot, self.n_requests,
                                         duration=self.duration,
                                         seed=self.seed,
                                         working_set=self.working_set,
                                         zipf_a=self.zipf_a,
                                         crowd_factor=self.crowd_factor,
                                         crowd_at=self.crowd_at,
                                         crowd_duration=self.crowd_duration,
                                         hot_objects=max(self.n_objects, 1),
                                         hot_size=self.size)
        else:
            trace = generate_workload(sites, self.n_requests,
                                      duration=self.duration,
                                      seed=self.seed,
                                      working_set=self.working_set,
                                      zipf_a=self.zipf_a,
                                      tenants=self.tenants)
        return trace


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One scenario, declaratively: federation + workload + outages +
    solver + engine.  Executed by :func:`run_scenario`; the same spec
    runs on either engine (``engine="sim" | "analytic"``)."""

    name: str
    federation: FederationSpec
    workload: Union[WorkloadSpec, Sequence[FetchRequest],
                    Sequence[AccessRequest]]
    outages: Optional[OutageSchedule] = None
    engine: str = "sim"
    method: str = "stash"            # default for declarative workloads
    sequential: bool = False         # chain requests (no competition)
    solver: str = "auto"
    streams: int = 8
    hedge_after: Optional[float] = None
    max_attempts: int = 4
    rank_limit: Optional[int] = 8
    router: str = "ring"
    # cache-selection policy: "static" (GeoIP order, the vectorizable
    # default) or "probe" (latency-EWMA re-ranking); a RankingPolicy
    # instance is shared across the scenario's clients.
    ranking: Union[str, RankingPolicy, None] = "static"
    control: Optional[ControlPlaneSpec] = None

    def __post_init__(self) -> None:
        if self.engine not in ("sim", "analytic"):
            raise ValueError(f"unknown engine {self.engine!r}")

    def requests(self, fed: Federation) -> List[FetchRequest]:
        if isinstance(self.workload, WorkloadSpec):
            return self.workload.build(fed, method=self.method)
        hosts = {s.name: max(1, s.workers) for s in fed.sites}
        out: List[FetchRequest] = []
        for r in self.workload:
            if isinstance(r, AccessRequest):
                out.append(FetchRequest(
                    path=r.path, site=r.site,
                    worker=r.worker % hosts.get(r.site, 1),
                    method=self.method, at=r.time, size=r.size,
                    tenant=getattr(r, "tenant", "") or r.experiment))
            else:
                out.append(r)
        return out

    def plane(self, fed: Federation) -> DataPlane:
        if self.engine == "analytic":
            return AnalyticPlane(fed, streams=self.streams,
                                 ranking=self.ranking,
                                 control=self.control)
        return SimulatedPlane(
            fed, solver=self.solver, streams=self.streams,
            hedge_after=self.hedge_after, max_attempts=self.max_attempts,
            rank_limit=self.rank_limit, router=self.router,
            ranking=self.ranking, control=self.control)


def run_scenario(spec: ScenarioSpec,
                 federation: Optional[Federation] = None) -> ScenarioReport:
    """Execute one declarative scenario end to end.

    Builds a fresh federation from the spec (pass ``federation`` to reuse
    one), publishes every workload path that no origin holds yet
    (namespace-routed synthetic objects), executes the workload on the
    chosen engine, and aggregates the report.
    """
    fed = federation if federation is not None else spec.federation.build()
    plane = spec.plane(fed)
    reqs = spec.requests(fed)
    sizes: Dict[str, int] = {}
    for r in reqs:
        sizes[r.path] = max(sizes.get(r.path, 0), r.size)
    for path, size in sizes.items():
        # Only requests that *declare* a size get a synthetic object; a
        # sizeless request for an unpublished path must fail visibly
        # (ok=False / FileNotFoundError), not fetch 0 bytes happily.
        if size > 0 and not plane.stat(path).found:
            plane.publish(path, size)
    # Federation counters are lifetime totals; snapshot them so a reused
    # federation (``federation=``) reports only *this* scenario's deltas.
    base = _fed_totals(fed)
    results = plane.fetch_all(reqs, schedule=spec.outages,
                              sequential=spec.sequential)
    rep = _report(spec, fed, plane, results)
    for field, before in base.items():
        cur = getattr(rep, field)
        if isinstance(before, dict):
            setattr(rep, field, {k: cur.get(k, 0) - before.get(k, 0)
                                 for k in sorted(set(cur) | set(before))})
        else:
            setattr(rep, field, cur - before)
    return rep


def _fed_totals(fed: Federation) -> Dict[str, object]:
    """The federation-lifetime counters a ScenarioReport aggregates."""
    gstats = [g.stats for g in fed.groups.values()]
    cstats = [c.stats for c in fed.caches.values()]
    t_hits, t_misses, t_fills, parent_fill = tier_tallies(
        fed.caches.values())
    return {
        "cache_hits": sum(c.hits for c in cstats),
        "cache_misses": sum(c.misses for c in cstats),
        "origin_egress_bytes": sum(o.stats.egress_bytes
                                   for o in fed.origins),
        "parent_fill_bytes": parent_fill,
        "tier_hits": t_hits,
        "tier_misses": t_misses,
        "tier_fill_bytes": t_fills,
        "evictions": sum(c.evictions for c in cstats),
        "bytes_evicted": sum(c.bytes_evicted for c in cstats),
        "admission_rejects": sum(c.admission_rejects for c in cstats),
        "group_failovers": sum(s.failovers for s in gstats),
        "outages": sum(s.outages for s in gstats),
        "recoveries": sum(s.recoveries for s in gstats),
    }


def _report(spec: ScenarioSpec, fed: Federation, plane: DataPlane,
            results: List[FetchResult]) -> ScenarioReport:
    if isinstance(plane, SimulatedPlane):
        return plane.engine.report(results, name=spec.name)
    cstats = [c.stats for c in plane.clients.values()]
    gstats = [g.stats for g in fed.groups.values()]
    cp = plane.control.stats if plane.control is not None else None
    t_hits, t_misses, t_fills, parent_fill = tier_tallies(
        fed.caches.values())
    return ScenarioReport(
        name=spec.name,
        engine=plane.name,
        results=results,
        bytes_moved=sum(r.bytes for r in results),
        cache_hits=sum(c.stats.hits for c in fed.caches.values()),
        cache_misses=sum(c.stats.misses for c in fed.caches.values()),
        origin_egress_bytes=sum(o.stats.egress_bytes for o in fed.origins),
        parent_fill_bytes=parent_fill,
        tier_hits=t_hits, tier_misses=t_misses, tier_fill_bytes=t_fills,
        evictions=sum(c.stats.evictions for c in fed.caches.values()),
        bytes_evicted=sum(c.stats.bytes_evicted
                          for c in fed.caches.values()),
        admission_rejects=sum(c.stats.admission_rejects
                              for c in fed.caches.values()),
        cache_failovers=sum(s.cache_failovers for s in cstats),
        hedged_fetches=sum(s.hedged_fetches for s in cstats),
        origin_fallbacks=sum(s.origin_fallbacks for s in cstats),
        group_failovers=sum(s.failovers for s in gstats),
        outages=sum(s.outages for s in gstats),
        recoveries=sum(s.recoveries for s in gstats),
        sheds=sum(1 for r in results if getattr(r, "shed", False)),
        queue_waits=cp.queue_waits if cp else 0,
        queue_wait_seconds=cp.queue_wait_seconds if cp else 0.0,
        retries=cp.retries if cp else 0,
        breaker_opens=cp.breaker_opens if cp else 0,
        breaker_skips=cp.breaker_skips if cp else 0,
        auto_downs=cp.auto_downs if cp else 0,
        auto_ups=cp.auto_ups if cp else 0,
    )


# ---------------------------------------------------------------------------
# Batched scenario sweeps
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A ScenarioSpec template crossed with parameter axes.

    ``axes`` maps an axis name to its values; the sweep is the full
    cross product in axis order (last axis fastest).  Axis names route
    to the template:

    * ``"workload.<field>"`` — a :class:`WorkloadSpec` field
      (``zipf_a``, ``working_set``, ``n_requests``, ``seed``, ...);
    * ``"federation.<field>"`` — a :class:`~repro.core.federation.
      FederationSpec` field, or a :class:`~repro.core.federation.
      SiteSpec` field (``cache_replicas``, ``cache_capacity``,
      ``eviction_policy``, ``workers``, ...) applied to every matching
      site;
    * ``"outage_rate"`` — synthetic axis: that fraction of the
      federation's caches cold-restarts mid-run (a
      :meth:`~repro.core.simclient.OutageSchedule.restart_storm` at
      half the workload horizon, down for a quarter of it);
    * any other name — a :class:`ScenarioSpec` field (``engine``,
      ``method``, ``streams``, ``router``, ...).

    The spec is inert data, like :class:`ScenarioSpec`: the same sweep
    runs batched (:func:`run_sweep`) or serially (one
    :func:`run_scenario` per cell), which is what the parity tests
    compare.
    """

    name: str
    base: ScenarioSpec
    axes: Dict[str, Sequence] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        n = 1
        for vals in self.axes.values():
            n *= len(vals)
        return n

    def cells(self) -> List[Tuple[Dict[str, object], ScenarioSpec]]:
        """Materialize every cell: ``(params, scenario)`` pairs in
        cross-product order."""
        names = list(self.axes)
        out: List[Tuple[Dict[str, object], ScenarioSpec]] = []
        for combo in itertools.product(*(self.axes[n] for n in names)):
            params = dict(zip(names, combo))
            spec = self.base
            outage_rate = 0.0
            for axis, value in params.items():
                if axis == "outage_rate":
                    outage_rate = float(value)
                else:
                    spec = _apply_axis(spec, axis, value)
            if outage_rate > 0.0:
                storm = _outage_storm_for(spec, outage_rate)
                outages = (spec.outages.merge(storm)
                           if spec.outages is not None else storm)
                spec = dataclasses.replace(spec, outages=outages)
            tag = ",".join(f"{k}={v}" for k, v in params.items())
            spec = dataclasses.replace(
                spec, name=f"{self.name}/{tag}" if tag else self.name)
            out.append((params, spec))
        return out


_SCENARIO_FIELDS = {f.name for f in dataclasses.fields(ScenarioSpec)}


def _apply_axis(spec: ScenarioSpec, axis: str, value) -> ScenarioSpec:
    if axis.startswith("workload."):
        field = axis[len("workload."):]
        if not isinstance(spec.workload, WorkloadSpec):
            raise ValueError(f"axis {axis!r} needs a WorkloadSpec workload")
        if field not in {f.name for f in dataclasses.fields(WorkloadSpec)}:
            raise ValueError(f"unknown workload axis {axis!r}")
        return dataclasses.replace(
            spec, workload=dataclasses.replace(spec.workload,
                                               **{field: value}))
    if axis.startswith("federation."):
        field = axis[len("federation."):]
        fed = spec.federation
        fed_fields = {f.name for f in dataclasses.fields(FederationSpec)}
        site_fields = {f.name for f in dataclasses.fields(SiteSpec)}
        if field in fed_fields and field != "sites":
            return dataclasses.replace(
                spec, federation=dataclasses.replace(fed, **{field: value}))
        m = re.fullmatch(r"tier(\d+)\.(\w+)", field)
        if m:
            # "federation.tier<k>.<field>" — a site knob applied only to
            # the cache-bearing sites at hierarchy depth k (1 = edge),
            # which is what an L1 × L2 split-sizing sweep crosses.
            depth, sub = int(m.group(1)), m.group(2)
            if sub not in site_fields or sub in ("name", "parent"):
                raise ValueError(f"unknown federation axis {axis!r}")
            tiers = fed.site_tiers()
            if depth not in set(tiers.values()):
                raise ValueError(
                    f"axis {axis!r}: federation has no tier-{depth} sites")
            sites = [dataclasses.replace(s, **{sub: value})
                     if tiers.get(s.name) == depth else s
                     for s in fed.sites]
            return dataclasses.replace(
                spec, federation=dataclasses.replace(fed, sites=sites))
        if field not in site_fields or field == "name":
            # "name" would rename every site identically — reject it
            # like any other unsweepable axis rather than no-op.
            raise ValueError(f"unknown federation axis {axis!r}")
        # Site-level knob: apply to every site the field is meaningful
        # for (cache knobs to cache-bearing sites, workers to
        # worker-bearing ones), leaving pure-storage sites intact.
        cache_knobs = field not in ("workers", "profile")
        sites = [dataclasses.replace(s, **{field: value})
                 if (s.has_cache if cache_knobs else s.workers > 0)
                 else s
                 for s in fed.sites]
        return dataclasses.replace(
            spec, federation=dataclasses.replace(fed, sites=sites))
    if axis in _SCENARIO_FIELDS and axis not in ("name", "federation",
                                                 "workload", "outages"):
        return dataclasses.replace(spec, **{axis: value})
    raise ValueError(f"unknown sweep axis {axis!r}")


def _workload_horizon(workload) -> float:
    if isinstance(workload, WorkloadSpec):
        if workload.kind in ("zipf", "abusive", "flash_crowd", "serve"):
            return workload.duration
        if workload.kind == "dataloader":
            shards_per_worker = -(-max(workload.n_objects, 1)
                                  // max(workload.workers_per_site, 1))
            return (workload.at + max(workload.waves, 1)
                    * shards_per_worker * workload.step_gap + 60.0)
        return workload.at + workload.jitter + 60.0
    times = [r.at if isinstance(r, FetchRequest) else r.time
             for r in workload]
    return (max(times) if times else 0.0) + 60.0


def _outage_storm_for(spec: ScenarioSpec, rate: float) -> OutageSchedule:
    caches = spec.federation.cache_names()
    k = min(len(caches), max(1, math.ceil(rate * len(caches))))
    horizon = _workload_horizon(spec.workload)
    return OutageSchedule.restart_storm(
        caches[:k], at=0.5 * horizon, downtime=0.25 * horizon,
        stagger=0.0, cold=True)


@dataclasses.dataclass
class SweepCell:
    """One executed sweep cell: its parameter point, how it ran, and the
    :meth:`~repro.core.simclient.ScenarioReport.summary` gauges (exactly
    what a serial :func:`run_scenario` of the same cell reports — the
    parity tests hold the two equal).  ``pricing`` carries the batched
    max-min gauges for cells priced by the vmapped waterfill."""

    params: Dict[str, object]
    name: str
    engine: str
    executor: str                     # "batched" | "serial"
    summary: Dict[str, object]
    pricing: Dict[str, float] = dataclasses.field(default_factory=dict)
    # ``fit=`` mode products (batched cells only; None otherwise).
    # These ride on the cell, *not* inside ``summary``, so the
    # batched-vs-serial parity comparisons stay byte-exact.
    reuse_histogram: Optional[Dict[str, Dict]] = None   # cache -> buckets
    models: Optional[Dict[str, object]] = None          # cache -> CacheModel


@dataclasses.dataclass
class SweepReport:
    """What :func:`run_sweep` produced: every cell plus execution
    telemetry (how many cells took the vectorized path, how many jitted
    waterfill calls priced the whole sweep)."""

    name: str
    axes: Dict[str, List]
    cells: List[SweepCell]
    wall_seconds: float = 0.0
    batched_cells: int = 0
    serial_cells: int = 0
    solver: Dict[str, object] = dataclasses.field(default_factory=dict)

    def cell(self, **params) -> SweepCell:
        for c in self.cells:
            if all(c.params.get(k) == v for k, v in params.items()):
                return c
        raise KeyError(f"no cell matches {params!r}")

    def marginal(self, axis: str, metric: str) -> List[Tuple[object, float]]:
        """Mean of ``metric`` per value of ``axis`` (cross-cell
        aggregate, in axis-value order)."""
        agg: Dict[object, List[float]] = {}
        for c in self.cells:
            agg.setdefault(c.params.get(axis), []).append(
                float(c.summary.get(metric, 0.0)))
        return [(v, sum(agg[v]) / len(agg[v]))
                for v in self.axes.get(axis, sorted(agg))]

    def fitted_models(self, **params) -> Dict[str, object]:
        """Per-cache fitted :class:`~repro.kernels.cache_model.
        CacheModel` objects from a ``fit=`` sweep — the cell matching
        ``params``, else the first cell that carries models (cells of
        one routing column share one model dict)."""
        if params:
            return self.cell(**params).models or {}
        for c in self.cells:
            if c.models:
                return c.models
        return {}

    def reuse_histograms(self, **params) -> Dict[str, Dict]:
        """Per-cache reuse-distance histograms (JSON-safe bucket dicts)
        from a ``fit=`` sweep, resolved like :meth:`fitted_models`."""
        if params:
            return self.cell(**params).reuse_histogram or {}
        for c in self.cells:
            if c.reuse_histogram:
                return c.reuse_histogram
        return {}

    def summary(self) -> Dict:
        return {
            "name": self.name,
            "cells": len(self.cells),
            "axes": {k: list(v) for k, v in self.axes.items()},
            "wall_seconds": self.wall_seconds,
            "batched_cells": self.batched_cells,
            "serial_cells": self.serial_cells,
            "fitted_cells": sum(1 for c in self.cells if c.models),
            "solver": dict(self.solver),
        }


def _sweep_batchable(spec: ScenarioSpec) -> bool:
    """Static eligibility for the vectorized analytic executor.

    Evicting caches are *in* the regime: LRU cells resolve through the
    stack-distance kernel, FIFO and size-aware-admission cells through
    the vectorized cache state machine (both in
    :mod:`repro.kernels.stack_distance`).  Only victim orders the
    kernels don't model (LFU frequency buckets, TTL expiry against the
    accounted clock) still fall back to a serial :func:`run_scenario`.
    """
    if spec.engine != "analytic":
        return False
    if spec.control is not None:
        # Control-plane cells carry cross-request queue/breaker state the
        # vectorized kernels don't model; they run serially (and the
        # sweep counts them in ``serial_cells``).
        return False
    if spec.method not in ("stash", "direct"):
        return False
    if spec.ranking not in (None, "static"):
        # probe ranking re-orders chains from observed latency — the
        # cross-request state the shared routing table can't carry
        return False
    if spec.outages is not None and any(
            getattr(ev, "kind", "cache") != "cache" for ev in spec.outages):
        # link degradation changes bandwidth mid-run; the batched
        # executor precomputes its timing constants once per column
        return False
    if not isinstance(spec.workload, WorkloadSpec):
        for r in spec.workload:
            if isinstance(r, FetchRequest) and (
                    r.method not in ("stash", "direct")
                    or r.offset or r.length >= 0 or r.avoid):
                # ranged / cache-avoiding requests move partial objects
                # the whole-object kernels don't model
                return False
    for s in spec.federation.sites:
        if s.has_cache and s.eviction_policy not in ("lru", "fifo"):
            return False
    if spec.federation.tier_depth() > 2:
        # the two-round executor derives exactly one parent stream per
        # fill target; deeper hierarchies replay serially
        return False
    return True


# The per-site knobs that select cache *policy* rather than routing:
# ranked chains, GeoIP order and ring ownership never read them, so
# cells differing only here share one pristine federation, one routing
# table and one set of per-cache request streams.
_POLICY_KNOBS = ("cache_capacity", "eviction_policy", "ttl_seconds",
                 "admission_max_fraction")
_SITE_KNOB_DEFAULTS = {f.name: f.default
                       for f in dataclasses.fields(SiteSpec)
                       if f.name in _POLICY_KNOBS}


def _routing_fedspec(fed: FederationSpec) -> FederationSpec:
    """``fed`` with every cache-bearing site's policy knobs canonicalized
    — the sharing key for federations, routing tables and streams."""
    sites = [dataclasses.replace(s, **_SITE_KNOB_DEFAULTS)
             if s.has_cache else s for s in fed.sites]
    return dataclasses.replace(fed, sites=sites)


def _cache_knobs(fed: FederationSpec) -> Dict[str, Tuple[float, str, float]]:
    """Per cache-server name: ``(capacity_bytes, policy, admission
    fraction)`` — the cell-specific half the shared federation lacks."""
    out: Dict[str, Tuple[float, str, float]] = {}
    for s in fed.sites:
        for name in s.cache_names():
            out[name] = (float(s.cache_capacity), s.eviction_policy,
                         float(s.admission_max_fraction))
    return out


class _SharedFederations:
    """Pristine federations shared across same-spec sweep cells.

    The vectorized executor never publishes objects or mutates cache
    storage, so every cell with an equal *routing-normalized*
    :class:`FederationSpec` (policy knobs canonicalized — see
    :func:`_routing_fedspec`) can route against one built federation —
    and share its liveness-independent ``(site, path) -> ranked cache
    names`` table, which is the expensive part of analytic routing."""

    def __init__(self) -> None:
        self._entries: List[Tuple[FederationSpec, Federation, Dict]] = []

    def get(self, spec: FederationSpec) -> Tuple[Federation, Dict]:
        for known, fed, routes in self._entries:
            if known == spec:
                return fed, routes
        fed = spec.build()
        state: Dict = {"routes": {}, "clients": {}, "cells": []}
        self._entries.append((spec, fed, state))
        return fed, state

    def __len__(self) -> int:
        return len(self._entries)


def _ranked_names(fed: Federation, state: Dict, site: str,
                  path: str) -> List[str]:
    key = (site, path)
    chain = state["routes"].get(key)
    if chain is None:
        client = state["clients"].get(site)
        if client is None:
            client = state["clients"][site] = fed.client(site, 0)
        chain = [c.name for c in client._ranked_caches(path=path)]
        state["routes"][key] = chain
    return chain


def _worker_node(fed: Federation, site: str, worker: int) -> str:
    """Ensure the worker node exists (mirrors ``Federation.client``
    without paying for a StashClient)."""
    name = f"{site}/worker{worker}"
    if name not in fed.topology.nodes:
        prof = fed.topology.profile(site)
        fed.topology.add_node(name, Coord(site, rack=0, host=worker),
                              prof.worker_nic)
    return name


class _CacheStream:
    """One cache server's chunk reference stream for one routing cell —
    everything a hit/miss kernel needs, all of it capacity- and
    policy-independent (eviction never feeds back into routing: a cache
    with nothing resident still *serves*, it just pulls first)."""

    __slots__ = ("req", "size", "prev", "reset", "seg", "eff_obj",
                 "miss_sec", "keys", "n_keys", "key_sizes",
                 "total_key_bytes", "eff_const", "variants",
                 "parent_ci", "fill_sec", "l2_sec", "l2_eff", "l2_seg",
                 "gpos", "pj", "is_fill")

    def __init__(self) -> None:
        self.req: List[int] = []       # request index per reference
        self.keys: List[int] = []      # stream-local (path, chunk) key id
        self.size: List[int] = []      # chunk bytes per reference
        self.prev: List[int] = []      # previous same-key ref (same
        #                                cold-restart segment), else -1
        self.reset: List[bool] = []    # cold restart before this ref
        self.seg: List[int] = []       # cold-restart segment per ref
        self.eff_obj: List[int] = []   # object size admission sees (the
        #                                chunk itself until the serving
        #                                cache has located the meta)
        self.miss_sec: List[float] = []  # redirector RPC + origin pull
        self.key_sizes: List[int] = []
        # tier-fill lane, per reference (all liveness-resolved, so they
        # are cell-policy-independent like everything else here):
        self.parent_ci: List[int] = []   # epoch-alive parent cache (-1:
        #                                  top tier / parent tier dead)
        self.fill_sec: List[float] = []  # parent -> this cache transfer
        self.l2_sec: List[float] = []    # parent's own origin-miss cost
        self.l2_eff: List[int] = []      # admission basis at the parent
        self.l2_seg: List[int] = []      # parent cold-restart segment
        self.gpos: List[int] = []        # global arrival position (the
        #                                  merge order for parent streams)
        self.pj: List[int] = []          # federation-global chunk id
        self.is_fill = None              # merged parent streams only
        # stack-distance variants, keyed by admitted-key signature: the
        # stream with one admission filter class applied (refused keys
        # dropped — they never enter the stack), with byte distances
        # and segment-end residency.  Shared by every cell whose
        # (fraction × capacity) threshold induces the same filter.
        self.variants: Dict[bytes, Dict[str, np.ndarray]] = {}

    def arrays(self) -> None:
        self.req = np.asarray(self.req, np.int64)
        self.keys = np.asarray(self.keys, np.int32)
        self.size = np.asarray(self.size, np.int64)
        self.prev = np.asarray(self.prev, np.int64)
        self.reset = np.asarray(self.reset, bool)
        self.seg = np.asarray(self.seg, np.int64)
        self.eff_obj = np.asarray(self.eff_obj, np.int64)
        self.miss_sec = np.asarray(self.miss_sec, np.float64)
        self.key_sizes = np.asarray(self.key_sizes, np.int64)
        self.parent_ci = np.asarray(self.parent_ci, np.int64)
        self.fill_sec = np.asarray(self.fill_sec, np.float64)
        self.l2_sec = np.asarray(self.l2_sec, np.float64)
        self.l2_eff = np.asarray(self.l2_eff, np.int64)
        self.l2_seg = np.asarray(self.l2_seg, np.int64)
        self.gpos = np.asarray(self.gpos, np.int64)
        self.pj = np.asarray(self.pj, np.int64)
        self.n_keys = len(self.key_sizes)
        # conservative residency bound: a capacity at or above the whole
        # distinct-key working set can never evict — those cells answer
        # hit/miss by compulsory-miss logic alone, no kernel involved
        self.total_key_bytes = int(self.key_sizes.sum())
        # is the admission-relevant object size constant per key?  (It
        # is, unless an outage made a non-head cache serve before the
        # meta was located.)  Constant → a size-aware filter refuses a
        # key always-or-never, which is what the filtered stack model
        # needs; varying → the slot state machine.
        if self.n_keys:
            lo = np.full(self.n_keys, np.iinfo(np.int64).max, np.int64)
            hi = np.zeros(self.n_keys, np.int64)
            np.minimum.at(lo, self.keys, self.eff_obj)
            np.maximum.at(hi, self.keys, self.eff_obj)
            self.eff_const = bool((lo[self.keys] == hi[self.keys]).all())
        else:
            self.eff_const = True


class _CellRouting:
    """The cell-policy-independent product of the vectorized executor:
    routing, liveness epochs, timing constants and per-cache reference
    streams (with stack distances precomputed).  Shared by every sweep
    cell that differs only in cache capacity / eviction policy /
    admission — the axes the hit/miss kernels resolve per cell."""


def _cell_routing(spec: ScenarioSpec, fed: Federation, state: Dict,
                  telemetry: Dict) -> Optional[_CellRouting]:
    """Route one analytic cell without touching cache policy: numpy
    epoch accounting over liveness-independent ranked chains, exactly as
    a serial :func:`run_scenario` would resolve it.

    Returns ``None`` when the cell leaves the vectorizable regime
    (unresolvable namespace — the serial path raises ``KeyError``),
    in which case the caller falls back to the serial executor.
    """
    reqs = spec.requests(fed)
    n = len(reqs)
    default_site = next((s.name for s in fed.sites if s.workers > 0),
                        fed.sites[0].name)

    # ---- request arrays (original order) -----------------------------------
    path_ids: Dict[str, int] = {}
    sizes: List[int] = []
    pid = np.empty(n, np.int64)
    at = np.empty(n, np.float64)
    sites: List[str] = []
    workers = np.empty(n, np.int64)
    methods: List[str] = []
    streams = np.empty(n, np.int64)
    for i, r in enumerate(reqs):
        p = path_ids.setdefault(r.path, len(path_ids))
        if p == len(sizes):
            sizes.append(0)
        sizes[p] = max(sizes[p], r.size)
        pid[i] = p
        at[i] = r.at
        sites.append(r.site or default_site)
        workers[i] = r.worker
        methods.append(r.method)
        streams[i] = r.streams or spec.streams
    P = len(path_ids)
    paths = list(path_ids)
    size = np.asarray(sizes, np.int64)
    found = size > 0

    owners: List[Optional[object]] = []
    for p in range(P):
        owner = fed.resolve_origin(paths[p])
        if owner is None and found[p]:
            return None  # serial run_scenario raises KeyError here
        owners.append(owner)
    # chunk count per path, from the owning origin's chunking (what a
    # serial run_scenario's publish would have produced)
    nchunks = np.asarray(
        [-(-size[p] // owners[p].chunk_size) if found[p] else 1
         for p in range(P)], np.int64)

    site_ids: Dict[str, int] = {}
    sid = np.asarray([site_ids.setdefault(s, len(site_ids)) for s in sites])
    site_names = list(site_ids)
    method_is_direct = np.asarray([m == "direct" for m in methods])

    # ---- routing (liveness-independent chains, shared across cells) --------
    cache_ids = {name: ci for ci, name in enumerate(fed.caches)}
    chains: Dict[Tuple[int, int], List[int]] = {}
    for si, pi in {(int(s), int(p))
                   for s, p, d in zip(sid, pid, method_is_direct) if not d}:
        names = _ranked_names(fed, state, site_names[si], paths[pi])
        chains[(si, pi)] = [cache_ids[nm] for nm in names]
    group_of = {c.name: g for g in fed.groups.values() for c in g.members}
    # primary cache (nearest group's ring owner) per chain — the one
    # whose liveness decides a counted group failover.
    primary: Dict[Tuple[int, int], int] = {}
    cache_names = list(fed.caches)
    for key, chain in chains.items():
        prim = -1
        for ci in chain:
            if cache_names[ci] in group_of:
                prim = ci
                break
        primary[key] = prim if prim >= 0 else (chain[0] if chain else -1)

    # ---- network constants (per site / cache / owner) ----------------------
    net, topo = fed.net, fed.topology
    wnode: Dict[Tuple[int, int], str] = {}
    for si, w in {(int(s), int(w)) for s, w in zip(sid, workers)}:
        wnode[(si, w)] = _worker_node(fed, site_names[si], w)

    # ---- chronological epochs between outage events ------------------------
    order = np.argsort(at, kind="stable")
    op = np.empty(n, np.int64)               # arrival rank per request
    op[order] = np.arange(n)
    events = list(spec.outages) if spec.outages is not None else []
    for ev in events:
        if ev.cache not in group_of and ev.cache not in fed.caches:
            raise KeyError(ev.cache)  # same failure as the serial plane
    alive = np.ones(len(cache_ids), bool)
    was_counted = {"outages": 0, "recoveries": 0}
    # cold-restart positions per cache, as arrival ranks: requests with
    # op >= the recorded rank see that cache's disk wiped
    resets: Dict[int, List[int]] = {}
    processed = 0

    chosen = np.full(n, -1, np.int64)        # serving cache (-1: none)
    parent_of = np.full(n, -1, np.int64)     # epoch-alive fill parent
    dead_before = np.zeros(n, np.int64)
    primary_dead = np.zeros(n, bool)
    fallback = np.zeros(n, bool)
    ok = np.ones(n, bool)

    caches = list(fed.caches.values())
    pchains: Dict[Tuple[int, int], List[int]] = {}

    def _parent_chain(serve_ci: int, pi: int) -> Sequence[int]:
        """The serving cache's parent-tier fill chain for one path —
        consistent-hash order, liveness-independent (aliveness is the
        per-epoch filter, exactly as ``CacheServer.parent_caches``)."""
        pg = caches[serve_ci].parent_group
        if pg is None:
            return ()
        key = (id(pg), pi)
        chain = pchains.get(key)
        if chain is None:
            chain = pchains[key] = [cache_ids[c.name]
                                    for c in pg.fill_chain(paths[pi])]
        return chain

    def apply_event(ev) -> None:
        ci = cache_ids[ev.cache]
        if ev.action == "down":
            if alive[ci]:
                alive[ci] = False
                if ev.cache in group_of:
                    was_counted["outages"] += 1
        else:
            if not alive[ci]:
                alive[ci] = True
                if ev.cache in group_of:
                    was_counted["recoveries"] += 1
                if ev.cold:
                    resets.setdefault(ci, []).append(processed)

    def run_epoch(idx: np.ndarray) -> None:
        """Vectorized routing for one liveness epoch (``idx`` are
        request indices in arrival order).  Hit/miss is *not* resolved
        here — that is the kernels' job, per cell — only which cache
        serves whom."""
        if idx.size == 0:
            return
        allstash = idx[~method_is_direct[idx]]
        stash = allstash[found[pid[allstash]]]
        # liveness-resolved serving cache per (site, path) this epoch
        for key, chain in chains.items():
            si, pi = key
            sel = allstash[(sid[allstash] == si) & (pid[allstash] == pi)]
            if sel.size == 0:
                continue
            # every stash request — found or not — walks the ranked
            # chain, so a dead ring owner counts its group failovers
            primary_dead[sel] = (primary[key] >= 0
                                 and not alive[primary[key]])
            fsel = sel[found[pid[sel]]]
            if fsel.size == 0:
                continue
            serve, dead = -1, 0
            for ci in chain:
                if alive[ci]:
                    serve = ci
                    break
                dead += 1
            chosen[fsel] = serve
            dead_before[fsel] = dead
            if serve >= 0:
                par = -1
                for qi in _parent_chain(serve, pi):
                    if alive[qi] and qi != serve:
                        par = qi
                        break
                parent_of[fsel] = par
        fallback[stash] = chosen[stash] < 0
        # not-found stash requests fail visibly, as on the serial plane
        nf = idx[~method_is_direct[idx] & ~found[pid[idx]]]
        ok[nf] = False
        direct = idx[method_is_direct[idx]]
        ok[direct] = found[pid[direct]]

    ei = 0
    pending: List[int] = []
    for i in order:
        while ei < len(events) and events[ei].time <= at[i]:
            run_epoch(np.asarray(pending, np.int64))
            processed += len(pending)
            pending = []
            apply_event(events[ei])
            ei += 1
        pending.append(int(i))
    run_epoch(np.asarray(pending, np.int64))
    processed += len(pending)
    while ei < len(events):
        apply_event(events[ei])
        ei += 1
    served_mask = chosen >= 0

    # ---- when does each cache learn an object's size? ----------------------
    # Admission sees the whole object only once the serving cache has
    # the meta cached — and only the liveness-independent chain *head*
    # is ever asked to locate it (``StashClient._meta`` returns at the
    # first non-None ``locate_meta``).  So a non-head cache serving
    # under an outage judges admission by the chunk payload until some
    # request whose chain it heads has touched the path.
    meta_rank: Dict[Tuple[int, int], int] = {}
    for i in range(n):
        if method_is_direct[i] or not found[pid[i]]:
            continue
        chain = chains.get((int(sid[i]), int(pid[i])))
        if chain:
            key = (chain[0], int(pid[i]))
            r = meta_rank.get(key)
            if r is None or op[i] < r:
                meta_rank[key] = int(op[i])

    # ---- timing constants + per-cache chunk reference streams --------------
    lookup = fed.geoip.lookup_latency
    bw_serve: Dict[Tuple[int, int], float] = {}
    rtt_serve: Dict[Tuple[int, int], float] = {}
    rpc_red: Dict[int, float] = {}
    bw_pull: Dict[Tuple[int, int], float] = {}
    rtt_pull: Dict[Tuple[int, int], float] = {}
    bw_fill: Dict[Tuple[int, int], float] = {}
    rtt_fill: Dict[Tuple[int, int], float] = {}
    red_node = fed.redirectors.members[0].node.name
    nreq = nchunks[pid]
    serve_base = np.zeros(n, np.float64)   # hit-path seconds per request
    streams_by_cache: Dict[int, _CacheStream] = {}
    key_ids: Dict[int, Dict[Tuple[int, int], int]] = {}
    last_ref: Dict[int, Dict[int, Tuple[int, int]]] = {}
    last_seg: Dict[int, int] = {}
    Cmax = int(nchunks.max()) if P else 1
    gpos = 0

    def _chunk_len(p: int, j: int) -> int:
        cs = owners[p].chunk_size
        return int(min(cs, size[p] - j * cs)) if size[p] else 0

    for i in order:
        if chosen[i] < 0:
            continue
        i, ci, p = int(i), int(chosen[i]), int(pid[i])
        si = int(sid[i])
        wn = wnode[(si, int(workers[i]))]
        cnode = caches[ci].node.name
        k = (ci, si)
        if k not in bw_serve:
            bw_serve[k] = net.effective_bandwidth(cnode, wn, streams=8)
            rtt_serve[k] = topo.rtt(cnode, wn)
        pk = (ci, p)
        if pk not in bw_pull:
            onode = owners[p].node.name
            bw_pull[pk] = net.effective_bandwidth(onode, cnode, streams=8)
            rtt_pull[pk] = topo.rtt(onode, cnode)
            if ci not in rpc_red:
                rpc_red[ci] = net.rpc_time(cnode, red_node)
        q = int(parent_of[i])
        if q >= 0:
            # miss fills cache-to-cache: parent -> this cache transfer,
            # plus the parent's own redirector RPC + origin pull if the
            # parent misses too (resolved by the round-2 kernels)
            pnode = caches[q].node.name
            fk = (q, ci)
            if fk not in bw_fill:
                bw_fill[fk] = net.effective_bandwidth(pnode, cnode,
                                                      streams=8)
                rtt_fill[fk] = topo.rtt(pnode, cnode)
            qk = (q, p)
            if qk not in bw_pull:
                onode = owners[p].node.name
                bw_pull[qk] = net.effective_bandwidth(onode, pnode,
                                                      streams=8)
                rtt_pull[qk] = topo.rtt(onode, pnode)
            if q not in rpc_red:
                rpc_red[q] = net.rpc_time(pnode, red_node)
            l2_base = rpc_red[q] + rtt_pull[qk]
            qcuts = resets.get(q, ())
            qseg = sum(1 for c in qcuts if c <= op[i])
        stream = streams_by_cache.get(ci)
        if stream is None:
            stream = streams_by_cache[ci] = _CacheStream()
            key_ids[ci] = {}
            last_ref[ci] = {}
            last_seg[ci] = 0
        cuts = resets.get(ci, ())
        seg = sum(1 for c in cuts if c <= op[i])
        fresh_seg = seg != last_seg[ci] and len(stream.req) > 0
        last_seg[ci] = seg
        known = meta_rank.get((ci, p), n + 1) <= op[i]
        # the *parent's* admission basis: the child forwards its located
        # object size upstream; failing that the parent falls back to
        # its own meta knowledge, then the chunk payload
        l2_known = known or (q >= 0
                             and meta_rank.get((q, p), n + 1) <= op[i])
        secs = lookup + nreq[i] * rtt_serve[k]
        miss_base = rpc_red[ci] + rtt_pull[pk]
        for j in range(int(nchunks[p])):
            csize = _chunk_len(p, j)
            kid = key_ids[ci].setdefault((p, j), len(key_ids[ci]))
            if kid == len(stream.key_sizes):
                stream.key_sizes.append(csize)
            prev_entry = last_ref[ci].get(kid)
            prev = (prev_entry[0] if prev_entry is not None
                    and prev_entry[1] == seg else -1)
            last_ref[ci][kid] = (len(stream.req), seg)
            basis = int(size[p]) if known else csize
            cap = caches[ci].serve_rate_cap(basis)
            secs += csize / (min(bw_serve[k], cap) if cap else bw_serve[k])
            stream.req.append(i)
            stream.keys.append(kid)
            stream.size.append(csize)
            stream.prev.append(prev)
            stream.reset.append(fresh_seg and j == 0)
            stream.seg.append(seg)
            stream.eff_obj.append(int(size[p]) if known else csize)
            stream.miss_sec.append(miss_base + csize / bw_pull[pk])
            stream.parent_ci.append(q)
            stream.gpos.append(gpos)
            stream.pj.append(p * Cmax + j)
            if q >= 0:
                stream.fill_sec.append(rtt_fill[fk] + csize / bw_fill[fk])
                stream.l2_sec.append(l2_base + csize / bw_pull[qk])
                stream.l2_eff.append(int(size[p]) if l2_known else csize)
                stream.l2_seg.append(qseg)
            else:
                stream.fill_sec.append(0.0)
                stream.l2_sec.append(0.0)
                stream.l2_eff.append(csize)
                stream.l2_seg.append(0)
            gpos += 1
        serve_base[i] = secs

    direct_like = ok & (fallback | method_is_direct)
    direct_sec = np.zeros(n, np.float64)
    for i in np.nonzero(direct_like)[0]:
        onode = owners[pid[i]].node.name
        wn = wnode[(int(sid[i]), int(workers[i]))]
        direct_sec[i] = net.transfer_time(onode, wn, int(size[pid[i]]),
                                          streams=int(streams[i]))

    for stream in streams_by_cache.values():
        stream.arrays()
    # The distance/replay scans are O(N) per reference (O(N²) per
    # stream); surface the longest stream so a sweep that drifts into
    # that regime is diagnosable from report.solver.
    if streams_by_cache:
        telemetry["max_stream_refs"] = max(
            telemetry.get("max_stream_refs", 0),
            max(len(s.req) for s in streams_by_cache.values()))

    # ---- cell-independent counters and flow constants ----------------------
    cache_failovers = int((nreq[served_mask] * dead_before[served_mask])
                          .sum())
    ranked_len = np.asarray([len(chains.get((int(s), int(p)), []))
                             for s, p in zip(sid, pid)])
    cache_failovers += int(2 * ranked_len[fallback].sum())
    # ranked-cache calls per request: n+2 (served), 6 (fallback: two
    # method attempts of meta+monitor+chunk0), 2 (not found: meta per
    # method) — each counting one group failover iff the nearest ring
    # owner is dead.
    stash_mask = ~method_is_direct
    calls = np.zeros(n, np.int64)
    calls[served_mask] = nreq[served_mask] + 2
    calls[fallback] = 6
    calls[stash_mask & ~ok] = 2

    serve_flow: Dict[int, Tuple[List, float]] = {}
    pull_flow: Dict[Tuple[int, int], Tuple[List, float]] = {}
    for i in range(n):
        if not ok[i]:
            continue
        p = int(pid[i])
        wn = wnode[(int(sid[i]), int(workers[i]))]
        if method_is_direct[i] or fallback[i]:
            src = owners[p].node.name
            links = topo.path(src, wn)
            cap_f = max(1, int(streams[i])) * net.per_stream_cap(
                topo.rtt(src, wn))
        else:
            ci = int(chosen[i])
            cnode = caches[ci].node.name
            q = int(parent_of[i])
            if q >= 0:
                # tiered miss path: child pulls from its parent, the
                # parent (on its own miss) pulls from the origin
                pnode = caches[q].node.name
                if (ci, p) not in pull_flow:
                    pull_flow[(ci, p)] = (
                        topo.path(pnode, cnode),
                        4 * net.per_stream_cap(topo.rtt(pnode, cnode)))
                if (q, p) not in pull_flow:
                    onode = owners[p].node.name
                    pull_flow[(q, p)] = (
                        topo.path(onode, pnode),
                        4 * net.per_stream_cap(topo.rtt(onode, pnode)))
            elif (ci, p) not in pull_flow:
                onode = owners[p].node.name
                pull_flow[(ci, p)] = (
                    topo.path(onode, cnode),
                    4 * net.per_stream_cap(topo.rtt(onode, cnode)))
            links = topo.path(cnode, wn)
            cap_f = max(1, spec.streams) * net.per_stream_cap(
                topo.rtt(cnode, wn))
            rc = caches[ci].serve_rate_cap(int(size[p]))
            if rc:
                cap_f = min(cap_f, rc)
        serve_flow[i] = (links, cap_f)

    fill_targets: Set[int] = set()
    for s in streams_by_cache.values():
        fill_targets.update(int(x) for x in np.unique(s.parent_ci)
                            if x >= 0)
    for q in fill_targets:
        sq = streams_by_cache.get(q)
        if sq is not None and (sq.parent_ci >= 0).any():
            # a fill target that itself fills upstream needs a third
            # kernel round; replay such cells serially
            return None

    routing = _CellRouting()
    routing.n = n
    routing.paths = paths
    routing.size = size
    routing.pid = pid
    routing.at = at
    routing.nchunks = nchunks
    routing.nreq = nreq
    routing.methods = methods
    routing.method_is_direct = method_is_direct
    routing.owner_names = [o.name if o is not None else "" for o in owners]
    routing.cache_names = cache_names
    routing.chosen = chosen
    routing.fallback = fallback
    routing.ok = ok
    routing.served_mask = served_mask
    routing.serve_base = serve_base
    routing.direct_sec = direct_sec
    routing.streams = streams_by_cache
    routing.fill_targets = fill_targets
    routing.cache_tier = [c.tier for c in caches]
    routing.all_tiers = sorted({c.tier for c in caches})
    routing.Cmax = Cmax
    routing.l2_cache = {}
    routing.counters = {
        "cache_failovers": cache_failovers,
        "group_failovers": int(calls[primary_dead].sum()),
        "origin_fallbacks": int(fallback.sum()),
        "outages": was_counted["outages"],
        "recoveries": was_counted["recoveries"],
    }
    routing.serve_flow = serve_flow
    routing.pull_flow = pull_flow
    # byte counters that never depend on cache policy
    sz_int = size[pid]
    moved = ok & (served_mask | fallback | method_is_direct)
    routing.bytes_moved = int(sz_int[moved].sum())
    routing.direct_egress = int(
        sz_int[ok & (fallback | method_is_direct)].sum())
    return routing


def _resolve_distances(wanted: Sequence[Tuple[_CacheStream, bytes,
                                              np.ndarray]],
                       telemetry: Dict) -> None:
    """Build every stack-distance variant the sweep's cells asked for —
    one bucketed kernel call for the whole sweep, which is the "one
    pass prices every capacity in the column" contract.

    A variant is the stream restricted to one admission filter class
    (``mask`` marks admitted keys; refused keys never perturb the LRU
    stack, so dropping their references is exact)."""
    from repro.kernels.stack_distance import stack_distances_batch
    pending: List[Tuple[_CacheStream, bytes, np.ndarray]] = []
    seen_sigs: Set[Tuple[int, bytes]] = set()
    for stream, sig, mask in wanted:
        if sig in stream.variants or (id(stream), sig) in seen_sigs:
            continue
        seen_sigs.add((id(stream), sig))
        pending.append((stream, sig, mask))
    if not pending:
        return
    problems = []
    selections = []
    for stream, sig, mask in pending:
        sel = np.nonzero(mask[stream.keys])[0]
        fkeys, fseg = stream.keys[sel], stream.seg[sel]
        prev: List[int] = []
        last: Dict[int, Tuple[int, int]] = {}
        for fi, (k, sg) in enumerate(zip(fkeys, fseg)):
            entry = last.get(int(k))
            prev.append(entry[0] if entry is not None
                        and entry[1] == sg else -1)
            last[int(k)] = (fi, int(sg))
        selections.append((sel, fkeys, fseg))
        problems.append((prev, stream.size[sel].astype(np.float64)))
    kstats: Dict = {}
    dists = stack_distances_batch(problems, stats=kstats)
    telemetry["stack_calls"] = (telemetry.get("stack_calls", 0)
                                + kstats["solve_calls"])
    telemetry["stack_variants"] = (telemetry.get("stack_variants", 0)
                                   + len(pending))
    for (stream, sig, _), (sel, fkeys, fseg), dist in zip(
            pending, selections, dists):
        fsizes = stream.size[sel]
        # distance from each key's final per-segment reference to its
        # segment's end: resident at the wipe (or run end) iff
        # end_dist + size <= capacity, so at capacity C the eviction
        # count is (admitted misses) − (keys resident at segment ends)
        end_dist, end_size = [], []
        tot: Dict[int, int] = {}
        seen: Set[Tuple[int, int]] = set()
        for r in range(len(sel) - 1, -1, -1):
            sk = (int(fseg[r]), int(fkeys[r]))
            if sk in seen:
                continue
            seen.add(sk)
            end_dist.append(tot.get(sk[0], 0))
            end_size.append(int(fsizes[r]))
            tot[sk[0]] = tot.get(sk[0], 0) + int(fsizes[r])
        stream.variants[sig] = {
            "sel": sel, "dist": dist, "sizes": fsizes,
            "end_dist": np.asarray(end_dist, np.float64),
            "end_size": np.asarray(end_size, np.int64),
        }


def _merged_parent_stream(routing: _CellRouting, q: int,
                          hits_by_child: Dict[int, np.ndarray]
                          ) -> Optional[_CacheStream]:
    """The round-2 reference stream of one fill-target (parent-tier)
    cache: its directly-routed references merged, in global arrival
    order, with the cache-to-cache fills induced by every child miss
    under the cell's L1 policy points.  Shared by every cell whose
    children resolve identically (the L1 knob signature), so an
    L1 × L2 split-sizing sweep builds each parent stream once per L1
    point and answers every L2 capacity from it."""
    r = routing
    parts: List[Tuple[np.ndarray, ...]] = []
    sq = r.streams.get(q)
    if sq is not None and len(sq.req):
        m = len(sq.req)
        parts.append((sq.gpos, sq.req, sq.pj, sq.size, sq.seg,
                      sq.eff_obj, sq.miss_sec, np.zeros(m, bool)))
    for ci, s in r.streams.items():
        if ci == q or not len(s.req):
            continue
        mask = s.parent_ci == q
        if not mask.any():
            continue
        sel = mask & ~hits_by_child[ci]
        if not sel.any():
            continue
        parts.append((s.gpos[sel], s.req[sel], s.pj[sel], s.size[sel],
                      s.l2_seg[sel], s.l2_eff[sel], s.l2_sec[sel],
                      np.ones(int(sel.sum()), bool)))
    if not parts:
        return None
    gp = np.concatenate([p[0] for p in parts])
    o = np.argsort(gp, kind="stable")
    m = _CacheStream()
    m.gpos = gp[o]
    m.req = np.concatenate([p[1] for p in parts])[o]
    m.pj = np.concatenate([p[2] for p in parts])[o]
    m.size = np.concatenate([p[3] for p in parts])[o]
    m.seg = np.concatenate([p[4] for p in parts])[o]
    m.eff_obj = np.concatenate([p[5] for p in parts])[o]
    m.miss_sec = np.concatenate([p[6] for p in parts])[o]
    m.is_fill = np.concatenate([p[7] for p in parts])[o]
    uniq, inv = np.unique(m.pj, return_inverse=True)
    m.keys = inv.astype(np.int32)
    key_sizes = np.zeros(len(uniq), np.int64)
    key_sizes[inv] = m.size
    m.key_sizes = key_sizes
    nref = len(m.req)
    m.reset = np.zeros(nref, bool)
    if nref > 1:
        m.reset[1:] = m.seg[1:] != m.seg[:-1]
    # previous same-key reference within the same cold-restart segment
    idx = np.arange(nref)
    by_key = np.lexsort((idx, m.seg, m.keys))
    sk, ss = m.keys[by_key], m.seg[by_key]
    m.prev = np.full(nref, -1, np.int64)
    if nref > 1:
        same = (sk[1:] == sk[:-1]) & (ss[1:] == ss[:-1])
        m.prev[by_key[1:]] = np.where(same, by_key[:-1], -1)
    m.parent_ci = np.full(nref, -1, np.int64)
    m.fill_sec = np.zeros(nref, np.float64)
    m.l2_sec = np.zeros(nref, np.float64)
    m.l2_eff = np.zeros(nref, np.int64)
    m.l2_seg = np.zeros(nref, np.int64)
    m.arrays()
    return m


class _CellPlan:
    """One batched cell, waiting on its hit/miss resolution.

    Construction decides, per cache, how the cell's policy point is
    evaluated against the shared :class:`_CellRouting` streams:

    * capacity at or above the stream's whole distinct-key working set
      with nothing refused → nothing can ever evict: hit iff not a
      compulsory miss, no kernel involved;
    * ``lru`` whose admission filter is constant per key (always, bar
      outage meta-location races) → stack distances over the filtered
      stream (refused keys never enter the stack), computed lazily in
      one batched kernel call for the whole sweep and shared by every
      cell with the same filter class: ``hit iff distance + size <=
      capacity``; evictions = admitted misses − keys resident at each
      segment end;
    * ``fifo`` → the O(N log N) byte-frontier replay
      (:func:`~repro.kernels.stack_distance.fifo_sim_batch`), which
      takes per-reference admit bits directly;
    * the residue (LRU whose admission basis flips mid-stream) → the
      exact slot state machine
      (:func:`~repro.kernels.stack_distance.cache_sim_batch`).

    ``finalize`` then folds per-reference hits into the cell's
    :class:`~repro.core.simclient.ScenarioReport` and pricing flow set.
    """

    def __init__(self, cspec: ScenarioSpec, routing: _CellRouting) -> None:
        self.spec = cspec
        self.routing = routing
        self.offset = 0                  # slot in the global sim problem list
        self.fifo_offset = 0             # slot in the global fifo list
        self.problems: List[Tuple] = []      # pending cache_sim problems
        self.fifo_problems: List[Tuple] = []  # pending fifo_sim problems
        self.dist_wanted: List[Tuple[_CacheStream, bytes, np.ndarray]] = []
        self._order: List[Tuple[int, str, object]] = []  # (cache, mode, arg)
        # round-2 state: parent-tier caches resolve against merged
        # direct+fill streams that depend on the children's hits, so
        # their problems are classified in prepare_l2, after round 1
        self.l2_offset = 0
        self.l2_fifo_offset = 0
        self.l2_problems: List[Tuple] = []
        self.l2_fifo_problems: List[Tuple] = []
        self.l2_dist_wanted: List[Tuple[_CacheStream, bytes,
                                        np.ndarray]] = []
        self._l2_order: List[Tuple[int, _CacheStream, str, object]] = []
        self._l1_res: Dict[int, Tuple] = {}
        self.knobs = knobs = _cache_knobs(cspec.federation)
        for ci in sorted(routing.streams):
            stream = routing.streams[ci]
            if not len(stream.req) or ci in routing.fill_targets:
                continue
            cap, policy, frac = knobs[routing.cache_names[ci]]
            mode, arg = self._classify(stream, cap, policy, frac,
                                       self.problems, self.fifo_problems,
                                       self.dist_wanted)
            self._order.append((ci, mode, arg))

    @staticmethod
    def _classify(stream: _CacheStream, cap: float, policy: str,
                  frac: float, problems: List, fifo_problems: List,
                  dist_wanted: List) -> Tuple[str, object]:
        refused = stream.size > cap
        if frac < 1.0:
            refused = refused | (stream.eff_obj > frac * cap)
        if not refused.any() and cap >= stream.total_key_bytes:
            return "fits", None
        if policy == "fifo":
            fifo_problems.append(
                (stream.keys, stream.size.astype(np.float64),
                 ~refused, stream.reset, stream.n_keys, float(cap)))
            return "fifo", len(fifo_problems) - 1
        if stream.eff_const:
            # the filter refuses a key always or never → exact as a
            # filtered stack; cells sharing the filter class share
            # the variant
            admitted = np.ones(stream.n_keys, bool)
            admitted[stream.keys[refused]] = False
            sig = admitted.tobytes()
            dist_wanted.append((stream, sig, admitted))
            return "dist", sig
        problems.append(
            (stream.keys, ~refused, stream.reset,
             stream.key_sizes.astype(np.float64), float(cap), False))
        return "sim", len(problems) - 1

    def _resolve(self, stream: _CacheStream, cap: float, frac: float,
                 mode: str, arg: object, sim_results: Sequence,
                 fifo_results: Sequence, sim_base: int,
                 fifo_base: int) -> Tuple:
        """(hits, evictions, bytes_evicted, admission_rejects) for one
        stream at one policy point, from the batched kernel answers."""
        policy_refused = (stream.eff_obj > frac * cap if frac < 1.0
                          else None)
        if mode == "fits":
            hits = stream.prev >= 0
            ev = evb = rejects = 0
        elif mode == "dist":
            v = stream.variants[arg]
            fhits = v["dist"] + v["sizes"] <= cap
            hits = np.zeros(len(stream.req), bool)
            hits[v["sel"][fhits]] = True
            resident = v["end_dist"] + v["end_size"] <= cap
            ev = int((~fhits).sum() - resident.sum())
            evb = int(v["sizes"][~fhits].sum()
                      - v["end_size"][resident].sum())
            # a constantly-refused key is never resident: every one of
            # its references re-asks admission
            rejects = (int(policy_refused.sum())
                       if policy_refused is not None else 0)
        else:
            results = fifo_results if mode == "fifo" else sim_results
            base = fifo_base if mode == "fifo" else sim_base
            hits, ev, evb = results[base + arg]
            rejects = (int((~hits & policy_refused).sum())
                       if policy_refused is not None else 0)
        return hits, ev, evb, rejects

    def _resolve_l1(self, sim_results: Sequence,
                    fifo_results: Sequence) -> None:
        if self._l1_res:
            return
        r = self.routing
        for ci, mode, arg in self._order:
            cap, _policy, frac = self.knobs[r.cache_names[ci]]
            self._l1_res[ci] = self._resolve(
                r.streams[ci], cap, frac, mode, arg, sim_results,
                fifo_results, self.offset, self.fifo_offset)

    def prepare_l2(self, sim_results: Sequence,
                   fifo_results: Sequence) -> None:
        """Resolve the children, derive (or reuse) each fill target's
        merged stream, and classify its round-2 problem."""
        r = self.routing
        if not r.fill_targets:
            return
        self._resolve_l1(sim_results, fifo_results)
        hits_by_child = {ci: res[0] for ci, res in self._l1_res.items()}
        for q in sorted(r.fill_targets):
            children = tuple(
                (ci, self.knobs[r.cache_names[ci]])
                for ci in sorted(r.streams)
                if ci != q and len(r.streams[ci].req)
                and (r.streams[ci].parent_ci == q).any())
            lkey = (q, children)
            if lkey not in r.l2_cache:
                r.l2_cache[lkey] = _merged_parent_stream(r, q,
                                                         hits_by_child)
            stream = r.l2_cache[lkey]
            if stream is None:
                continue
            capq, policyq, fracq = self.knobs[r.cache_names[q]]
            mode, arg = self._classify(stream, capq, policyq, fracq,
                                       self.l2_problems,
                                       self.l2_fifo_problems,
                                       self.l2_dist_wanted)
            self._l2_order.append((q, stream, mode, arg))

    def finalize(self, sim_results: List, fifo_results: List,
                 l2_sim_results: Sequence = (),
                 l2_fifo_results: Sequence = ()
                 ) -> Tuple[ScenarioReport, Tuple]:
        r = self.routing
        knobs = self.knobs
        n = r.n
        self._resolve_l1(sim_results, fifo_results)
        hit_chunks = np.zeros(n, np.int64)
        miss_chunks = np.zeros(n, np.int64)
        miss_secs = np.zeros(n, np.float64)
        egress = r.direct_egress
        evictions = bytes_evicted = admission_rejects = 0
        total_hits = total_misses = parent_fill = 0
        tier_hits = {t: 0 for t in r.all_tiers}
        tier_misses = {t: 0 for t in r.all_tiers}
        tier_fill = {t: 0 for t in r.all_tiers}
        req_pulled = np.zeros(n, bool)       # request had >= 1 miss
        l2_pulled: Set[Tuple[int, int]] = set()
        for ci, mode, arg in self._order:
            stream = r.streams[ci]
            hits, ev, evb, rejects = self._l1_res[ci]
            evictions += ev
            bytes_evicted += evb
            admission_rejects += rejects
            miss = ~hits
            np.add.at(hit_chunks, stream.req[hits], 1)
            np.add.at(miss_chunks, stream.req[miss], 1)
            # a miss with a live parent fills cache-to-cache (no
            # redirector RPC at the child); otherwise it pulls straight
            # from the origin, which is the only path that counts egress
            tiered = stream.parent_ci >= 0
            cost = np.where(tiered, stream.fill_sec, stream.miss_sec)
            np.add.at(miss_secs, stream.req[miss], cost[miss])
            egress += int(stream.size[miss & ~tiered].sum())
            parent_fill += int(stream.size[miss & tiered].sum())
            t = r.cache_tier[ci]
            nh, nm = int(hits.sum()), int(miss.sum())
            tier_hits[t] += nh
            tier_misses[t] += nm
            tier_fill[t] += int(stream.size[miss].sum())
            total_hits += nh
            total_misses += nm
            req_pulled[stream.req[miss]] = True
        for q, stream, mode, arg in self._l2_order:
            capq, _policyq, fracq = knobs[r.cache_names[q]]
            hits, ev, evb, rejects = self._resolve(
                stream, capq, fracq, mode, arg, l2_sim_results,
                l2_fifo_results, self.l2_offset, self.l2_fifo_offset)
            evictions += ev
            bytes_evicted += evb
            admission_rejects += rejects
            miss = ~hits
            # only directly-routed references touch request-level
            # counters; fill references surface as the parent's own
            # hit/miss tallies plus upstream seconds on the child's
            # request when the parent misses through to the origin
            direct = ~stream.is_fill
            np.add.at(hit_chunks, stream.req[hits & direct], 1)
            np.add.at(miss_chunks, stream.req[miss & direct], 1)
            np.add.at(miss_secs, stream.req[miss], stream.miss_sec[miss])
            egress += int(stream.size[miss].sum())
            t = r.cache_tier[q]
            nh, nm = int(hits.sum()), int(miss.sum())
            tier_hits[t] += nh
            tier_misses[t] += nm
            tier_fill[t] += int(stream.size[miss].sum())
            total_hits += nh
            total_misses += nm
            req_pulled[stream.req[miss & direct]] = True
            for p in np.unique(stream.pj[miss] // r.Cmax):
                l2_pulled.add((q, int(p)))

        seconds = r.serve_base + miss_secs + r.direct_sec

        results: List[FetchResult] = []
        flow_specs: List[Tuple[List, float]] = []
        flow_bytes: List[float] = []
        pulled: set = set()
        for i in range(n):
            p = int(r.pid[i])
            if not r.ok[i]:
                results.append(FetchResult(
                    path=r.paths[p], method=r.methods[i], plane="analytic",
                    start=r.at[i], ok=False,
                    error=f"FileNotFoundError: {r.paths[p]}"))
                continue
            if r.method_is_direct[i] or r.fallback[i]:
                results.append(FetchResult(
                    path=r.paths[p], size=int(r.size[p]),
                    method=("direct" if r.method_is_direct[i]
                            else "origin-direct"),
                    plane="analytic", seconds=seconds[i],
                    bytes=int(r.size[p]), chunks=int(r.nchunks[p]),
                    cache_misses=int(r.nchunks[p]),
                    source=r.owner_names[p], start=r.at[i]))
            else:
                ci = int(r.chosen[i])
                if req_pulled[i] and (ci, p) not in pulled:
                    pulled.add((ci, p))
                    links, cap_f = r.pull_flow[(ci, p)]
                    flow_specs.append((links, cap_f))
                    flow_bytes.append(float(r.size[p]))
                hit = miss_chunks[i] == 0
                results.append(FetchResult(
                    path=r.paths[p], size=int(r.size[p]), method="stash",
                    plane="analytic", seconds=seconds[i],
                    bytes=int(r.size[p]), chunks=int(r.nchunks[p]),
                    cache_hit=bool(hit), cache_hits=int(hit_chunks[i]),
                    cache_misses=int(miss_chunks[i]),
                    source=r.cache_names[ci], start=r.at[i]))
            links, cap_f = r.serve_flow[i]
            flow_specs.append((links, cap_f))
            flow_bytes.append(float(r.size[p]))
        for q, p in sorted(l2_pulled):
            # the parent's own origin pulls (fill misses); direct misses
            # at the parent were already priced through ``pulled``
            if (q, p) in pulled:
                continue
            entry = r.pull_flow.get((q, p))
            if entry is not None:
                links, cap_f = entry
                flow_specs.append((links, cap_f))
                flow_bytes.append(float(r.size[p]))

        report = ScenarioReport(
            name=self.spec.name, engine="analytic", results=results,
            bytes_moved=r.bytes_moved,
            cache_hits=total_hits,
            cache_misses=total_misses,
            origin_egress_bytes=egress,
            parent_fill_bytes=parent_fill,
            tier_hits=tier_hits, tier_misses=tier_misses,
            tier_fill_bytes=tier_fill,
            evictions=evictions, bytes_evicted=bytes_evicted,
            admission_rejects=admission_rejects,
            **r.counters)
        return report, (flow_specs, flow_bytes)


def _plan_cell_vectorized(cspec: ScenarioSpec, routing_fed: FederationSpec,
                          fed: Federation, state: Dict,
                          telemetry: Dict) -> Optional[_CellPlan]:
    """Build (or reuse) the cell's routing product and wrap it in a
    policy-point plan.  Routing is cached by the cell spec with its
    *name* cleared and its federation replaced by ``routing_fed`` (the
    normalized spec the caller already built to pick the shared
    federation) — the whole cache-policy sweep column shares one
    entry."""
    key = dataclasses.replace(cspec, name="", federation=routing_fed)
    routing = None
    for known, cached in state["cells"]:
        if known == key:
            routing = cached
            break
    if routing is None:
        routing = _cell_routing(key, fed, state, telemetry)
        if routing is None:
            return None
        state["cells"].append((key, routing))
    return _CellPlan(cspec, routing)


def _fit_wanted(plan: "_CellPlan", wanted: List, l2: bool = False) -> None:
    """Queue the *unfiltered* (all keys admitted) stack-distance
    variant of every stream the plan touches — the capacity-free reuse
    profile the differentiable cache models fit.  Rides the same
    batched kernel call as the cells' own variants; streams that
    already resolve through an all-admitted ``dist`` variant share it
    byte for byte."""
    order = ([(stream, None) for _q, stream, _m, _a in plan._l2_order]
             if l2 else
             [(plan.routing.streams[ci], None)
              for ci, _m, _a in plan._order])
    for stream, _ in order:
        admitted = np.ones(stream.n_keys, bool)
        wanted.append((stream, admitted.tobytes(), admitted))


def _fit_products(stream: _CacheStream, fit, cache: Dict[int, Tuple]
                  ) -> Tuple[Optional[Dict], Optional[object]]:
    """(histogram dict, CacheModel) for one stream, built once per
    stream object and shared by every cell of the routing column."""
    got = cache.get(id(stream))
    if got is not None:
        return got
    from repro.kernels.cache_model import (fit_histogram_model,
                                           fit_lognormal_mixture,
                                           reuse_histogram)
    sig = np.ones(stream.n_keys, bool).tobytes()
    v = stream.variants.get(sig)
    if v is None:
        return None, None
    if stream.is_fill is not None:
        of = 1.0   # merged parent streams miss straight to the origin
    else:
        tot = float(stream.size.sum())
        of = (float(stream.size[stream.parent_ci < 0].sum()) / tot
              if tot > 0 else 1.0)
    hist = reuse_histogram(v["dist"], v["sizes"])
    model = (fit_lognormal_mixture(hist, origin_fraction=of)
             if fit == "mixture"
             else fit_histogram_model(hist, origin_fraction=of))
    cache[id(stream)] = (hist.to_dict(), model)
    return cache[id(stream)]


def run_sweep(spec: SweepSpec, batched: bool = True,
              price_contention: bool = True, fit=False) -> SweepReport:
    """Execute every cell of a sweep.

    ``batched=True`` routes eligible analytic cells through the
    vectorized executor: pristine federations, routing tables and
    per-cache request streams shared across each cache-policy sweep
    column; hit/miss resolved by the stack-distance kernel (one pass
    answers every LRU capacity in the column) or the batched LRU/FIFO
    state machine (capacity × policy × admission points of one stream
    share a device call); and every cell's contention — the all-at-once
    storm counterfactual of its workload — priced by the pow2-bucketed,
    vmapped max-min kernel.  A handful of jitted calls covers the whole
    sweep (``report.solver``).  Ineligible cells (sim engine,
    proxy/cvmfs methods, LFU/TTL victim orders) fall back to a serial
    :func:`run_scenario`, so a mixed sweep still completes with
    identical semantics.  ``batched=False`` is the all-serial baseline
    the benchmarks and parity tests compare against.

    ``fit=True`` additionally returns *fitted models* alongside the
    exact cells: every batched stream's unfiltered reuse-distance
    profile is resolved in the same batched kernel calls, bucketed
    into a per-cache ``reuse_histogram`` and fitted into a
    differentiable :class:`~repro.kernels.cache_model.CacheModel`
    (``fit="mixture"`` fits parametric lognormal mixtures instead of
    the nonparametric smoothed-histogram curve).  Both ride on the
    cells — ``cell.reuse_histogram`` / ``cell.models``,
    :meth:`SweepReport.fitted_models` — never inside the summaries the
    parity tests compare, and feed :mod:`repro.core.planner`.
    """
    t0 = time.perf_counter()
    shared = _SharedFederations()
    telemetry: Dict[str, object] = {}
    entries: List[Tuple[Dict, ScenarioSpec, Optional[_CellPlan],
                        Optional[ScenarioReport]]] = []
    sim_problems: List[Tuple] = []
    fifo_problems: List[Tuple] = []
    dist_wanted: List[Tuple[_CacheStream, bytes, np.ndarray]] = []
    batched_cells = serial_cells = 0
    for params, cspec in spec.cells():
        plan = None
        if batched and _sweep_batchable(cspec):
            routing_fed = _routing_fedspec(cspec.federation)
            fed, state = shared.get(routing_fed)
            plan = _plan_cell_vectorized(cspec, routing_fed, fed, state,
                                         telemetry)
        if plan is not None:
            plan.offset = len(sim_problems)
            plan.fifo_offset = len(fifo_problems)
            sim_problems.extend(plan.problems)
            fifo_problems.extend(plan.fifo_problems)
            dist_wanted.extend(plan.dist_wanted)
            if fit:
                _fit_wanted(plan, dist_wanted)
            batched_cells += 1
            entries.append((dict(params), cspec, plan, None))
        else:
            serial_cells += 1
            entries.append((dict(params), cspec, None, run_scenario(cspec)))

    if dist_wanted:
        _resolve_distances(dist_wanted, telemetry)
    sim_results: List = []
    fifo_results: List = []
    if fifo_problems:
        from repro.kernels.stack_distance import fifo_sim_batch
        fifo_stats: Dict = {}
        fifo_results = fifo_sim_batch(fifo_problems, stats=fifo_stats)
        telemetry["fifo_calls"] = fifo_stats["solve_calls"]
        telemetry["fifo_problems"] = fifo_stats["problems"]
    if sim_problems:
        from repro.kernels.stack_distance import cache_sim_batch
        sim_stats: Dict = {}
        sim_results = cache_sim_batch(sim_problems, stats=sim_stats)
        telemetry["cache_sim_calls"] = sim_stats["solve_calls"]
        telemetry["cache_sim_problems"] = sim_stats["problems"]

    # round 2: parent-tier caches see their direct references merged
    # with the fills the children's misses induced, so their problems
    # only exist once round 1 is resolved — same batched kernels, one
    # more pass, still zero serial cells
    l2_sim_problems: List[Tuple] = []
    l2_fifo_problems: List[Tuple] = []
    l2_dist_wanted: List[Tuple[_CacheStream, bytes, np.ndarray]] = []
    for params, cspec, plan, report in entries:
        if plan is not None and plan.routing.fill_targets:
            plan.prepare_l2(sim_results, fifo_results)
            plan.l2_offset = len(l2_sim_problems)
            plan.l2_fifo_offset = len(l2_fifo_problems)
            l2_sim_problems.extend(plan.l2_problems)
            l2_fifo_problems.extend(plan.l2_fifo_problems)
            l2_dist_wanted.extend(plan.l2_dist_wanted)
            if fit:
                _fit_wanted(plan, l2_dist_wanted, l2=True)
    if l2_dist_wanted:
        _resolve_distances(l2_dist_wanted, telemetry)
    l2_sim_results: List = []
    l2_fifo_results: List = []
    if l2_fifo_problems:
        from repro.kernels.stack_distance import fifo_sim_batch
        l2_fifo_stats: Dict = {}
        l2_fifo_results = fifo_sim_batch(l2_fifo_problems,
                                         stats=l2_fifo_stats)
        telemetry["fifo_calls"] = (telemetry.get("fifo_calls", 0)
                                   + l2_fifo_stats["solve_calls"])
        telemetry["fifo_problems"] = (telemetry.get("fifo_problems", 0)
                                      + l2_fifo_stats["problems"])
    if l2_sim_problems:
        from repro.kernels.stack_distance import cache_sim_batch
        l2_sim_stats: Dict = {}
        l2_sim_results = cache_sim_batch(l2_sim_problems,
                                         stats=l2_sim_stats)
        telemetry["cache_sim_calls"] = (
            telemetry.get("cache_sim_calls", 0)
            + l2_sim_stats["solve_calls"])
        telemetry["cache_sim_problems"] = (
            telemetry.get("cache_sim_problems", 0)
            + l2_sim_stats["problems"])
    if l2_sim_problems or l2_fifo_problems or l2_dist_wanted:
        telemetry["tier_rounds"] = 2

    cells: List[SweepCell] = []
    problems = []
    problem_bytes = []
    problem_cells: List[SweepCell] = []
    fit_cache: Dict[int, Tuple] = {}
    for params, cspec, plan, report in entries:
        if plan is not None:
            report, (flow_specs, flow_bytes) = plan.finalize(
                sim_results, fifo_results, l2_sim_results,
                l2_fifo_results)
            executor = "batched"
        else:
            flow_specs = flow_bytes = None
            executor = "serial"
        cell = SweepCell(params=params, name=cspec.name,
                         engine=cspec.engine, executor=executor,
                         summary=report.summary())
        if fit and plan is not None:
            r = plan.routing
            hists: Dict[str, Dict] = {}
            mods: Dict[str, object] = {}
            pairs = [(r.cache_names[ci], r.streams[ci])
                     for ci, _m, _a in plan._order]
            pairs += [(r.cache_names[q], stream)
                      for q, stream, _m, _a in plan._l2_order]
            for name, stream in pairs:
                h, mdl = _fit_products(stream, fit, fit_cache)
                if h is not None:
                    hists[name] = h
                    mods[name] = mdl
            cell.reuse_histogram = hists
            cell.models = mods
        if executor == "batched" and price_contention and flow_specs:
            problems.append(sparse_flow_problem(flow_specs))
            problem_bytes.append(np.asarray(flow_bytes))
            problem_cells.append(cell)
        cells.append(cell)
    solver: Dict[str, object] = {"solve_calls": 0, "priced_cells": 0}
    if fit:
        telemetry["fit_streams"] = len(fit_cache)
    solver.update(telemetry)
    if problems:
        from repro.kernels.batched_maxmin import maxmin_rates_batch
        stats: Dict = {}
        rates = maxmin_rates_batch(problems, stats=stats)
        solver.update(stats)
        solver["priced_cells"] = len(problems)
        for cell, nbytes, rr in zip(problem_cells, problem_bytes, rates):
            rr = np.maximum(rr, 1e-9)
            cell.pricing = {
                "peak_flows": int(len(rr)),
                "min_rate": float(rr.min()) if len(rr) else 0.0,
                "mean_rate": float(rr.mean()) if len(rr) else 0.0,
                "storm_finish_seconds": float((nbytes / rr).max())
                if len(rr) else 0.0,
            }
    return SweepReport(
        name=spec.name, axes={k: list(v) for k, v in spec.axes.items()},
        cells=cells, wall_seconds=time.perf_counter() - t0,
        batched_cells=batched_cells, serial_cells=serial_cells,
        solver=solver)
