"""One federation access API — the unified data plane (paper §3).

The paper's value proposition is the *federation interface*: clients name
data by path, and the federation (redirectors, namespace, caches) resolves
and serves it.  This module is that interface as a typed protocol with two
interchangeable engines:

* :class:`AnalyticPlane` — instant execution over the functional
  federation (:class:`~repro.core.client.StashClient` /
  :class:`~repro.core.proxy.HTTPProxy`): transfers move real or synthetic
  bytes immediately and *account* time with the uncontended
  :class:`~repro.core.transfer.NetworkModel`.
* :class:`SimulatedPlane` — the same requests replayed as coroutines on
  the fluid-flow discrete-event simulator
  (:class:`~repro.core.simclient.SimStashClient` /
  :class:`~repro.core.simulator.FluidFlowSim`), with max-min link
  contention, collapsed forwarding, hedged fetches and outage schedules.

Callers write ``plane.fetch("/ospool/file")`` identically on either plane
and get a :class:`FetchResult` back — the type that unifies the old
``TransferStats`` (analytic) and ``DownloadResult`` (simulated) shapes.
Path resolution is namespace-first: the owning origin comes from
longest-prefix match through :class:`~repro.core.redirector.Redirector` /
:class:`~repro.core.namespace.Namespace`, never from a held origin or
cache reference.

On top of the planes sits the declarative layer: a
:class:`ScenarioSpec` names a federation
(:class:`~repro.core.federation.FederationSpec`), a workload
(:class:`WorkloadSpec` or an explicit request list), an optional
:class:`~repro.core.simclient.OutageSchedule`, the solver and the engine;
:func:`run_scenario` builds a fresh federation, publishes the workload's
objects, executes every request on the chosen engine and aggregates a
:class:`ScenarioReport`.  Because the spec is inert data, the *same*
scenario runs on both engines — which is what the engine-parity tests
and the CI smoke assert.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, Generator, List, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

from .client import StashClient
from .federation import Federation, FederationSpec
from .simclient import (OutageSchedule, ScenarioEngine, ScenarioReport,
                        apply_outage)
from .simulator import direct_download, proxy_download
from .transfer import TransferStats
from .workload import AccessRequest, generate_workload, storm_workload

GB = 10**9


# ---------------------------------------------------------------------------
# Typed request/response models
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FetchRequest:
    """One named-data fetch: *what* (path), *where from* (site/worker),
    *how* (method) and *when* (arrival time, simulated plane)."""

    path: str
    site: str = ""          # requesting site; "" = first worker-bearing site
    worker: int = 0
    method: str = "stash"   # "stash" | "cvmfs" | "proxy" | "direct"
    at: float = 0.0         # arrival time (sim clock; analytic outage clock)
    size: int = 0           # size hint for publishing synthetic objects
    streams: int = 0        # 0 = plane default

    METHODS = ("stash", "cvmfs", "proxy", "direct")

    def __post_init__(self) -> None:
        if self.method not in self.METHODS:
            raise ValueError(f"unknown fetch method {self.method!r}")


@dataclasses.dataclass
class FetchResult:
    """What one fetch did — the unification of the analytic path's
    ``TransferStats`` and the simulator's ``DownloadResult``.

    ``seconds`` is accounted (analytic) or simulated (sim) wall time;
    ``bytes`` is what crossed the last hop to the worker; chunk-level
    ``cache_hits``/``cache_misses`` are exact on the analytic plane and
    derived from the hit/miss status on the simulated plane (per-chunk
    splits under concurrency live in the federation's ``CacheStats``).
    """

    path: str
    size: int = 0
    method: str = ""
    plane: str = ""         # "analytic" | "sim"
    seconds: float = 0.0
    bytes: int = 0
    chunks: int = 0
    cache_hit: bool = False
    cache_hits: int = 0
    cache_misses: int = 0
    waited: bool = False    # collapsed-forwarding wait (sim)
    hedged: bool = False    # a backup fetch was raced (sim)
    source: str = ""        # cache/proxy/origin that served the last hop
    failovers: int = 0
    start: float = 0.0
    ok: bool = True
    error: str = ""

    @classmethod
    def from_transfer(cls, path: str, stats: TransferStats, *,
                      method: str, start: float = 0.0) -> "FetchResult":
        """Analytic-plane constructor: fold a ``TransferStats``."""
        return cls(path=path, size=stats.bytes, method=method,
                   plane="analytic", seconds=stats.seconds,
                   bytes=stats.bytes, chunks=stats.chunks,
                   cache_hit=(stats.cache_misses == 0
                              and stats.cache_hits > 0),
                   cache_hits=stats.cache_hits,
                   cache_misses=stats.cache_misses,
                   source=stats.source, start=start)


@dataclasses.dataclass
class StatResult:
    """Namespace-first metadata lookup: does the federation know the
    path, how big is it, and which origin exports it."""

    path: str
    found: bool
    size: int = 0
    num_chunks: int = 0
    chunk_size: int = 0
    origin: str = ""


# ---------------------------------------------------------------------------
# The protocol both engines implement
# ---------------------------------------------------------------------------
@runtime_checkable
class DataPlane(Protocol):
    """The one federation access API.

    Implementations hold a :class:`Federation`; callers hold only paths.
    ``fetch`` accepts a bare path (all defaults) or a
    :class:`FetchRequest`; ``fetch_all`` executes a workload — under
    contention with an optional outage schedule on the simulated plane,
    in request-time order with outage events interleaved on the analytic
    plane.  ``publish``/``stat`` route through the redirectors'
    namespace (longest-prefix), so multi-origin federations work without
    the caller ever naming an origin.
    """

    name: str
    fed: Federation

    def stat(self, path: str) -> StatResult: ...

    def publish(self, path: str, data: Union[bytes, int],
                mtime: float = 0.0) -> StatResult: ...

    def fetch(self, request: Union[str, FetchRequest]) -> FetchResult: ...

    def fetch_all(self, requests: Sequence[FetchRequest],
                  schedule: Optional[OutageSchedule] = None,
                  sequential: bool = False) -> List[FetchResult]: ...


class _PlaneBase:
    """Namespace-first resolution shared by both engines."""

    name = ""

    def __init__(self, fed: Federation) -> None:
        self.fed = fed

    def stat(self, path: str) -> StatResult:
        try:
            origin = self.fed.redirectors.locate(path)
        except ConnectionError:
            origin = None
        if origin is None:
            return StatResult(path=path, found=False)
        meta = origin.meta(path)
        return StatResult(path=path, found=True, size=meta.size,
                          num_chunks=meta.num_chunks,
                          chunk_size=meta.chunk_size, origin=origin.name)

    def publish(self, path: str, data: Union[bytes, int],
                mtime: float = 0.0) -> StatResult:
        origin = self.fed.resolve_origin(path)
        if origin is None:
            raise KeyError(f"no origin exports a prefix of {path!r}")
        meta = origin.put_object(path, data, mtime=mtime)
        return StatResult(path=path, found=True, size=meta.size,
                          num_chunks=meta.num_chunks,
                          chunk_size=meta.chunk_size, origin=origin.name)

    def _default_site(self) -> str:
        for s in self.fed.sites:
            if s.workers > 0:
                return s.name
        return self.fed.sites[0].name

    def _req(self, request: Union[str, FetchRequest]) -> FetchRequest:
        req = (FetchRequest(path=request) if isinstance(request, str)
               else request)
        if not req.site:
            req = dataclasses.replace(req, site=self._default_site())
        return req


# ---------------------------------------------------------------------------
# Engine 1: analytic (functional federation, uncontended accounting)
# ---------------------------------------------------------------------------
class AnalyticPlane(_PlaneBase):
    """Instant execution with :class:`NetworkModel` time accounting.

    ``stash`` fetches go through the real :class:`StashClient` fallback
    chain restricted to the cache-served methods (``xrootd``/``http``) —
    the worker-local CVMFS cache is *not* consulted, so the cache tier
    sees the same lookups the simulated plane produces (engine parity).
    ``cvmfs`` exposes the POSIX read path (worker-local chunk cache
    included); ``proxy`` is the squid baseline; ``direct`` bypasses the
    cache tier entirely.
    """

    name = "analytic"

    def __init__(self, fed: Federation, streams: int = 8) -> None:
        super().__init__(fed)
        self.streams = streams
        self.clients: Dict[Tuple[str, int], StashClient] = {}

    def client(self, site: str, worker: int = 0) -> StashClient:
        key = (site, worker)
        c = self.clients.get(key)
        if c is None:
            c = self.fed.client(site, worker)
            self.clients[key] = c
        return c

    # -- the one entry point -------------------------------------------------
    def fetch(self, request: Union[str, FetchRequest]) -> FetchResult:
        req = self._req(request)
        try:
            return self._fetch(req)
        except (FileNotFoundError, ConnectionError, KeyError) as e:
            return FetchResult(path=req.path, method=req.method,
                               plane=self.name, start=req.at,
                               ok=False, error=f"{type(e).__name__}: {e}")

    def _fetch(self, req: FetchRequest) -> FetchResult:
        client = self.client(req.site, req.worker)
        client.now = max(client.now, req.at)
        if req.method == "stash":
            try:
                _, stats = client.copy(req.path, methods=("xrootd", "http"))
            except (FileNotFoundError, ConnectionError):
                # Every ranked cache failed: like the simulated client,
                # the federation degrades to a direct origin pull — but
                # only if the path actually exists.
                if not self.stat(req.path).found:
                    raise
                client.stats.origin_fallbacks += 1
                res = self._fetch_direct(req, client)
                res.method = "origin-direct"
                res.start = req.at
                return res
        elif req.method == "cvmfs":
            _, stats = client.read(req.path)
        elif req.method == "proxy":
            res = self._fetch_proxy(req, client)
            res.start = req.at
            return res
        else:  # direct
            res = self._fetch_direct(req, client)
            res.start = req.at
            return res
        res = FetchResult.from_transfer(req.path, stats, method=req.method,
                                        start=req.at)
        return res

    def _fetch_proxy(self, req: FetchRequest,
                     client: StashClient) -> FetchResult:
        proxy = self.fed.proxies.get(req.site)
        if proxy is None:
            raise KeyError(f"site {req.site!r} has no HTTP proxy")
        origin = self.fed.redirectors.locate(req.path)
        if origin is None:
            raise FileNotFoundError(req.path)
        meta = origin.meta(req.path)
        _, stats = proxy.get_object(client.node.name, meta, now=req.at)
        return FetchResult(
            path=req.path, size=meta.size, method="proxy",
            plane=self.name, seconds=stats.seconds, bytes=stats.bytes,
            chunks=stats.chunks, cache_hit=stats.cache_hits > 0,
            cache_hits=stats.cache_hits, cache_misses=stats.cache_misses,
            source=stats.source)

    def _fetch_direct(self, req: FetchRequest,
                      client: StashClient) -> FetchResult:
        origin = self.fed.redirectors.locate(req.path)
        if origin is None:
            raise FileNotFoundError(req.path)
        meta = origin.meta(req.path)
        streams = req.streams or self.streams
        seconds = self.fed.net.transfer_time(
            origin.node.name, client.node.name, meta.size, streams=streams)
        for ref in meta.chunk_refs():
            origin.read_chunk(req.path, ref.index)  # egress accounting
        return FetchResult(
            path=req.path, size=meta.size, method="direct",
            plane=self.name, seconds=seconds, bytes=meta.size,
            chunks=meta.num_chunks, cache_misses=meta.num_chunks,
            source=origin.name)

    def fetch_all(self, requests: Sequence[FetchRequest],
                  schedule: Optional[OutageSchedule] = None,
                  sequential: bool = False) -> List[FetchResult]:
        """Requests in arrival order, outage events interleaved by time.

        The analytic plane is sequential by construction (transfers are
        instantaneous), so ``sequential`` is accepted for protocol
        symmetry and ignored.
        """
        events = list(schedule) if schedule is not None else []
        group_of = {c.name: g for g in self.fed.groups.values()
                    for c in g.members} if events else {}
        results: List[Optional[FetchResult]] = [None] * len(requests)
        order = sorted(range(len(requests)),
                       key=lambda i: self._req(requests[i]).at)
        ei = 0
        for i in order:
            req = self._req(requests[i])
            while ei < len(events) and events[ei].time <= req.at:
                apply_outage(self.fed, events[ei], group_of=group_of)
                ei += 1
            results[i] = self.fetch(req)
        while ei < len(events):
            apply_outage(self.fed, events[ei], group_of=group_of)
            ei += 1
        return [r for r in results if r is not None]


# ---------------------------------------------------------------------------
# Engine 2: simulated (fluid-flow DES, contention + outages)
# ---------------------------------------------------------------------------
class SimulatedPlane(_PlaneBase):
    """The same API, replayed as coroutines under max-min contention.

    Wraps a :class:`~repro.core.simclient.ScenarioEngine` for its sim,
    per-(site, worker) :class:`SimStashClient` pool and outage
    controller.  ``fetch`` runs one request to completion; ``fetch_all``
    spawns the whole workload (concurrently by arrival time, or
    ``sequential`` for protocols like the paper's 4-download experiment
    where requests must not compete) and runs the sim once.
    """

    name = "sim"

    def __init__(self, fed: Federation, solver: str = "auto",
                 streams: int = 8, hedge_after: Optional[float] = None,
                 max_attempts: int = 4, rank_limit: Optional[int] = 8,
                 router: str = "ring") -> None:
        super().__init__(fed)
        self.engine = ScenarioEngine(
            fed, solver=solver, streams=streams, hedge_after=hedge_after,
            max_attempts=max_attempts, rank_limit=rank_limit, router=router)
        self.streams = streams

    @property
    def sim(self):
        return self.engine.sim

    @property
    def clients(self):
        return self.engine._clients

    # -- coroutines ----------------------------------------------------------
    def _download(self, req: FetchRequest, res: FetchResult) -> Generator:
        sim = self.sim
        origin = self.fed.redirectors.locate(req.path)
        if origin is None:
            res.ok = False
            res.error = f"FileNotFoundError: {req.path}"
            return
        meta = origin.meta(req.path)
        res.size = meta.size
        res.chunks = meta.num_chunks
        if req.method in ("stash", "cvmfs"):
            # The simulator models no worker-local cache; cvmfs degrades
            # to the cache-served path (same chunks, same accounting).
            sc = self.engine.client(req.site, req.worker)
            yield from sc.download(req.path, meta=meta, result=res)
        elif req.method == "proxy":
            proxy = self.fed.proxies.get(req.site)
            if proxy is None:
                res.ok = False
                res.error = f"KeyError: site {req.site!r} has no HTTP proxy"
                return
            wnode = self.engine.client(req.site, req.worker).node_name
            yield from proxy_download(sim, wnode, proxy, origin.node.name,
                                      meta, result=res)
            res.method = "proxy"
        else:  # direct
            wnode = self.engine.client(req.site, req.worker).node_name
            yield from direct_download(sim, wnode, origin.node.name, meta,
                                       streams=req.streams or self.streams,
                                       result=res)
            origin.stats.egress_bytes += meta.size
            res.source = origin.name
        if res.seconds > 0:
            res.bytes = meta.size
            if res.cache_hit:
                res.cache_hits = res.chunks
            else:
                res.cache_misses = res.chunks

    def _chain(self, pairs: List[Tuple[FetchRequest, FetchResult]]
               ) -> Generator:
        for req, res in pairs:
            if req.at > self.sim.t:
                yield self.sim.delay(req.at - self.sim.t)
            yield from self._download(req, res)

    # -- the one entry point -------------------------------------------------
    def fetch(self, request: Union[str, FetchRequest]) -> FetchResult:
        return self.fetch_all([self._req(request)], sequential=True)[0]

    def fetch_all(self, requests: Sequence[FetchRequest],
                  schedule: Optional[OutageSchedule] = None,
                  sequential: bool = False) -> List[FetchResult]:
        reqs = [self._req(r) for r in requests]
        results = [FetchResult(path=r.path, method=r.method,
                               plane=self.name) for r in reqs]
        if sequential:
            self.sim.spawn(self._chain(list(zip(reqs, results))))
        else:
            for req, res in zip(reqs, results):
                # A reused plane's clock has advanced past early arrival
                # times; never schedule into the past (the sim clock is
                # monotonic).
                self.sim.spawn(self._download(req, res),
                               at=max(req.at, self.sim.t))
        if schedule is not None and len(schedule):
            self.sim.spawn(self.engine._outage_controller(schedule))
        self.sim.run()
        return results


# ---------------------------------------------------------------------------
# Declarative scenarios
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WorkloadSpec:
    """A declarative workload: a restart ``storm`` (every worker pulls
    the same object) or a production-shaped ``zipf`` trace (Table 2
    sizes, Table 1 experiment mix).  ``sites=None`` targets every
    worker-bearing site of the federation."""

    kind: str = "zipf"               # "zipf" | "storm"
    sites: Optional[Sequence[str]] = None
    # zipf trace knobs
    n_requests: int = 100
    duration: float = 3600.0
    working_set: int = 64
    zipf_a: float = 1.2
    seed: int = 0
    # storm knobs
    path: str = "/ckpt/step/params"
    size: int = 2 * GB
    at: float = 0.0
    workers_per_site: int = 1
    jitter: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in ("zipf", "storm"):
            raise ValueError(f"unknown workload kind {self.kind!r}")

    def build(self, fed: Federation, method: str = "stash"
              ) -> List[FetchRequest]:
        sites = (list(self.sites) if self.sites
                 else [s.name for s in fed.sites if s.workers > 0])
        if self.kind == "storm":
            trace = storm_workload(sites, path=self.path, size=self.size,
                                   at=self.at,
                                   workers_per_site=self.workers_per_site,
                                   jitter=self.jitter, seed=self.seed)
        else:
            trace = generate_workload(sites, self.n_requests,
                                      duration=self.duration,
                                      seed=self.seed,
                                      working_set=self.working_set,
                                      zipf_a=self.zipf_a)
        hosts = {s.name: max(1, s.workers) for s in fed.sites}
        return [FetchRequest(path=r.path, site=r.site,
                             worker=r.worker % hosts.get(r.site, 1),
                             method=method, at=r.time, size=r.size)
                for r in trace]


@dataclasses.dataclass
class ScenarioSpec:
    """One scenario, declaratively: federation + workload + outages +
    solver + engine.  Executed by :func:`run_scenario`; the same spec
    runs on either engine (``engine="sim" | "analytic"``)."""

    name: str
    federation: FederationSpec
    workload: Union[WorkloadSpec, Sequence[FetchRequest],
                    Sequence[AccessRequest]]
    outages: Optional[OutageSchedule] = None
    engine: str = "sim"
    method: str = "stash"            # default for declarative workloads
    sequential: bool = False         # chain requests (no competition)
    solver: str = "auto"
    streams: int = 8
    hedge_after: Optional[float] = None
    max_attempts: int = 4
    rank_limit: Optional[int] = 8
    router: str = "ring"

    def __post_init__(self) -> None:
        if self.engine not in ("sim", "analytic"):
            raise ValueError(f"unknown engine {self.engine!r}")

    def requests(self, fed: Federation) -> List[FetchRequest]:
        if isinstance(self.workload, WorkloadSpec):
            return self.workload.build(fed, method=self.method)
        hosts = {s.name: max(1, s.workers) for s in fed.sites}
        out: List[FetchRequest] = []
        for r in self.workload:
            if isinstance(r, AccessRequest):
                out.append(FetchRequest(
                    path=r.path, site=r.site,
                    worker=r.worker % hosts.get(r.site, 1),
                    method=self.method, at=r.time, size=r.size))
            else:
                out.append(r)
        return out

    def plane(self, fed: Federation) -> DataPlane:
        if self.engine == "analytic":
            return AnalyticPlane(fed, streams=self.streams)
        return SimulatedPlane(
            fed, solver=self.solver, streams=self.streams,
            hedge_after=self.hedge_after, max_attempts=self.max_attempts,
            rank_limit=self.rank_limit, router=self.router)


def run_scenario(spec: ScenarioSpec,
                 federation: Optional[Federation] = None) -> ScenarioReport:
    """Execute one declarative scenario end to end.

    Builds a fresh federation from the spec (pass ``federation`` to reuse
    one), publishes every workload path that no origin holds yet
    (namespace-routed synthetic objects), executes the workload on the
    chosen engine, and aggregates the report.
    """
    fed = federation if federation is not None else spec.federation.build()
    plane = spec.plane(fed)
    reqs = spec.requests(fed)
    sizes: Dict[str, int] = {}
    for r in reqs:
        sizes[r.path] = max(sizes.get(r.path, 0), r.size)
    for path, size in sizes.items():
        # Only requests that *declare* a size get a synthetic object; a
        # sizeless request for an unpublished path must fail visibly
        # (ok=False / FileNotFoundError), not fetch 0 bytes happily.
        if size > 0 and not plane.stat(path).found:
            plane.publish(path, size)
    # Federation counters are lifetime totals; snapshot them so a reused
    # federation (``federation=``) reports only *this* scenario's deltas.
    base = _fed_totals(fed)
    results = plane.fetch_all(reqs, schedule=spec.outages,
                              sequential=spec.sequential)
    rep = _report(spec, fed, plane, results)
    for field, before in base.items():
        setattr(rep, field, getattr(rep, field) - before)
    return rep


def _fed_totals(fed: Federation) -> Dict[str, int]:
    """The federation-lifetime counters a ScenarioReport aggregates."""
    gstats = [g.stats for g in fed.groups.values()]
    return {
        "cache_hits": sum(c.stats.hits for c in fed.caches.values()),
        "cache_misses": sum(c.stats.misses for c in fed.caches.values()),
        "origin_egress_bytes": sum(o.stats.egress_bytes
                                   for o in fed.origins),
        "group_failovers": sum(s.failovers for s in gstats),
        "outages": sum(s.outages for s in gstats),
        "recoveries": sum(s.recoveries for s in gstats),
    }


def _report(spec: ScenarioSpec, fed: Federation, plane: DataPlane,
            results: List[FetchResult]) -> ScenarioReport:
    if isinstance(plane, SimulatedPlane):
        return plane.engine.report(results, name=spec.name)
    cstats = [c.stats for c in plane.clients.values()]
    gstats = [g.stats for g in fed.groups.values()]
    return ScenarioReport(
        name=spec.name,
        engine=plane.name,
        results=results,
        bytes_moved=sum(r.bytes for r in results),
        cache_hits=sum(c.stats.hits for c in fed.caches.values()),
        cache_misses=sum(c.stats.misses for c in fed.caches.values()),
        origin_egress_bytes=sum(o.stats.egress_bytes for o in fed.origins),
        cache_failovers=sum(s.cache_failovers for s in cstats),
        hedged_fetches=sum(s.hedged_fetches for s in cstats),
        origin_fallbacks=sum(s.origin_fallbacks for s in cstats),
        group_failovers=sum(s.failovers for s in gstats),
        outages=sum(s.outages for s in gstats),
        recoveries=sum(s.recoveries for s in gstats),
    )
