"""Production control plane: admission queues, circuit breakers, quotas.

The paper's caches serve opportunistic users who neither own the hardware
nor control demand.  Without a control plane, excess load just contends on
links (the fluid solver is work-conserving, so everything slows down
together) and outages have to be scripted.  This module supplies the three
mechanisms real federations use to stay up under abuse:

* **Admission queues** — each cache admits at most ``max_concurrent``
  transfers; excess arrivals wait in a bounded FIFO and are *shed* (an
  explicit refusal, not silent contention) once ``queue_depth`` waiters
  are already parked.
* **Per-tenant quotas / fair share** — a tenant may hold at most
  ``tenant_quota`` of a cache's service slots, and the dequeue order is
  max-min fair across tenants (fewest-slots-held first, FIFO within a
  tenant), so one abusive experiment cannot starve the rest.
* **Circuit breakers + backoff** — clients track per-cache failures and
  stop hammering a cache that keeps erroring (closed → open → half-open),
  retrying elsewhere with exponential backoff instead of blind failover.

Health-driven demotion (time-decayed error gauges firing
``CacheGroup.mark_down(auto=True)``) lives in :mod:`repro.core.monitoring`;
:class:`ControlPlane` here is the runtime that binds all of it to a
federation for one scenario run.

Everything is engine-agnostic: the same :class:`ControlPlaneSpec` drives
the coroutine :class:`AdmissionQueue` under the fluid simulator and the
:class:`AnalyticQueue` c-server model under the analytic plane.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Generator, List, Optional, Tuple

from .monitoring import CacheHealthMonitor

__all__ = [
    "ControlPlaneSpec",
    "ControlStats",
    "CircuitBreaker",
    "AdmissionQueue",
    "AnalyticQueue",
    "ControlPlane",
    "fair_shares",
]


# ---------------------------------------------------------------------------
# Declarative knobs


@dataclasses.dataclass(frozen=True)
class ControlPlaneSpec:
    """All control-plane knobs for one scenario, declaratively.

    ``tenant_quota`` is the fraction of a cache's ``max_concurrent``
    service slots a single tenant may hold (1.0 disables quotas).
    ``queue_depth`` bounds how many requests may *wait* at one cache;
    arrivals beyond that are shed.  Breaker/backoff knobs shape the
    client retry loop; health knobs shape gauge-driven auto demotion.
    """

    max_concurrent: int = 32
    queue_depth: int = 64
    tenant_quota: float = 1.0
    # client retry behaviour
    backoff_base: float = 0.1
    backoff_multiplier: float = 2.0
    backoff_max: float = 10.0
    # per-cache circuit breakers
    breaker_enabled: bool = True
    breaker_threshold: int = 5
    breaker_cooldown: float = 30.0
    # streaming-gauge health -> automatic mark_down / mark_up
    health_enabled: bool = True
    error_threshold: float = 0.5
    latency_threshold: Optional[float] = None
    min_samples: float = 4.0
    gauge_tau: float = 60.0
    health_cooldown: float = 60.0
    topk: int = 8

    def __post_init__(self):
        if self.max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        if self.queue_depth < 0:
            raise ValueError("queue_depth must be >= 0")
        if not 0.0 < self.tenant_quota <= 1.0:
            raise ValueError("tenant_quota must be in (0, 1]")
        if self.breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")

    def quota_slots(self) -> int:
        """Service slots a single tenant may hold at one cache."""
        if self.tenant_quota >= 1.0:
            return self.max_concurrent
        return max(1, int(self.max_concurrent * self.tenant_quota))


@dataclasses.dataclass
class ControlStats:
    """Counters for one scenario's control-plane activity."""

    sheds: int = 0
    queue_waits: int = 0
    queue_wait_seconds: float = 0.0
    retries: int = 0
    backoff_seconds: float = 0.0
    breaker_opens: int = 0
    breaker_skips: int = 0
    auto_downs: int = 0
    auto_ups: int = 0
    shed_by_tenant: Dict[str, int] = dataclasses.field(default_factory=dict)

    def record_shed(self, tenant: str) -> None:
        self.sheds += 1
        key = tenant or "default"
        self.shed_by_tenant[key] = self.shed_by_tenant.get(key, 0) + 1


# ---------------------------------------------------------------------------
# Fair share


def fair_shares(demands: List[float], capacity: float,
                weights: Optional[List[float]] = None) -> List[float]:
    """Max-min fair (water-filling) allocation of ``capacity`` to demands.

    Returns per-demand allocations such that no allocation exceeds its
    demand, the total never exceeds ``capacity``, and — when demand
    outstrips supply — unsatisfied tenants split the remainder in
    proportion to ``weights`` (equal by default).  Invariant used by the
    property tests: ``sum(alloc) == min(capacity, sum(demands))``.
    """
    n = len(demands)
    if n == 0:
        return []
    w = list(weights) if weights is not None else [1.0] * n
    if len(w) != n or any(x <= 0 for x in w):
        raise ValueError("weights must be positive and match demands")
    alloc = [0.0] * n
    remaining = max(0.0, capacity)
    active = [i for i in range(n) if demands[i] > 0]
    while active and remaining > 1e-12:
        total_w = sum(w[i] for i in active)
        # smallest normalised headroom decides how far this round fills
        level = min((demands[i] - alloc[i]) / w[i] for i in active)
        level = min(level, remaining / total_w)
        for i in active:
            alloc[i] += level * w[i]
        remaining -= level * total_w
        active = [i for i in active if demands[i] - alloc[i] > 1e-12]
    return alloc


# ---------------------------------------------------------------------------
# Circuit breaker FSM


class CircuitBreaker:
    """Classic 3-state breaker: closed → open → half-open → {open, closed}.

    ``allow`` answers "may I try this cache now?"; ``on_success`` /
    ``on_failure`` feed outcomes back.  The only legal transitions are
    closed→open (threshold consecutive failures), open→half-open (cooldown
    elapsed, one probe allowed), half-open→closed (probe succeeded) and
    half-open→open (probe failed) — the property suite checks exactly
    this edge set via :attr:`state`.
    """

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half-open"

    def __init__(self, threshold: int = 5, cooldown: float = 30.0):
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self.state = self.CLOSED
        self.failures = 0
        self.opened_at = 0.0
        self.opens = 0

    def allow(self, now: float) -> bool:
        if self.state == self.OPEN:
            if now >= self.opened_at + self.cooldown:
                self.state = self.HALF_OPEN
                return True
            return False
        return True  # closed, or half-open probe in flight

    def on_success(self, now: float) -> None:
        self.failures = 0
        if self.state == self.HALF_OPEN:
            self.state = self.CLOSED

    def on_failure(self, now: float) -> None:
        if self.state == self.HALF_OPEN:
            self.state = self.OPEN
            self.opened_at = now
            self.opens += 1
            return
        self.failures += 1
        if self.state == self.CLOSED and self.failures >= self.threshold:
            self.state = self.OPEN
            self.opened_at = now
            self.opens += 1


# ---------------------------------------------------------------------------
# Admission queues — coroutine (fluid sim) and analytic (c-server) flavours


class AdmissionQueue:
    """Bounded-concurrency admission at one cache, for the coroutine sim.

    ``acquire`` is a generator: it either grants a slot immediately,
    sheds (returns ``False`` without yielding a wait), or parks the
    caller on an :class:`~repro.core.simulator.Event` until ``release``
    drains it back in.  Dequeue order is fair-share: among eligible
    waiters, the tenant currently holding the fewest slots goes first,
    FIFO within a tenant.
    """

    def __init__(self, sim, spec: ControlPlaneSpec,
                 stats: Optional[ControlStats] = None, name: str = ""):
        self.sim = sim
        self.spec = spec
        self.stats = stats if stats is not None else ControlStats()
        self.name = name
        self.in_service = 0
        self.by_tenant: Dict[str, int] = {}
        self.waiting: List[Tuple[str, object]] = []
        self.max_in_service = 0
        self.max_waiting = 0

    def can_admit(self, tenant: str = "") -> bool:
        if self.in_service >= self.spec.max_concurrent:
            return False
        if (self.spec.tenant_quota < 1.0
                and self.by_tenant.get(tenant, 0) >= self.spec.quota_slots()):
            return False
        return True

    def _grant(self, tenant: str) -> None:
        self.in_service += 1
        self.by_tenant[tenant] = self.by_tenant.get(tenant, 0) + 1
        self.max_in_service = max(self.max_in_service, self.in_service)

    def acquire(self, tenant: str = "") -> Generator:
        """Yield-from this; returns True (admitted) or False (shed)."""
        # Barge only past waiters that are themselves quota-blocked: a
        # same-tenant waiter or any admittable waiter keeps FIFO order.
        if self.can_admit(tenant) and not any(
                t == tenant or self.can_admit(t) for t, _ in self.waiting):
            self._grant(tenant)
            return True
        if len(self.waiting) >= self.spec.queue_depth:
            self.stats.record_shed(tenant)
            return False
        ev = self.sim.event()
        self.waiting.append((tenant, ev))
        self.max_waiting = max(self.max_waiting, len(self.waiting))
        t0 = self.sim.t
        yield ev
        self.stats.queue_waits += 1
        self.stats.queue_wait_seconds += self.sim.t - t0
        return True

    def release(self, tenant: str = "") -> None:
        self.in_service -= 1
        held = self.by_tenant.get(tenant, 0)
        if held <= 1:
            self.by_tenant.pop(tenant, None)
        else:
            self.by_tenant[tenant] = held - 1
        self._drain()

    def _drain(self) -> None:
        while self.waiting:
            best_i = None
            best_key: Optional[Tuple[int, int]] = None
            seen = set()
            for i, (tenant, _) in enumerate(self.waiting):
                if tenant in seen:
                    continue  # FIFO within a tenant: only its head competes
                seen.add(tenant)
                if not self.can_admit(tenant):
                    continue
                key = (self.by_tenant.get(tenant, 0), i)
                if best_key is None or key < best_key:
                    best_key, best_i = key, i
            if best_i is None:
                return
            tenant, ev = self.waiting.pop(best_i)
            self._grant(tenant)
            ev.set()


class AnalyticQueue:
    """c-server FIFO queue for the analytic plane's instant accounting.

    The analytic plane processes requests in arrival order, so a heap of
    per-slot free times reproduces queue waits exactly.  The shed
    decision (would this arrival have to wait while ``queue_depth``
    others already do?) depends only on the arrival time and current
    heap state — never on this request's own service time — so callers
    ``reserve`` before doing the transfer and ``commit`` the measured
    service time afterwards.
    """

    def __init__(self, spec: ControlPlaneSpec,
                 stats: Optional[ControlStats] = None):
        self.spec = spec
        self.stats = stats if stats is not None else ControlStats()
        self.free_at = [0.0] * spec.max_concurrent
        self.tenant_free: Dict[str, List[float]] = {}
        self._pending_starts: List[float] = []

    def reserve(self, t: float, tenant: str = "") -> Optional[float]:
        """Return the start time for an arrival at ``t``, or None = shed."""
        self._pending_starts = [s for s in self._pending_starts if s > t]
        start = max(t, self.free_at[0])
        if self.spec.tenant_quota < 1.0:
            th = self.tenant_free.setdefault(
                tenant, [0.0] * self.spec.quota_slots())
            start = max(start, th[0])
        if start > t and len(self._pending_starts) >= self.spec.queue_depth:
            self.stats.record_shed(tenant)
            return None
        return start

    def commit(self, t: float, start: float, seconds: float,
               tenant: str = "") -> float:
        """Occupy a slot for [start, start+seconds); return the wait."""
        heapq.heapreplace(self.free_at, start + seconds)
        if self.spec.tenant_quota < 1.0:
            th = self.tenant_free.setdefault(
                tenant, [0.0] * self.spec.quota_slots())
            heapq.heapreplace(th, start + seconds)
        wait = start - t
        if wait > 0:
            self._pending_starts.append(start)
            self.stats.queue_waits += 1
            self.stats.queue_wait_seconds += wait
        return wait


# ---------------------------------------------------------------------------
# Runtime


class ControlPlane:
    """Binds one :class:`ControlPlaneSpec` to a federation for a run.

    Lazily creates one breaker and one admission queue per cache, owns
    the shared :class:`ControlStats`, and bridges streaming health
    gauges to ``CacheGroup.mark_down(auto=True)`` / ``mark_up``.
    ``group_of`` maps cache name → its :class:`~repro.core.ring.CacheGroup`
    so auto demotion routes through the ring (remaps keys, counts stats)
    exactly like a scripted outage would.
    """

    def __init__(self, spec: ControlPlaneSpec, sim=None,
                 group_of: Optional[Dict[str, object]] = None):
        self.spec = spec
        self.sim = sim
        self.group_of = group_of or {}
        self.stats = ControlStats()
        self.breakers: Dict[str, CircuitBreaker] = {}
        self.queues: Dict[str, object] = {}
        self.health = CacheHealthMonitor(tau=spec.gauge_tau, topk=spec.topk)
        self._auto_down: Dict[str, float] = {}

    # -- breakers ----------------------------------------------------------

    def breaker(self, name: str) -> CircuitBreaker:
        br = self.breakers.get(name)
        if br is None:
            br = CircuitBreaker(self.spec.breaker_threshold,
                                self.spec.breaker_cooldown)
            self.breakers[name] = br
        return br

    def allow(self, name: str, now: float) -> bool:
        """May the client attempt this cache now? (breaker gate)"""
        if not self.spec.breaker_enabled:
            return True
        if self.breaker(name).allow(now):
            return True
        self.stats.breaker_skips += 1
        return False

    def backoff(self, attempt: int) -> float:
        """Exponential backoff delay before the (attempt+1)-th retry."""
        return min(self.spec.backoff_base
                   * self.spec.backoff_multiplier ** attempt,
                   self.spec.backoff_max)

    # -- admission ---------------------------------------------------------

    def queue(self, name: str):
        q = self.queues.get(name)
        if q is None:
            if self.sim is not None:
                q = AdmissionQueue(self.sim, self.spec, self.stats, name)
            else:
                q = AnalyticQueue(self.spec, self.stats)
            self.queues[name] = q
        return q

    def acquire(self, name: str, tenant: str = "",
                nbytes: int = 0) -> Generator:
        """Sim engines: yield-from; returns True (admitted) / False (shed)."""
        self.health.demand(tenant or "default", nbytes)
        admitted = yield from self.queue(name).acquire(tenant)
        return admitted

    def release(self, name: str, tenant: str = "") -> None:
        q = self.queues.get(name)
        if q is not None:
            q.release(tenant)

    # -- outcome feedback + health ----------------------------------------

    def on_success(self, name: str, now: float, seconds: float = 0.0,
                   tenant: str = "", nbytes: int = 0) -> None:
        if self.spec.breaker_enabled:
            self.breaker(name).on_success(now)
        if self.spec.health_enabled:
            self.health.observe(name, ok=True, latency=seconds, now=now)

    def on_failure(self, name: str, now: float) -> None:
        if self.spec.breaker_enabled:
            br = self.breaker(name)
            was = br.state
            br.on_failure(now)
            if br.state == CircuitBreaker.OPEN and was != CircuitBreaker.OPEN:
                self.stats.breaker_opens += 1
        if self.spec.health_enabled:
            self.health.observe(name, ok=False, latency=0.0, now=now)
            self._health_check(name, now)

    def _health_check(self, name: str, now: float) -> None:
        """Demote via the ring when the streaming gauges say unhealthy."""
        if name in self._auto_down:
            return
        group = self.group_of.get(name)
        if group is None:
            return
        cache = group.caches.get(name)
        if cache is None or not cache.available:
            return  # already down (scripted or otherwise): nothing to demote
        if self.health.unhealthy(name, now, self.spec.error_threshold,
                                 self.spec.min_samples,
                                 self.spec.latency_threshold):
            group.mark_down(name, auto=True)
            self._auto_down[name] = now
            self.stats.auto_downs += 1
            self.health.reset(name)

    def maybe_recover(self, name: str, now: float) -> bool:
        """Lazy probe: re-admit an auto-demoted cache after its cooldown.

        Called from the client routing path (there is deliberately no
        periodic controller coroutine — it would keep the simulator's
        event loop alive forever).  Never touches a cache this control
        plane did not itself demote: if a scripted schedule already
        brought it back, just drop our record without double-counting.
        """
        t_down = self._auto_down.get(name)
        if t_down is None:
            return False
        group = self.group_of.get(name)
        cache = group.caches.get(name) if group is not None else None
        if cache is not None and cache.available:
            del self._auto_down[name]  # someone else recovered it
            return False
        if now < t_down + self.spec.health_cooldown:
            return False
        del self._auto_down[name]
        if group is not None:
            group.mark_up(name, auto=True)
            self.stats.auto_ups += 1
            self.health.reset(name)
            # fresh breaker so the recovered cache gets a clean probe
            self.breakers.pop(name, None)
        return True
