"""Write-back caching — the paper's §6 future work, implemented.

"Writeback cache will allow users to write output files to a cache rather
than back to the origin.  Once the files are written to StashCache, writing
to the origin will be scheduled in order to not overwhelm the origin."

Semantics here:
  * ``write`` lands chunks in the cache immediately (fast ack, dirty);
  * reads of a dirty object are served from the cache (read-your-writes);
  * ``drain`` pushes dirty chunks to the owning origin under a rate limit,
    at most ``max_inflight`` objects at a time — the scheduling that keeps
    the origin alive during e.g. a 512-worker checkpoint save.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple, Union

from .cache import CacheServer
from .chunk import ObjectMeta, chunk_object, synthetic_object
from .origin import Origin
from .redirector import RedirectorPair
from .transfer import NetworkModel, TransferStats


@dataclasses.dataclass
class WritebackStats:
    writes: int = 0
    bytes_written: int = 0
    drained_objects: int = 0
    drained_bytes: int = 0
    drain_seconds: float = 0.0


class WritebackCache:
    """Dirty-tracking overlay on a :class:`CacheServer`."""

    def __init__(self, cache: CacheServer, net: NetworkModel,
                 redirectors: RedirectorPair,
                 drain_rate_bytes_per_sec: float = 2e9,
                 max_inflight: int = 4) -> None:
        self.cache = cache
        self.net = net
        self.redirectors = redirectors
        self.drain_rate = drain_rate_bytes_per_sec
        self.max_inflight = max_inflight
        self._dirty: Deque[str] = deque()
        self._pending: Dict[str, Tuple[ObjectMeta, List]] = {}
        self.stats = WritebackStats()

    # ------------------------------------------------------------------
    def write(self, client_node: str, path: str,
              data: Union[bytes, int]) -> Tuple[ObjectMeta, TransferStats]:
        """Write an object into the cache; ack as soon as it is resident."""
        if isinstance(data, (bytes, bytearray)):
            meta, payloads = chunk_object(path, bytes(data))
        else:
            meta, payloads = synthetic_object(path, int(data))
        stats = TransferStats(method="writeback")
        for i, p in enumerate(payloads):
            self.cache.pin(path, i)  # dirty chunks must not be evicted
            # force: dirty data must land regardless of admission policy —
            # the write is acked against cache residency.
            self.cache.admit(path, i, p, force=True)
            stats.bytes += p.size
            stats.chunks += 1
        stats.seconds += self.net.transfer_time(
            client_node, self.cache.node.name, meta.size, streams=4)
        self.cache._metas[path] = meta
        self._pending[path] = (meta, payloads)
        self._dirty.append(path)
        self.stats.writes += 1
        self.stats.bytes_written += meta.size
        return meta, stats

    def dirty_paths(self) -> List[str]:
        return list(self._dirty)

    def is_dirty(self, path: str) -> bool:
        return path in self._pending

    # ------------------------------------------------------------------
    def drain(self, max_objects: Optional[int] = None) -> TransferStats:
        """Flush dirty objects to their origins under the rate limit.

        Processes waves of ``max_inflight`` concurrent pushes until the
        dirty set is empty (or ``max_objects`` reached) — the scheduling
        that keeps the origin alive while still finishing the flush.
        """
        stats = TransferStats(method="writeback-drain")
        budget = max_objects if max_objects is not None else len(self._dirty)
        while self._dirty and budget > 0:
            before = len(self._dirty)
            wave = self._drain_wave(min(self.max_inflight, budget))
            stats.add(wave)
            drained = before - len(self._dirty)
            if drained == 0:
                break
            budget -= drained
        return stats

    def _drain_wave(self, max_objects: int) -> TransferStats:
        stats = TransferStats(method="writeback-drain-wave")
        inflight = 0
        budget = max_objects
        while self._dirty and inflight < self.max_inflight and budget > 0:
            path = self._dirty.popleft()
            meta, payloads = self._pending.pop(path)
            origin = self.redirectors.locate_origin_for_write(path) \
                if hasattr(self.redirectors, "locate_origin_for_write") else None
            if origin is None:
                origin = self._owner_origin(path)
            # Rate-limited push: the origin is protected by design.
            wire = self.net.transfer_time(self.cache.node.name,
                                          origin.node.name, meta.size,
                                          streams=4)
            limited = meta.size / self.drain_rate
            seconds = max(wire, limited)
            if payloads[0].data is not None:
                origin.put_object(path, b"".join(p.data for p in payloads),
                                  mtime=meta.mtime)
            else:
                origin.put_object(path, meta.size, mtime=meta.mtime)
            for i in range(meta.num_chunks):
                self.cache.unpin(path, i)  # now clean → evictable
            stats.bytes += meta.size
            stats.seconds += seconds
            stats.chunks += meta.num_chunks
            self.stats.drained_objects += 1
            self.stats.drained_bytes += meta.size
            self.stats.drain_seconds += seconds
            inflight += 1
            budget -= 1
        return stats

    def _owner_origin(self, path: str) -> Origin:
        for r in self.redirectors.members:
            owner = r.namespace.resolve(path)
            if owner is not None and owner in r.origins:
                return r.origins[owner]
        # Unclaimed prefix: fall back to the first subscribed origin.
        return next(iter(self.redirectors.members[0].origins.values()))
