"""Federation assembly: origins + redirector pair + caches + proxies +
clients wired over a topology (paper Fig. 1 / Fig. 2).

Two deployment idioms are provided:

* :func:`build_osg_federation` — the paper's geography: caches at
  universities and Internet2 PoPs, one origin (Stash at UChicago), two HA
  redirectors, an HTTP proxy per site.
* :func:`build_fleet_federation` — the TPU mapping: one cache per pod (and
  optionally per rack), the origin is the dataset/checkpoint store, workers
  are TPU hosts.  This is the instance the data loader and checkpointing
  layers use.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .cache import CacheServer
from .client import StashClient
from .indexer import Catalog, Indexer
from .monitoring import MessageBus, MonitorCollector, UsageAggregator
from .origin import Origin
from .policies import SizeAwareAdmission
from .proxy import HTTPProxy
from .redirector import Redirector, RedirectorGroup, RedirectorPair
from .ring import CacheGroup
from .topology import BandwidthProfile, Coord, GeoIPService, Topology
from .transfer import NetworkModel
from .writeback import WritebackCache

GB = 1e9
TB = 1e12


@dataclasses.dataclass
class SiteSpec:
    """One site (university / I2 PoP / pod).

    ``cache_replicas`` > 1 turns the site cache into an HA
    :class:`~repro.core.ring.CacheGroup`: the replicas partition the
    site's working set by consistent hashing and fail over to each other.
    ``eviction_policy`` / ``ttl_seconds`` / ``admission_max_fraction``
    select the per-cache policies (:mod:`repro.core.policies`);
    ``admission_max_fraction`` < 1 refuses objects larger than that
    fraction of cache capacity.
    """

    name: str
    workers: int = 4
    has_cache: bool = True
    has_proxy: bool = True
    cache_capacity: float = 8 * TB   # "several TBs of caching storage" (§1)
    profile: Optional[BandwidthProfile] = None
    cache_replicas: int = 1
    eviction_policy: str = "lru"
    ttl_seconds: float = 3600.0
    admission_max_fraction: float = 1.0


@dataclasses.dataclass
class Federation:
    topology: Topology
    net: NetworkModel
    geoip: GeoIPService
    origins: List[Origin]
    redirectors: RedirectorGroup
    caches: Dict[str, CacheServer]
    groups: Dict[str, CacheGroup]
    proxies: Dict[str, HTTPProxy]
    monitor: MonitorCollector
    bus: MessageBus
    aggregator: UsageAggregator
    sites: List[SiteSpec]

    # -- factories ----------------------------------------------------------
    def client(self, site: str, worker: int = 0,
               catalog: Optional[Catalog] = None,
               cvmfs: bool = True, xrootd: bool = True) -> StashClient:
        name = f"{site}/worker{worker}"
        if name not in self.topology.nodes:
            prof = self.topology.profile(site)
            self.topology.add_node(name, Coord(site, rack=0, host=worker),
                                   prof.worker_nic)
        return StashClient(self.topology.nodes[name],
                           list(self.caches.values()), self.geoip, self.net,
                           catalog=catalog, cvmfs_available=cvmfs,
                           xrootd_available=xrootd,
                           groups=list(self.groups.values()))

    def indexer(self, origin: Optional[Origin] = None) -> Indexer:
        return Indexer(origin or self.origins[0])

    def writeback(self, cache_name: str,
                  drain_rate: float = 2e9) -> WritebackCache:
        return WritebackCache(self.caches[cache_name], self.net,
                              self.redirectors,
                              drain_rate_bytes_per_sec=drain_rate)

    def nearest_cache(self, client_node: str) -> CacheServer:
        order = self.geoip.nearest(client_node, list(self.caches))
        return self.caches[order[0]]


def _build(sites: Sequence[SiteSpec], origin_site: str,
           origin_exports: Sequence[str] = ("/",),
           redirector_site: Optional[str] = None,
           proxy_max_cacheable: int = 1 * 2**30,
           proxy_ttl: float = 3600.0,
           monitor_drop_rate: float = 0.0,
           geoip_lookup_latency: float = 0.200) -> Federation:
    topo = Topology()
    for s in sites:
        topo.add_site(s.name, s.profile)
    net = NetworkModel(topo)
    geoip = GeoIPService(topo, lookup_latency=geoip_lookup_latency)
    bus = MessageBus()
    aggregator = UsageAggregator()
    bus.subscribe(aggregator)
    monitor = MonitorCollector(bus, drop_rate=monitor_drop_rate)

    oprof = topo.profile(origin_site)
    origin_node = topo.add_node(f"{origin_site}/origin",
                                Coord(origin_site, rack=255, host=0),
                                oprof.origin_nic)
    origin = Origin(f"{origin_site}/origin", origin_node,
                    exports=origin_exports)

    rsite = redirector_site or origin_site
    rprof = topo.profile(rsite)
    r1 = Redirector("redirector1", topo.add_node(
        f"{rsite}/redirector1", Coord(rsite, rack=254, host=0), rprof.cache_nic))
    r2 = Redirector("redirector2", topo.add_node(
        f"{rsite}/redirector2", Coord(rsite, rack=254, host=1), rprof.cache_nic))
    redirectors = RedirectorPair(r1, r2)
    redirectors.subscribe(origin)

    caches: Dict[str, CacheServer] = {}
    groups: Dict[str, CacheGroup] = {}
    proxies: Dict[str, HTTPProxy] = {}
    for s in sites:
        prof = topo.profile(s.name)
        if s.has_cache:
            admission = (SizeAwareAdmission(s.admission_max_fraction)
                         if s.admission_max_fraction < 1.0 else None)
            members = []
            for i in range(max(1, s.cache_replicas)):
                suffix = "cache" if i == 0 else f"cache{i}"
                node = topo.add_node(f"{s.name}/{suffix}",
                                     Coord(s.name, rack=253, host=i),
                                     prof.cache_nic)
                cache = CacheServer(
                    node.name, node, int(s.cache_capacity), redirectors, net,
                    monitor, mem_object_max=prof.cache_mem_max,
                    disk_bw=prof.cache_disk_bw, policy=s.eviction_policy,
                    ttl_seconds=s.ttl_seconds, admission=admission)
                caches[node.name] = cache
                members.append(cache)
            groups[s.name] = CacheGroup(s.name, members)
        if s.has_proxy:
            node = topo.add_node(f"{s.name}/proxy",
                                 Coord(s.name, rack=252, host=0),
                                 prof.proxy_nic)
            proxies[s.name] = HTTPProxy(
                node.name, node, origin, net,
                max_cacheable_bytes=proxy_max_cacheable,
                ttl_seconds=proxy_ttl, mem_object_max=prof.proxy_mem_max,
                disk_bw=prof.proxy_disk_bw)
    return Federation(topo, net, geoip, [origin], redirectors, caches,
                      groups, proxies, monitor, bus, aggregator, list(sites))


# Paper Fig. 2 deployment: the five test sites of §4.1 with bandwidth
# profiles calibrated to reproduce Table 3's signs (see bench docs).
# Profiles calibrated so the simulator reproduces Table 3's signs; the
# mechanisms are the paper's own observations: per-site proxy/cache NIC
# asymmetries (Fig. 6: Colorado prioritises proxy↔WAN bandwidth; its
# workers see far less bandwidth to the nearest — remote — StashCache
# cache) and disk-bound large-object serving ("proxies are optimized for
# small files").  cache_nic abstracts the worker→nearest-cache path, which
# for cache-less sites (Colorado, Bellarmine) is a remote Internet2 PoP.
OSG_SITE_PROFILES: Dict[str, BandwidthProfile] = {
    "colorado": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.16e9,
                                 proxy_nic=5.0e9, site_uplink=12.5e9,
                                 proxy_disk_bw=2.5e9),
    "syracuse": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.55e9,
                                 proxy_nic=1.25e9, site_uplink=12.5e9,
                                 proxy_disk_bw=0.6e9),
    "bellarmine": BandwidthProfile(worker_nic=1.25e9, cache_nic=1.25e9,
                                   proxy_nic=0.3e9, site_uplink=1.25e9,
                                   cache_disk_bw=0.17e9),
    "nebraska": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.6e9,
                                 proxy_nic=1.0e9, site_uplink=12.5e9,
                                 proxy_disk_bw=0.9e9, cache_disk_bw=0.5e9),
    "chicago": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.8e9,
                                proxy_nic=1.4e9, site_uplink=12.5e9,
                                proxy_disk_bw=0.8e9),
}


def build_osg_federation(workers_per_site: int = 4,
                         monitor_drop_rate: float = 0.0,
                         eviction_policy: str = "lru",
                         cache_replicas: int = 1) -> Federation:
    sites = [SiteSpec(name=n, workers=workers_per_site, profile=p,
                      eviction_policy=eviction_policy,
                      cache_replicas=cache_replicas)
             for n, p in OSG_SITE_PROFILES.items()]
    return _build(sites, origin_site="chicago",
                  monitor_drop_rate=monitor_drop_rate)


def build_fleet_federation(num_pods: int = 2, hosts_per_pod: int = 64,
                           cache_capacity: float = 32 * TB,
                           monitor_drop_rate: float = 0.0,
                           eviction_policy: str = "lru",
                           cache_replicas: int = 1,
                           ttl_seconds: float = 3600.0,
                           admission_max_fraction: float = 1.0) -> Federation:
    """TPU-fleet mapping: one cache group per pod, origin = dataset store.

    Intra-pod links are ICI-class, cross-pod is DCN-class, the origin sits
    behind a storage-fabric link.  GeoIP lookup latency is LAN-scale.
    ``cache_replicas`` > 1 gives each pod an HA consistent-hash cache
    group; ``eviction_policy`` selects the per-cache policy fleet-wide.
    """
    prof = BandwidthProfile(worker_nic=25e9, cache_nic=100e9,
                            proxy_nic=25e9, origin_nic=40e9,
                            site_uplink=50e9, wan_rtt=0.002,
                            lan_rtt=0.0002)
    sites = [SiteSpec(name=f"pod{p}", workers=hosts_per_pod,
                      cache_capacity=cache_capacity, profile=prof,
                      eviction_policy=eviction_policy,
                      cache_replicas=cache_replicas,
                      ttl_seconds=ttl_seconds,
                      admission_max_fraction=admission_max_fraction)
             for p in range(num_pods)]
    sites.append(SiteSpec(name="storage", workers=0, has_cache=False,
                          has_proxy=False, profile=prof))
    return _build(sites, origin_site="storage",
                  monitor_drop_rate=monitor_drop_rate,
                  geoip_lookup_latency=0.002)
