"""Federation assembly: origins + redirector pair + caches + proxies +
clients wired over a topology (paper Fig. 1 / Fig. 2).

Two deployment idioms are provided:

* :func:`build_osg_federation` — the paper's geography: caches at
  universities and Internet2 PoPs, one origin (Stash at UChicago), two HA
  redirectors, an HTTP proxy per site.
* :func:`build_fleet_federation` — the TPU mapping: one cache per pod (and
  optionally per rack), the origin is the dataset/checkpoint store, workers
  are TPU hosts.  This is the instance the data loader and checkpointing
  layers use.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .cache import CacheServer
from .client import StashClient
from .indexer import Catalog, Indexer
from .monitoring import MessageBus, MonitorCollector, UsageAggregator
from .origin import Origin
from .policies import SizeAwareAdmission
from .proxy import HTTPProxy
from .redirector import Redirector, RedirectorGroup, RedirectorPair
from .ring import CacheGroup
from .routing import RankingPolicy, StaticRankingPolicy, ranked_caches
from .topology import BandwidthProfile, Coord, GeoIPService, Topology
from .transfer import NetworkModel
from .writeback import WritebackCache

GB = 1e9
TB = 1e12


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One site (university / I2 PoP / pod).

    ``cache_replicas`` > 1 turns the site cache into an HA
    :class:`~repro.core.ring.CacheGroup`: the replicas partition the
    site's working set by consistent hashing and fail over to each other.
    ``eviction_policy`` / ``ttl_seconds`` / ``admission_max_fraction``
    select the per-cache policies (:mod:`repro.core.policies`);
    ``admission_max_fraction`` < 1 refuses objects larger than that
    fraction of cache capacity.

    ``parent`` names another cache-bearing site whose group is this
    site's *parent tier*: the site's cache misses fill from the parent
    group's ring before the origin (multi-tier CDN, arXiv:2007.01408).
    ``region`` places the site on the continental backbone topology
    (``core/topology.py``): same-region cross-site traffic rides the
    regional network, cross-region traffic a backbone segment.
    """

    name: str
    workers: int = 4
    has_cache: bool = True
    has_proxy: bool = True
    cache_capacity: float = 8 * TB   # "several TBs of caching storage" (§1)
    profile: Optional[BandwidthProfile] = None
    cache_replicas: int = 1
    eviction_policy: str = "lru"
    ttl_seconds: float = 3600.0
    admission_max_fraction: float = 1.0
    parent: Optional[str] = None
    region: str = ""

    def cache_names(self) -> List[str]:
        """Cache-server names this site contributes to a built
        federation, in replica order — the one naming authority shared
        by ``_build`` and anything that must address caches before a
        federation exists (sweep outage axes)."""
        if not self.has_cache:
            return []
        return [f"{self.name}/cache" if i == 0 else f"{self.name}/cache{i}"
                for i in range(max(1, self.cache_replicas))]


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One level of a cache hierarchy: the sites at that level and the
    parent site they all fill from.

    A preset-building convenience — ``flatten()`` stamps ``parent`` onto
    copies of the sites, and a built federation only ever sees
    ``SiteSpec.parent`` — so hierarchies can be declared level-by-level:

        TierSpec(sites=[edge_a, edge_b], parent="us-east-backbone")
    """

    sites: List[SiteSpec] = dataclasses.field(default_factory=list)
    parent: Optional[str] = None

    def flatten(self) -> List[SiteSpec]:
        return [dataclasses.replace(s, parent=self.parent)
                for s in self.sites]


def site_tiers(sites: Sequence[SiteSpec]) -> Dict[str, int]:
    """Tier of each cache-bearing site: 1 = edge (client-facing), and a
    parent site sits one tier above its deepest child.  Validates the
    parent graph — parents must exist, hold a cache, and form no cycle.
    """
    by_name = {s.name: s for s in sites}
    tiers: Dict[str, int] = {}
    for s in sites:
        if not s.has_cache:
            if s.parent is not None:
                raise ValueError(
                    f"site {s.name!r} names a parent but has no cache")
            continue
        chain = [s.name]
        cur = s
        while cur.parent is not None:
            p = by_name.get(cur.parent)
            if p is None:
                raise ValueError(f"site {cur.name!r} names unknown parent "
                                 f"{cur.parent!r}")
            if not p.has_cache:
                raise ValueError(f"parent site {p.name!r} of {cur.name!r} "
                                 f"has no cache")
            if p.name in chain:
                raise ValueError("parent cycle: "
                                 + " -> ".join(chain + [p.name]))
            chain.append(p.name)
            cur = p
        for depth, name in enumerate(chain, start=1):
            tiers[name] = max(tiers.get(name, 1), depth)
    return tiers


@dataclasses.dataclass
class Federation:
    topology: Topology
    net: NetworkModel
    geoip: GeoIPService
    origins: List[Origin]
    redirectors: RedirectorGroup
    caches: Dict[str, CacheServer]
    groups: Dict[str, CacheGroup]
    proxies: Dict[str, HTTPProxy]
    monitor: MonitorCollector
    bus: MessageBus
    aggregator: UsageAggregator
    sites: List[SiteSpec]

    # -- factories ----------------------------------------------------------
    def client(self, site: str, worker: int = 0,
               catalog: Optional[Catalog] = None,
               cvmfs: bool = True, xrootd: bool = True,
               ranking: Union[str, RankingPolicy, None] = None
               ) -> StashClient:
        name = f"{site}/worker{worker}"
        if name not in self.topology.nodes:
            prof = self.topology.profile(site)
            self.topology.add_node(name, Coord(site, rack=0, host=worker),
                                   prof.worker_nic)
        return StashClient(self.topology.nodes[name],
                           list(self.caches.values()), self.geoip, self.net,
                           catalog=catalog, cvmfs_available=cvmfs,
                           xrootd_available=xrootd,
                           groups=list(self.groups.values()),
                           ranking=ranking)

    def indexer(self, origin: Optional[Origin] = None) -> Indexer:
        return Indexer(origin or self.origins[0])

    def writeback(self, cache_name: str,
                  drain_rate: float = 2e9) -> WritebackCache:
        return WritebackCache(self.caches[cache_name], self.net,
                              self.redirectors,
                              drain_rate_bytes_per_sec=drain_rate)

    def nearest_cache(self, client_node: str, path: str = "/") -> CacheServer:
        """The cache a client at ``client_node`` would actually be served
        by for ``path`` — the same ranked ordering clients use (group ring
        order within the nearest group), skipping dead members.  Falls
        back to the overall ranking head when everything is down.  A pure
        query: does not touch group route/failover counters."""
        ranked = ranked_caches(client_node, self.caches,
                               list(self.groups.values()), self.geoip,
                               StaticRankingPolicy(), path=path,
                               count_stats=False)
        for cache in ranked:
            if cache.available:
                return cache
        return ranked[0]

    # -- namespace-first origin routing -------------------------------------
    def resolve_origin(self, path: str) -> Optional[Origin]:
        """The origin whose exported prefix owns ``path``
        (longest-prefix match through the redirectors' namespace).

        This is how the unified data plane *publishes*: callers name data
        by path and the federation picks the origin — nobody holds origin
        references.  Returns None when no export claims the path.
        """
        for r in self.redirectors.members:
            owner = r.namespace.resolve(path)
            if owner is not None and owner in r.origins:
                return r.origins[owner]
        return None

    def add_origin(self, site: str, exports: Sequence[str],
                   name: Optional[str] = None) -> Origin:
        """Attach another origin exporting ``exports`` at ``site`` and
        subscribe it to the redirectors (multi-origin federations)."""
        prof = self.topology.profile(site)
        idx = len(self.origins)
        if name is None:
            # Never reuse a node name: after remove_origin, a plain
            # len(origins) counter would mint an existing origin's name
            # and hijack its node + namespace registration.
            while f"{site}/origin{idx}" in self.topology.nodes:
                idx += 1
            name = f"{site}/origin{idx}"
        if name in self.topology.nodes:
            raise ValueError(f"origin node {name!r} already exists")
        node = self.topology.add_node(name, Coord(site, rack=255, host=idx),
                                      prof.origin_nic)
        origin = Origin(node.name, node, exports=exports)
        self.redirectors.subscribe(origin)
        self.origins.append(origin)
        return origin

    def remove_origin(self, origin: Union[Origin, str]) -> None:
        """Retire an origin: unsubscribe it (which unregisters its
        namespace prefixes — no dangling longest-prefix matches) and drop
        it from the federation's origin list."""
        name = origin.name if isinstance(origin, Origin) else origin
        self.redirectors.unsubscribe(name)
        self.origins = [o for o in self.origins if o.name != name]


def _build(sites: Sequence[SiteSpec], origin_site: str,
           origin_exports: Sequence[str] = ("/",),
           redirector_site: Optional[str] = None,
           proxy_max_cacheable: int = 1 * 2**30,
           proxy_ttl: float = 3600.0,
           monitor_drop_rate: float = 0.0,
           geoip_lookup_latency: float = 0.200) -> Federation:
    topo = Topology()
    for s in sites:
        topo.add_site(s.name, s.profile, region=s.region)
    net = NetworkModel(topo)
    geoip = GeoIPService(topo, lookup_latency=geoip_lookup_latency)
    bus = MessageBus()
    aggregator = UsageAggregator()
    bus.subscribe(aggregator)
    monitor = MonitorCollector(bus, drop_rate=monitor_drop_rate)

    oprof = topo.profile(origin_site)
    origin_node = topo.add_node(f"{origin_site}/origin",
                                Coord(origin_site, rack=255, host=0),
                                oprof.origin_nic)
    origin = Origin(f"{origin_site}/origin", origin_node,
                    exports=origin_exports)

    rsite = redirector_site or origin_site
    rprof = topo.profile(rsite)
    r1 = Redirector("redirector1", topo.add_node(
        f"{rsite}/redirector1", Coord(rsite, rack=254, host=0), rprof.cache_nic))
    r2 = Redirector("redirector2", topo.add_node(
        f"{rsite}/redirector2", Coord(rsite, rack=254, host=1), rprof.cache_nic))
    redirectors = RedirectorPair(r1, r2)
    redirectors.subscribe(origin)

    caches: Dict[str, CacheServer] = {}
    groups: Dict[str, CacheGroup] = {}
    proxies: Dict[str, HTTPProxy] = {}
    for s in sites:
        prof = topo.profile(s.name)
        if s.has_cache:
            admission = (SizeAwareAdmission(s.admission_max_fraction)
                         if s.admission_max_fraction < 1.0 else None)
            members = []
            for i, cache_name in enumerate(s.cache_names()):
                node = topo.add_node(cache_name,
                                     Coord(s.name, rack=253, host=i),
                                     prof.cache_nic)
                cache = CacheServer(
                    node.name, node, int(s.cache_capacity), redirectors, net,
                    monitor, mem_object_max=prof.cache_mem_max,
                    disk_bw=prof.cache_disk_bw, policy=s.eviction_policy,
                    ttl_seconds=s.ttl_seconds, admission=admission)
                caches[node.name] = cache
                members.append(cache)
            groups[s.name] = CacheGroup(s.name, members)
        if s.has_proxy:
            node = topo.add_node(f"{s.name}/proxy",
                                 Coord(s.name, rack=252, host=0),
                                 prof.proxy_nic)
            proxies[s.name] = HTTPProxy(
                node.name, node, origin, net,
                max_cacheable_bytes=proxy_max_cacheable,
                ttl_seconds=proxy_ttl, mem_object_max=prof.proxy_mem_max,
                disk_bw=prof.proxy_disk_bw)
    # Wire cache tiers: a site's caches fill misses from its parent
    # site's group before the origin.  site_tiers() validated the parent
    # graph (existence, cache-bearing, acyclic), so the wiring is a
    # straight second pass once every group exists.
    tiers = site_tiers(sites)
    for s in sites:
        if not s.has_cache:
            continue
        for cache in groups[s.name].members:
            cache.tier = tiers[s.name]
            if s.parent is not None:
                cache.parent_group = groups[s.parent]
    return Federation(topo, net, geoip, [origin], redirectors, caches,
                      groups, proxies, monitor, bus, aggregator, list(sites))


@dataclasses.dataclass(frozen=True)
class FederationSpec:
    """Declarative federation description — the deployment half of a
    :class:`~repro.core.api.ScenarioSpec`.

    A spec is data (sites + origin placement + knobs), ``build()`` turns
    it into a live :class:`Federation`.  The two deployment idioms the
    repo ships are constructors: :meth:`osg` (paper Fig. 2) and
    :meth:`fleet` (the TPU mapping).  Because the spec is inert, one
    ``ScenarioSpec`` can be executed on the analytic *and* the simulated
    engine, each against its own freshly-built federation.
    """

    sites: List[SiteSpec] = dataclasses.field(default_factory=list)
    origin_site: str = ""
    origin_exports: Tuple[str, ...] = ("/",)
    redirector_site: Optional[str] = None
    proxy_max_cacheable: int = 1 * 2**30
    proxy_ttl: float = 3600.0
    monitor_drop_rate: float = 0.0
    geoip_lookup_latency: float = 0.200

    def cache_names(self) -> List[str]:
        """Every cache-server name ``build()`` will create, in build
        order (site order, then replica index)."""
        return [n for s in self.sites for n in s.cache_names()]

    def site_tiers(self) -> Dict[str, int]:
        """Tier of each cache-bearing site (1 = edge), from the sites'
        ``parent`` links — same computation ``build()`` uses to stamp
        ``CacheServer.tier``, usable before a federation exists (sweep
        axes address tiers declaratively)."""
        return site_tiers(self.sites)

    def tier_depth(self) -> int:
        """Deepest tier in the hierarchy (1 for a flat federation)."""
        tiers = self.site_tiers()
        return max(tiers.values()) if tiers else 1

    def build(self) -> Federation:
        if not self.sites:
            raise ValueError("FederationSpec needs at least one site")
        return _build(self.sites, self.origin_site or self.sites[0].name,
                      origin_exports=self.origin_exports,
                      redirector_site=self.redirector_site,
                      proxy_max_cacheable=self.proxy_max_cacheable,
                      proxy_ttl=self.proxy_ttl,
                      monitor_drop_rate=self.monitor_drop_rate,
                      geoip_lookup_latency=self.geoip_lookup_latency)

    @classmethod
    def osg(cls, workers_per_site: int = 4, monitor_drop_rate: float = 0.0,
            eviction_policy: str = "lru",
            cache_replicas: int = 1) -> "FederationSpec":
        """The paper's five-site OSG deployment (Fig. 2, §4.1)."""
        sites = [SiteSpec(name=n, workers=workers_per_site, profile=p,
                          eviction_policy=eviction_policy,
                          cache_replicas=cache_replicas)
                 for n, p in OSG_SITE_PROFILES.items()]
        return cls(sites=sites, origin_site="chicago",
                   monitor_drop_rate=monitor_drop_rate)

    @classmethod
    def fleet(cls, num_pods: int = 2, hosts_per_pod: int = 64,
              cache_capacity: float = 32 * TB,
              monitor_drop_rate: float = 0.0,
              eviction_policy: str = "lru", cache_replicas: int = 1,
              ttl_seconds: float = 3600.0,
              admission_max_fraction: float = 1.0) -> "FederationSpec":
        """TPU-fleet mapping: one cache group per pod, origin = dataset
        store.  Intra-pod links are ICI-class, cross-pod is DCN-class,
        the origin sits behind a storage-fabric link; GeoIP lookup
        latency is LAN-scale."""
        prof = BandwidthProfile(worker_nic=25e9, cache_nic=100e9,
                                proxy_nic=25e9, origin_nic=40e9,
                                site_uplink=50e9, wan_rtt=0.002,
                                lan_rtt=0.0002)
        sites = [SiteSpec(name=f"pod{p}", workers=hosts_per_pod,
                          cache_capacity=cache_capacity, profile=prof,
                          eviction_policy=eviction_policy,
                          cache_replicas=cache_replicas,
                          ttl_seconds=ttl_seconds,
                          admission_max_fraction=admission_max_fraction)
                 for p in range(num_pods)]
        sites.append(SiteSpec(name="storage", workers=0, has_cache=False,
                              has_proxy=False, profile=prof))
        return cls(sites=sites, origin_site="storage",
                   monitor_drop_rate=monitor_drop_rate,
                   geoip_lookup_latency=0.002)

    @classmethod
    def osdf(cls, regions: Sequence[str] = ("us-east", "us-west"),
             edges_per_region: int = 2, workers_per_edge: int = 4,
             l1_capacity: float = 2 * TB, l2_capacity: float = 16 * TB,
             eviction_policy: str = "lru", cache_replicas: int = 1,
             backbone_replicas: int = 1,
             origin_region: Optional[str] = None,
             monitor_drop_rate: float = 0.0) -> "FederationSpec":
        """OSDF-style tiered CDN (arXiv:2007.01408): per region,
        ``edges_per_region`` L1 edge sites fill from one regional L2
        backbone site; backbone misses pull from the origin over the
        continental backbone.  Edge sites hold workers; backbone sites
        are pure caches (workers=0) with the larger capacity.  The
        origin facility sits in ``origin_region`` (first region by
        default), so same-region backbones reach it over the regional
        network and remote ones over a backbone segment."""
        sites: List[SiteSpec] = []
        for r in regions:
            backbone = SiteSpec(name=f"{r}-backbone", workers=0,
                                has_proxy=False, region=r,
                                cache_capacity=l2_capacity,
                                cache_replicas=backbone_replicas,
                                eviction_policy=eviction_policy)
            tier = TierSpec(parent=backbone.name, sites=[
                SiteSpec(name=f"{r}-edge{i}", workers=workers_per_edge,
                         has_proxy=False, region=r,
                         cache_capacity=l1_capacity,
                         cache_replicas=cache_replicas,
                         eviction_policy=eviction_policy)
                for i in range(edges_per_region)])
            sites.extend(tier.flatten())
            sites.append(backbone)
        sites.append(SiteSpec(name="origin-facility", workers=0,
                              has_cache=False, has_proxy=False,
                              region=origin_region or regions[0]))
        return cls(sites=sites, origin_site="origin-facility",
                   monitor_drop_rate=monitor_drop_rate)


# Paper Fig. 2 deployment: the five test sites of §4.1 with bandwidth
# profiles calibrated to reproduce Table 3's signs (see bench docs).
# Profiles calibrated so the simulator reproduces Table 3's signs; the
# mechanisms are the paper's own observations: per-site proxy/cache NIC
# asymmetries (Fig. 6: Colorado prioritises proxy↔WAN bandwidth; its
# workers see far less bandwidth to the nearest — remote — StashCache
# cache) and disk-bound large-object serving ("proxies are optimized for
# small files").  cache_nic abstracts the worker→nearest-cache path, which
# for cache-less sites (Colorado, Bellarmine) is a remote Internet2 PoP.
OSG_SITE_PROFILES: Dict[str, BandwidthProfile] = {
    "colorado": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.16e9,
                                 proxy_nic=5.0e9, site_uplink=12.5e9,
                                 proxy_disk_bw=2.5e9),
    "syracuse": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.55e9,
                                 proxy_nic=1.25e9, site_uplink=12.5e9,
                                 proxy_disk_bw=0.6e9),
    "bellarmine": BandwidthProfile(worker_nic=1.25e9, cache_nic=1.25e9,
                                   proxy_nic=0.3e9, site_uplink=1.25e9,
                                   cache_disk_bw=0.17e9),
    "nebraska": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.6e9,
                                 proxy_nic=1.0e9, site_uplink=12.5e9,
                                 proxy_disk_bw=0.9e9, cache_disk_bw=0.5e9),
    "chicago": BandwidthProfile(worker_nic=1.25e9, cache_nic=0.8e9,
                                proxy_nic=1.4e9, site_uplink=12.5e9,
                                proxy_disk_bw=0.8e9),
}


def build_osg_federation(workers_per_site: int = 4,
                         monitor_drop_rate: float = 0.0,
                         eviction_policy: str = "lru",
                         cache_replicas: int = 1) -> Federation:
    return FederationSpec.osg(
        workers_per_site=workers_per_site,
        monitor_drop_rate=monitor_drop_rate,
        eviction_policy=eviction_policy,
        cache_replicas=cache_replicas).build()


def build_fleet_federation(num_pods: int = 2, hosts_per_pod: int = 64,
                           cache_capacity: float = 32 * TB,
                           monitor_drop_rate: float = 0.0,
                           eviction_policy: str = "lru",
                           cache_replicas: int = 1,
                           ttl_seconds: float = 3600.0,
                           admission_max_fraction: float = 1.0) -> Federation:
    """TPU-fleet mapping: one cache group per pod, origin = dataset store.

    Intra-pod links are ICI-class, cross-pod is DCN-class, the origin sits
    behind a storage-fabric link.  GeoIP lookup latency is LAN-scale.
    ``cache_replicas`` > 1 gives each pod an HA consistent-hash cache
    group; ``eviction_policy`` selects the per-cache policy fleet-wide.
    """
    return FederationSpec.fleet(
        num_pods=num_pods, hosts_per_pod=hosts_per_pod,
        cache_capacity=cache_capacity,
        monitor_drop_rate=monitor_drop_rate,
        eviction_policy=eviction_policy, cache_replicas=cache_replicas,
        ttl_seconds=ttl_seconds,
        admission_max_fraction=admission_max_fraction).build()


def build_osdf_federation(regions: Sequence[str] = ("us-east", "us-west"),
                          edges_per_region: int = 2,
                          workers_per_edge: int = 4,
                          l1_capacity: float = 2 * TB,
                          l2_capacity: float = 16 * TB,
                          eviction_policy: str = "lru") -> Federation:
    """Tiered OSDF-style CDN: regional L1 edges over L2 backbones."""
    return FederationSpec.osdf(
        regions=regions, edges_per_region=edges_per_region,
        workers_per_edge=workers_per_edge, l1_capacity=l1_capacity,
        l2_capacity=l2_capacity, eviction_policy=eviction_policy).build()
