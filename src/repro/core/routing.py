"""Pluggable cache-ranking policies (latency-aware routing).

The paper's clients pick a cache by *static* GeoIP distance (§3.1).  The
CDN follow-on (arXiv:2007.01408) replaced that with latency-driven
selection: clients probe the caches they use and re-rank when one starts
failing or slowing down — static distance is only the prior.  This module
makes the ranking a policy object so both client surfaces
(:class:`~repro.core.client.StashClient` and
:class:`~repro.core.simclient.SimStashClient`) share one implementation:

* :class:`StaticRankingPolicy` — the paper's behaviour, byte-identical
  to the historical inline ranking (GeoIP distance with the
  deterministic ``(distance, name)`` tie-break).
* :class:`ProbeRankingPolicy` — per-cache latency EWMAs self-calibrated
  against each cache's first observation, with multiplicative failure
  penalties that decay on success.  A cache that dies (or degrades)
  sinks in the ranking after a few failures and climbs back as probes
  succeed again — re-ranking under churn without a control plane.

``ranked_caches`` is the one ranking pipeline: groups ordered by the
policy over their ring loci, members in consistent-hash ring order
within a group, stray (ungrouped) caches policy-ranked at the tail.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .cache import CacheServer
    from .ring import CacheGroup
    from .topology import GeoIPService


class RankingPolicy:
    """Orders candidate cache *names* for one client.

    ``order`` must be a total, deterministic order.  ``observe`` /
    ``on_failure`` are the probe feedback hooks; the static policy
    ignores them (which is what makes static rankings vectorizable in
    the batched sweep executor — they never depend on history).
    """

    name = "static"

    def order(self, client: str, names: Sequence[str],
              geoip: "GeoIPService",
              exclude: Sequence[str] = ()) -> List[str]:
        return geoip.nearest(client, names, exclude=exclude)

    def observe(self, cache_name: str, seconds: float) -> None:
        pass

    def on_failure(self, cache_name: str) -> None:
        pass


class StaticRankingPolicy(RankingPolicy):
    """Static GeoIP-distance ranking — the paper's client behaviour."""


class ProbeRankingPolicy(RankingPolicy):
    """Latency-probe ranking: static distance as prior, re-ranked by
    observed behaviour.

    Each cache's score is ``penalty × (ewma / base)`` where ``base`` is
    the first latency this client observed from the cache (so scores are
    relative slowdowns, comparable across caches serving different
    object mixes) and ``penalty`` multiplies by ``failure_penalty`` per
    failure and decays by ``recovery`` per subsequent success.  Unprobed
    caches score 1.0 and keep their static rank — the policy only
    *re-ranks* on evidence.
    """

    name = "probe"

    def __init__(self, alpha: float = 0.3, failure_penalty: float = 8.0,
                 recovery: float = 0.5) -> None:
        self.alpha = alpha
        self.failure_penalty = failure_penalty
        self.recovery = recovery
        self.ewma: Dict[str, float] = {}
        self.base: Dict[str, float] = {}
        self.penalty: Dict[str, float] = {}

    def score(self, name: str) -> float:
        base = self.base.get(name)
        rel = (self.ewma[name] / base) if base else 1.0
        return self.penalty.get(name, 1.0) * rel

    def order(self, client: str, names: Sequence[str],
              geoip: "GeoIPService",
              exclude: Sequence[str] = ()) -> List[str]:
        static = geoip.nearest(client, names, exclude=exclude)
        rank = {n: i for i, n in enumerate(static)}
        return sorted(static, key=lambda n: (self.score(n), rank[n]))

    def observe(self, cache_name: str, seconds: float) -> None:
        if seconds <= 0:
            return
        if cache_name not in self.base:
            self.base[cache_name] = seconds
            self.ewma[cache_name] = seconds
        else:
            self.ewma[cache_name] = (self.alpha * seconds
                                     + (1 - self.alpha) * self.ewma[cache_name])
        p = self.penalty.get(cache_name, 1.0)
        if p > 1.0:
            self.penalty[cache_name] = max(1.0, p * self.recovery)

    def on_failure(self, cache_name: str) -> None:
        self.penalty[cache_name] = min(
            self.penalty.get(cache_name, 1.0) * self.failure_penalty, 1e9)


RANKING_POLICIES = {"static": StaticRankingPolicy, "probe": ProbeRankingPolicy}


def make_ranking_policy(spec: Union[str, RankingPolicy, None]
                        ) -> RankingPolicy:
    if spec is None:
        return StaticRankingPolicy()
    if isinstance(spec, RankingPolicy):
        return spec
    try:
        return RANKING_POLICIES[spec]()
    except KeyError:
        raise ValueError(f"unknown ranking policy {spec!r}; "
                         f"expected one of {sorted(RANKING_POLICIES)}")


def ranked_caches(client: str, caches: Dict[str, "CacheServer"],
                  groups: Sequence["CacheGroup"], geoip: "GeoIPService",
                  policy: Optional[RankingPolicy] = None,
                  path: Optional[str] = None,
                  exclude: Sequence[str] = (),
                  limit: Optional[int] = None,
                  count_stats: bool = True) -> List["CacheServer"]:
    """Cache servers in preference order for ``path``.

    Without HA groups this is the pure policy order.  With groups, the
    *groups* are ranked (by their ring loci) and each contributes its
    members in consistent-hash ring order for the path — so a given
    object always lands on the same member of the nearest group, and a
    dead member degrades to the next ring member instead of straight to
    the origin.  Stray (ungrouped) caches participate policy-ranked at
    the tail.

    ``limit`` truncates the failover tail: a fleet-scale ranking over
    1000+ single-member groups otherwise walks every group's ring per
    request even though only the first few entries are ever tried.
    ``count_stats=False`` makes the ranking a pure query (convenience
    lookups like ``Federation.nearest_cache`` must not inflate the
    serving group's route/failover counters).
    """
    policy = policy or StaticRankingPolicy()
    if groups and path is not None:
        locus = {g.name: g.locus().name for g in groups
                 if g.locus() is not None}
        order = policy.order(client, list(locus.values()), geoip)
        by_locus = {locus[g.name]: g for g in groups if g.name in locus}
        ranked: List["CacheServer"] = []
        for locus_name in order:
            if limit is not None and len(ranked) >= limit:
                return ranked[:limit]
            # only the group that heads the ranking is actually being
            # routed to; the rest are its fleet-wide failover tail.
            members = by_locus[locus_name].route(
                path, exclude=exclude,
                count_stats=count_stats and not ranked)
            ranked.extend(members)
        # stray caches not in any group still participate, policy-ranked.
        grouped = {c.name for g in groups for c in g.members}
        stray = [n for n in caches if n not in grouped and n not in exclude]
        if stray:
            for n in policy.order(client, stray, geoip):
                ranked.append(caches[n])
        return ranked[:limit] if limit is not None else ranked
    order = policy.order(client, list(caches), geoip, exclude=exclude)
    ranked = [caches[n] for n in order]
    return ranked[:limit] if limit is not None else ranked
