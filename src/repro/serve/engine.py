"""Batched serving engine: prefill → decode with per-slot request states.

A deliberately small but real continuous-batching-lite engine:
  * requests queue up; a batch slot is freed when its request finishes
    (EOS or max tokens) and the next queued request is prefilled into it;
  * prefill uses :func:`forward_with_cache` (one pass, cache populated);
  * decode advances all active slots one token per step with the shared
    ``decode_step`` (ring-buffer KV for windowed layers);
  * model weights are *distributed to serving hosts through the
    federation's data plane* (:meth:`ServeEngine.from_federation`, weight
    shards via :meth:`ServeEngine.fetch_shard`) — weight distribution is
    a large-file problem, exactly the regime where the paper shows
    StashCache beats HTTP proxies.  Every fetch folds into
    ``engine.data_stats`` (the unified
    :class:`~repro.core.monitoring.FetchRollup`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.api import DataPlane, FetchRequest, FetchResult
from ..core.monitoring import FetchRollup
from ..models import decode_step, forward_with_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,)
    max_new_tokens: int = 16
    eos_id: int = -1                     # -1 → never stops early
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0


class ServeEngine:
    """Static-batch engine with slot recycling (continuous-batching-lite)."""

    def __init__(self, cfg: ArchConfig, params, batch_size: int = 4,
                 max_seq: int = 256, greedy: bool = True,
                 seed: int = 0, plane: Optional[DataPlane] = None,
                 site: str = "", worker: int = 0) -> None:
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self.plane = plane
        self.site = site
        self.worker = worker
        self.data_stats = FetchRollup("serve")
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))

    # -- federation weight path ----------------------------------------
    @classmethod
    def from_federation(cls, cfg: ArchConfig, plane: DataPlane, run: str,
                        step: Optional[int] = None, *, site: str = "",
                        worker: int = 0, like=None,
                        **engine_kw) -> "ServeEngine":
        """Build an engine whose weights arrive through the data plane:
        restore the newest (or given) checkpoint of ``run`` via the
        federation's cache tier and account the fetches on
        ``engine.data_stats``.  ``like`` is the parameter-tree template;
        omitted, a fresh :func:`~repro.models.init_lm` tree is used."""
        from ..train.checkpoint import FederatedCheckpointer
        ck = FederatedCheckpointer(run, plane, site=site, worker=worker)
        if step is None:
            step = ck.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint for run {run!r}")
        if like is None:
            from ..models import init_lm
            # Template init must track the engine's own seed: a
            # hard-coded PRNGKey(0) here meant two engines built with
            # different seeds silently shared init weights whenever the
            # checkpoint restore fell back to the template values.
            like, _ = init_lm(
                jax.random.PRNGKey(engine_kw.get("seed", 0)), cfg)
        params, _ = ck.restore(step, like=like)
        eng = cls(cfg, params, plane=plane, site=site, worker=worker,
                  **engine_kw)
        eng.data_stats.merge(ck.stats)
        return eng

    def fetch_shard(self, path: str, method: str = "stash") -> FetchResult:
        """Pull one weight/KV shard object through the data plane (the
        serving-traffic read path — Zipf-popular shard objects under
        ``/models/<name>``)."""
        if self.plane is None:
            raise RuntimeError("engine was built without a data plane")
        res = self.plane.fetch(FetchRequest(
            path=path, site=self.site, worker=self.worker, method=method,
            tenant="serving"))
        self.data_stats.add(res)
        return res

    # ------------------------------------------------------------------
    def _prefill_batch(self, prompts: np.ndarray):
        """prompts: (B, P) — one shared prompt length per wave."""
        logits, cache, _ = forward_with_cache(
            self.params, jnp.asarray(prompts), self.cfg,
            max_seq=self.max_seq)
        self.stats.prefills += prompts.shape[0]
        return logits[:, -1, :], cache

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(sub, logits))

    # ------------------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in waves of ``batch`` slots."""
        queue = list(requests)
        while queue:
            wave = queue[:self.batch]
            queue = queue[len(wave):]
            plen = max(len(r.prompt) for r in wave)
            prompts = np.stack([
                np.pad(r.prompt, (plen - len(r.prompt), 0))
                for r in wave])                      # left-pad to align
            if len(wave) < self.batch:               # pad slots
                prompts = np.pad(prompts,
                                 ((0, self.batch - len(wave)), (0, 0)))
            last_logits, cache = self._prefill_batch(prompts)
            tok = self._sample(last_logits)
            for i, r in enumerate(wave):
                r.output.append(int(tok[i]))
            steps = max(r.max_new_tokens for r in wave) - 1
            pos = plen
            for _ in range(max(steps, 0)):
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(tok, jnp.int32),
                    jnp.int32(pos))
                self.stats.decode_steps += 1
                tok = self._sample(logits)
                pos += 1
                alive = False
                for i, r in enumerate(wave):
                    if r.done or len(r.output) >= r.max_new_tokens:
                        r.done = True
                        continue
                    t = int(tok[i])
                    r.output.append(t)
                    self.stats.tokens_out += 1
                    if t == r.eos_id:
                        r.done = True
                    else:
                        alive = True
                if not alive:
                    break
            for r in wave:
                r.done = True
        return requests
