"""Batched serving engine: prefill → decode with per-slot request states.

A deliberately small but real continuous-batching-lite engine:
  * requests queue up; a batch slot is freed when its request finishes
    (EOS or max tokens) and the next queued request is prefilled into it;
  * prefill uses :func:`forward_with_cache` (one pass, cache populated);
  * decode advances all active slots one token per step with the shared
    ``decode_step`` (ring-buffer KV for windowed layers);
  * model weights can be *distributed to serving hosts through the
    federation* (see ``examples/serve_lm.py``) — weight distribution is a
    large-file problem, exactly the regime where the paper shows StashCache
    beats HTTP proxies.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import decode_step, forward_with_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,)
    max_new_tokens: int = 16
    eos_id: int = -1                     # -1 → never stops early
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0


class ServeEngine:
    """Static-batch engine with slot recycling (continuous-batching-lite)."""

    def __init__(self, cfg: ArchConfig, params, batch_size: int = 4,
                 max_seq: int = 256, greedy: bool = True,
                 seed: int = 0) -> None:
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))

    # ------------------------------------------------------------------
    def _prefill_batch(self, prompts: np.ndarray):
        """prompts: (B, P) — one shared prompt length per wave."""
        logits, cache, _ = forward_with_cache(
            self.params, jnp.asarray(prompts), self.cfg,
            max_seq=self.max_seq)
        self.stats.prefills += prompts.shape[0]
        return logits[:, -1, :], cache

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(sub, logits))

    # ------------------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in waves of ``batch`` slots."""
        queue = list(requests)
        while queue:
            wave = queue[:self.batch]
            queue = queue[len(wave):]
            plen = max(len(r.prompt) for r in wave)
            prompts = np.stack([
                np.pad(r.prompt, (plen - len(r.prompt), 0))
                for r in wave])                      # left-pad to align
            if len(wave) < self.batch:               # pad slots
                prompts = np.pad(prompts,
                                 ((0, self.batch - len(wave)), (0, 0)))
            last_logits, cache = self._prefill_batch(prompts)
            tok = self._sample(last_logits)
            for i, r in enumerate(wave):
                r.output.append(int(tok[i]))
            steps = max(r.max_new_tokens for r in wave) - 1
            pos = plen
            for _ in range(max(steps, 0)):
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(tok, jnp.int32),
                    jnp.int32(pos))
                self.stats.decode_steps += 1
                tok = self._sample(logits)
                pos += 1
                alive = False
                for i, r in enumerate(wave):
                    if r.done or len(r.output) >= r.max_new_tokens:
                        r.done = True
                        continue
                    t = int(tok[i])
                    r.output.append(t)
                    self.stats.tokens_out += 1
                    if t == r.eos_id:
                        r.done = True
                    else:
                        alive = True
                if not alive:
                    break
            for r in wave:
                r.done = True
        return requests
