"""Backend-dispatching wrappers: Pallas on TPU, jnp oracle elsewhere.

Model code calls these; the dry-run (CPU backend, 512 fake host devices)
and CPU tests automatically take the jnp path, real TPUs take the kernel.
Set ``FORCE_INTERPRET=True`` (tests do) to run the kernel bodies in
interpret mode on CPU for correctness validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .chunk_checksum import chunk_checksum as _checksum_pallas
from .flash_attention import flash_attention as _flash_pallas
from .maxmin import maxmin_rates as _maxmin_vector
from .ssd_scan import ssd_intra as _ssd_pallas

FORCE_INTERPRET = False


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    if _on_tpu() or FORCE_INTERPRET:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             softcap=softcap,
                             interpret=not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)


def chunk_checksum(data, block: int = 1024):
    if _on_tpu() or FORCE_INTERPRET:
        return _checksum_pallas(data, block, interpret=not _on_tpu())
    return ref.poly_digest_ref(data, block)[0]


def maxmin_rates(link_caps, membership, flow_caps):
    """Batched max-min fair-share waterfilling (fluid-flow simulator).

    Always the vectorized jnp path — it is array ops, not a TPU kernel —
    with ``ref.maxmin_ref`` as the scalar ground truth for tests.
    """
    return _maxmin_vector(link_caps, membership, flow_caps)


def ssd_intra(x, dt, cum, b_in, c_in):
    if _on_tpu() or FORCE_INTERPRET:
        return _ssd_pallas(x, dt, cum, b_in, c_in,
                           interpret=not _on_tpu())
    return ref.ssd_intra_ref(x, dt, cum, b_in, c_in)
