"""Mamba2 SSD intra-chunk kernel (Pallas TPU).

The quadratic half of the SSD decomposition (DESIGN.md §6): for each
(batch, chunk, head) tile, compute

    Y[i] = Σ_{j≤i} (C_i·B_j) · exp(cum_i − cum_j) · Δ_j · x_j

as two MXU matmuls ((Q×N)@(N×Q) scores, masked-decay weighting, then
(Q×Q)@(Q×P)) entirely in VMEM — the systolic-array port of the CUDA
chunk-scan in the Mamba2 reference.  The O(L/Q) inter-chunk recurrence
stays a lax.scan (tiny state, latency-bound, not kernel-worthy).

Oracle: ``repro.kernels.ref.ssd_intra_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32)       # (Q,)
    bb = b_ref[0, 0, :, :].astype(jnp.float32)          # (Q, N)
    cc = c_ref[0, 0, :, :].astype(jnp.float32)          # (Q, N)
    q = x.shape[0]
    scores = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(cols <= rows, scores * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_intra(x: jax.Array, dt: jax.Array, cum: jax.Array,
              b_in: jax.Array, c_in: jax.Array,
              interpret: bool = False) -> jax.Array:
    """Intra-chunk SSD output.

    x: (B, NC, Q, H, P); dt, cum: (B, NC, Q, H); b_in, c_in: (B, NC, Q, N)
    → (B, NC, Q, H, P)
    """
    bsz, nc, q, h, p = x.shape
    n = b_in.shape[-1]
    grid = (bsz, nc, h)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1),
                         lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1),
                         lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, 1, p),
                               lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, dt, cum, b_in, c_in)
