"""Eviction-aware cache modelling kernels: Mattson stack distances and a
vectorized single-capacity LRU/FIFO state machine.

The sweep executor (:func:`repro.core.api.run_sweep`) resolves every
batched cell's hit/miss pattern without ever *running* a cache.  For
evicting caches that takes one of two kernels, both jitted and bucketed
to power-of-two shapes like :mod:`repro.kernels.batched_maxmin`:

* :func:`stack_distances_batch` — the Mattson / reuse-distance kernel.
  LRU with byte-granular ``evict_until`` satisfies the *inclusion
  property*: at any instant the resident set is the maximal prefix of
  the recency stack whose cumulative bytes fit the capacity (eviction
  removes from the stack bottom until the insert fits, so the prefix
  stays maximal).  A reference to key ``k`` therefore hits at capacity
  ``C`` iff ``D + size(k) <= C`` where ``D`` is the *byte-weighted stack
  distance*: the total size of distinct keys touched since the previous
  reference to ``k``.  One pass over a request stream prices **every**
  capacity in a sweep column — the distances are capacity-independent;
  each cell only compares them against its own ``C``.

* :func:`cache_sim_batch` — an exact single-capacity LRU/FIFO replay
  for the cells the stack model cannot express: size-aware admission
  (a refused chunk is served but never inserted, yet a still-resident
  copy admitted *earlier* keeps hitting — the filter applies on miss,
  not on lookup), FIFO victim order (not a stack algorithm), and
  payloads larger than the whole cache.  Each reference carries a
  precomputed ``admit`` bit; eviction picks resident keys in ascending
  priority (last-access counter for LRU, admit counter for FIFO) until
  the insert fits, via an in-step sort + exclusive cumulative sum.

Cold restarts appear in both kernels as stream markers: a reset wipes
residency without counting evictions (the disk came back empty; nothing
was *chosen* as a victim), mirroring ``CacheServer.clear``.

Byte counters must be exact — a one-byte error flips an eviction
decision and breaks the sweep's cell-exact parity guarantee — so both
kernels run in float64 under a scoped :func:`jax.experimental.
enable_x64` (integers up to 2**53 are exact, far above any capacity the
federation models).  ``tests/test_stack_distance.py`` holds both
kernels byte-equal to a scalar :class:`~repro.core.cache.CacheServer`
oracle replay.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .maxmin import _next_pow2

# Bucket floors: streams shorter than these pad up so a sweep's ragged
# stream/key counts land in very few shapes — each (N, K) shape is one
# jit compile, and compile time dominates runtime for these scans.
_FLOOR_N = 256
_FLOOR_K = 64

# One stack-distance problem: (prev, sizes) — per-reference index of the
# previous reference to the same key within the same cold-restart
# segment (-1: none → compulsory miss), and per-reference chunk bytes.
DistanceProblem = Tuple[Sequence[int], Sequence[float]]

# One state-machine problem:
#   (keys, admit, reset, key_sizes, capacity, fifo)
# keys: (N,) int key ids; admit: (N,) bool (miss-path insert allowed —
# admission policy AND capacity refusal, precomputed); reset: (N,) bool
# (cold restart applied before this reference); key_sizes: (K,) bytes
# per key id; capacity: bytes; fifo: True → insertion-order victims.
SimProblem = Tuple[Sequence[int], Sequence[bool], Sequence[bool],
                   Sequence[float], float, bool]


def _distances(prev: jax.Array, sizes: jax.Array) -> jax.Array:
    """Byte-weighted stack distances for one reference stream.

    Scan-carried *marker* array: position ``j`` holds ``sizes[j]`` while
    ``j`` is the most recent reference to its key, else 0.  The distance
    of reference ``i`` is the sum of markers strictly between its
    previous occurrence and ``i`` — markers at or after ``i`` are still
    zero, markers of dead occurrences were zeroed when superseded.
    Compulsory misses (``prev < 0``, including every first reference
    after a cold restart) return ``inf``.
    """
    n = prev.shape[0]
    idx = jnp.arange(n)

    def step(markers, x):
        p, s, i = x
        d = jnp.where(idx > p, markers, 0.0).sum()
        markers = markers.at[jnp.where(p >= 0, p, i)].set(0.0)
        markers = markers.at[i].set(s)
        return markers, jnp.where(p >= 0, d, jnp.inf)

    _, out = jax.lax.scan(step, jnp.zeros(n, sizes.dtype),
                          (prev, sizes, idx))
    return out


def _simulate(keys: jax.Array, admit: jax.Array, reset: jax.Array,
              key_sizes: jax.Array, capacity: jax.Array,
              fifo: jax.Array):
    """Exact LRU/FIFO replay of one stream at one capacity.

    Mirrors :meth:`CacheServer.admit`/``evict_until`` byte for byte:
    a hit touches (LRU) or leaves (FIFO) the key's priority; an
    admitted miss evicts resident keys in ascending priority while the
    bytes freed so far are short of ``usage + size - capacity``, then
    inserts.  Returns ``(hits, evictions, bytes_evicted)``.

    Victim order is kept in *priority slots*: slot ``t`` is written
    only at step ``t``, so slot order IS policy order — an LRU touch
    vacates the key's old slot and occupies slot ``t``, a FIFO hit
    keeps its admit slot.  Eviction is then a prefix of the occupied
    slots (exclusive cumulative bytes short of the need), one O(N)
    cumsum per step instead of a sort or an O(K²) rank comparison —
    both of which are catastrophic inside a vmapped scan.
    """
    K = key_sizes.shape[0]
    n = keys.shape[0]

    def step(carry, x):
        slot_bytes, slot_key, resident, key_slot, usage, ev, evb = carry
        k, a, r, t = x
        slot_bytes = jnp.where(r, 0.0, slot_bytes)
        resident = jnp.where(r, False, resident)
        usage = jnp.where(r, 0.0, usage)
        s = key_sizes[k]
        hit = resident[k]
        do_insert = jnp.logical_and(~hit, a)
        need = jnp.where(do_insert, usage + s - capacity, 0.0)
        excl = jnp.cumsum(slot_bytes) - slot_bytes
        evict_slot = (slot_bytes > 0) & (excl < need)
        freed = jnp.where(evict_slot, slot_bytes, 0.0).sum()
        # scatter-max: stale slot_key duplicates carry zero bytes, so
        # their evict_slot is False and the max is order-independent
        gone = jnp.zeros(K, bool).at[slot_key].max(evict_slot)
        resident = resident & ~gone
        slot_bytes = jnp.where(evict_slot, 0.0, slot_bytes)
        usage = usage - freed
        # occupy slot t on admit or LRU touch; vacate the old slot on
        # touch (an evicted key's old slot is already zero)
        touch = do_insert | (hit & ~fifo)
        old = key_slot[k]
        slot_bytes = slot_bytes.at[old].set(
            jnp.where(hit & touch, 0.0, slot_bytes[old]))
        slot_bytes = slot_bytes.at[t].set(jnp.where(touch, s, 0.0))
        slot_key = slot_key.at[t].set(k)
        key_slot = key_slot.at[k].set(jnp.where(touch, t, old))
        resident = resident.at[k].set(hit | do_insert)
        usage = usage + jnp.where(do_insert, s, 0.0)
        return (slot_bytes, slot_key, resident, key_slot, usage,
                ev + evict_slot.sum().astype(jnp.int32), evb + freed), hit

    carry0 = (jnp.zeros(n, key_sizes.dtype), jnp.zeros(n, jnp.int32),
              jnp.zeros(K, bool), jnp.zeros(K, jnp.int32),
              jnp.asarray(0.0, key_sizes.dtype),
              jnp.asarray(0, jnp.int32), jnp.asarray(0.0, key_sizes.dtype))
    (_, _, _, _, _, ev, evb), hits = jax.lax.scan(
        step, carry0, (keys, admit, reset, jnp.arange(n, dtype=jnp.int32)))
    return hits, ev, evb


def _fifo_replay(keys: jax.Array, sizes: jax.Array, admit: jax.Array,
                 reset: jax.Array, kcum0: jax.Array,
                 capacity: jax.Array):
    """Exact FIFO replay in O(N log N): eviction only ever consumes a
    *prefix* of the admit sequence (hits never touch, re-admits get new
    slots), so the whole cache reduces to a moving byte frontier ``E``
    over the cumulative-admitted-bytes curve.  A key is resident iff
    the cumulative total at its latest admit exceeds ``E``; evicting
    for an insert is one ``searchsorted`` — no per-step cumsum, no
    sort.  Returns ``(hits, evictions, bytes_evicted)``.

    ``kcum0`` is a zeros(K) scratch fixing the per-key state width.
    """
    n = keys.shape[0]
    big = jnp.inf

    def step(carry, x):
        cumB, cumN, kcum, total, totN, E, EN, ev, evb = carry
        k, s, a, r, t = x
        # cold restart: everything already admitted is gone, uncounted
        E = jnp.where(r, total, E)
        EN = jnp.where(r, totN, EN)
        hit = kcum[k] > E
        ins = jnp.logical_and(~hit, a)
        # evict the minimal admit-prefix putting resident + s under cap
        # (ins implies s <= capacity: the host folds the oversize
        # refusal into the admit bit)
        target = total + s - capacity
        do_evict = ins & (target > E)
        j = jnp.searchsorted(cumB, target)
        newE = jnp.where(do_evict, cumB[j], E)
        newN = jnp.where(do_evict, cumN[j], EN)
        ev = ev + (newN - EN)
        evb = evb + (newE - E)
        E, EN = newE, newN
        total = total + jnp.where(ins, s, 0.0)
        totN = totN + ins.astype(jnp.int32)
        cumB = cumB.at[t].set(total)     # flat where not inserted
        cumN = cumN.at[t].set(totN)
        kcum = kcum.at[k].set(jnp.where(ins, total, kcum[k]))
        return (cumB, cumN, kcum, total, totN, E, EN, ev, evb), hit

    zero = jnp.asarray(0.0, sizes.dtype)
    carry0 = (jnp.full(n, big, sizes.dtype), jnp.zeros(n, jnp.int32),
              kcum0, zero, jnp.asarray(0, jnp.int32), zero,
              jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32), zero)
    (_, _, _, _, _, _, _, ev, evb), hits = jax.lax.scan(
        step, carry0, (keys, sizes, admit, reset,
                       jnp.arange(n, dtype=jnp.int32)))
    return hits, ev, evb


_dist_batch = jax.jit(jax.vmap(_distances))
_sim_batch = jax.jit(jax.vmap(_simulate))
_fifo_batch = jax.jit(jax.vmap(_fifo_replay))


def _note(stats: Optional[Dict], bucket: Tuple[int, ...], pad: int) -> None:
    if stats is not None:
        stats["solve_calls"] += 1
        stats["buckets"].append(bucket)
        stats["padded_problems"] += pad


def _init_stats(stats: Optional[Dict], n: int) -> None:
    if stats is not None:
        stats.update(solve_calls=0, buckets=[], problems=n,
                     padded_problems=0)


def stack_distances_batch(problems: Sequence[DistanceProblem],
                          stats: Optional[Dict] = None) -> List[np.ndarray]:
    """Byte-weighted stack distances for many streams in few jitted calls.

    Streams are padded to power-of-two lengths and same-bucket streams
    stacked (batch padded to a power of two with empty streams), one
    ``jax.jit(jax.vmap(...))`` call per bucket — the JIT cache sees
    O(log) shapes for a whole sweep.  Returns one ``(N_i,)`` float64
    array per problem, ``inf`` marking compulsory misses.
    """
    _init_stats(stats, len(problems))
    out: List[Optional[np.ndarray]] = [None] * len(problems)
    by_bucket: Dict[int, List[int]] = {}
    for i, (prev, _) in enumerate(problems):
        by_bucket.setdefault(_next_pow2(max(len(prev), 1), floor=_FLOOR_N),
                             []).append(i)
    with enable_x64():
        for Np, idxs in sorted(by_bucket.items()):
            B = _next_pow2(len(idxs), floor=1)
            prevs = np.full((B, Np), -1, np.int64)
            sizes = np.zeros((B, Np), np.float64)
            for bi, i in enumerate(idxs):
                p, s = problems[i]
                prevs[bi, :len(p)] = p
                sizes[bi, :len(s)] = s
            dists = np.asarray(_dist_batch(prevs, sizes))
            _note(stats, (B, Np), B - len(idxs))
            for bi, i in enumerate(idxs):
                out[i] = dists[bi, :len(problems[i][0])]
    return [r if r is not None else np.zeros(0) for r in out]


def lru_hits(distances: np.ndarray, ref_sizes: np.ndarray,
             capacity: float) -> np.ndarray:
    """Hit mask at one capacity from precomputed stack distances — the
    per-cell half of the one-pass-per-column contract."""
    return distances + ref_sizes <= capacity


# One FIFO problem: (keys, ref_sizes, admit, reset, n_keys, capacity).
FifoProblem = Tuple[Sequence[int], Sequence[float], Sequence[bool],
                    Sequence[bool], int, float]


def fifo_sim_batch(problems: Sequence[FifoProblem],
                   stats: Optional[Dict] = None
                   ) -> List[Tuple[np.ndarray, int, int]]:
    """Replay many FIFO (stream, capacity) problems in few jitted calls.

    Bucketed like :func:`cache_sim_batch`; capacity is vmapped data, so
    a whole capacity × admission column over one stream shares a device
    call.  Admission is a per-reference bit (refusals — policy or
    oversize — simply never insert), so time-varying filters cost
    nothing here, unlike the LRU stack model.
    """
    _init_stats(stats, len(problems))
    out: List[Optional[Tuple[np.ndarray, int, int]]] = [None] * len(problems)
    by_bucket: Dict[Tuple[int, int], List[int]] = {}
    for i, (keys, _, _, _, n_keys, _) in enumerate(problems):
        bucket = (_next_pow2(max(len(keys), 1), floor=_FLOOR_N),
                  _next_pow2(max(n_keys, 1), floor=_FLOOR_K))
        by_bucket.setdefault(bucket, []).append(i)
    with enable_x64():
        for (Np, Kp), idxs in sorted(by_bucket.items()):
            B = _next_pow2(len(idxs), floor=1)
            keys = np.zeros((B, Np), np.int32)
            sizes = np.zeros((B, Np), np.float64)
            admit = np.zeros((B, Np), bool)
            reset = np.zeros((B, Np), bool)
            kcum0 = np.zeros((B, Kp), np.float64)
            cap = np.full(B, np.inf, np.float64)
            for bi, i in enumerate(idxs):
                k, s, a, r, _, c = problems[i]
                keys[bi, :len(k)] = k
                sizes[bi, :len(s)] = s
                admit[bi, :len(a)] = a
                reset[bi, :len(r)] = r
                cap[bi] = c
            hits, ev, evb = (np.asarray(x) for x in
                             _fifo_batch(keys, sizes, admit, reset,
                                         kcum0, cap))
            _note(stats, (B, Np, Kp), B - len(idxs))
            for bi, i in enumerate(idxs):
                n = len(problems[i][0])
                out[i] = (hits[bi, :n], int(ev[bi]), int(round(evb[bi])))
    return [r if r is not None else (np.zeros(0, bool), 0, 0) for r in out]


def cache_sim_batch(problems: Sequence[SimProblem],
                    stats: Optional[Dict] = None
                    ) -> List[Tuple[np.ndarray, int, int]]:
    """Replay many (stream, capacity, policy) problems in few jitted
    calls.

    Problems are bucketed by padded ``(N, K)`` shape; capacity and the
    FIFO flag are vmapped *data*, so a whole capacity × policy ×
    admission sweep column over one stream shares a single bucket (and
    a single device call).  Returns ``(hits, evictions, bytes_evicted)``
    per problem, byte-exact against a scalar ``CacheServer`` replay.
    """
    _init_stats(stats, len(problems))
    out: List[Optional[Tuple[np.ndarray, int, int]]] = [None] * len(problems)
    by_bucket: Dict[Tuple[int, int], List[int]] = {}
    for i, (keys, _, _, key_sizes, _, _) in enumerate(problems):
        bucket = (_next_pow2(max(len(keys), 1), floor=_FLOOR_N),
                  _next_pow2(max(len(key_sizes), 1), floor=_FLOOR_K))
        by_bucket.setdefault(bucket, []).append(i)
    with enable_x64():
        for (Np, Kp), idxs in sorted(by_bucket.items()):
            B = _next_pow2(len(idxs), floor=1)
            keys = np.zeros((B, Np), np.int32)
            admit = np.zeros((B, Np), bool)
            reset = np.zeros((B, Np), bool)
            ksz = np.zeros((B, Kp), np.float64)
            cap = np.zeros(B, np.float64)
            fifo = np.zeros(B, bool)
            for bi, i in enumerate(idxs):
                k, a, r, s, c, f = problems[i]
                keys[bi, :len(k)] = k
                admit[bi, :len(a)] = a
                reset[bi, :len(r)] = r
                ksz[bi, :len(s)] = s
                cap[bi] = c
                fifo[bi] = f
            hits, ev, evb = (np.asarray(x) for x in
                             _sim_batch(keys, admit, reset, ksz, cap, fifo))
            _note(stats, (B, Np, Kp), B - len(idxs))
            for bi, i in enumerate(idxs):
                n = len(problems[i][0])
                out[i] = (hits[bi, :n], int(ev[bi]), int(round(evb[bi])))
    return [r if r is not None else (np.zeros(0, bool), 0, 0) for r in out]
