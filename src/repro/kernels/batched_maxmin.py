"""Batched max-min waterfilling across heterogeneous problems (vmap).

The sweep engine (:func:`repro.core.api.run_sweep`) prices link
contention for *hundreds* of scenarios per solve: every sweep cell
contributes one (flows, links) max-min problem — its storm-counterfactual
flow set — and all cells are solved together.  Calling
``maxmin_rates_sparse`` per cell would pay one JIT dispatch (and, for
each new shape, one compile) per scenario; this module instead

* pads each problem to a power-of-two ``(Fp, Lp, width)`` bucket with the
  same dummy-link layout as :func:`repro.kernels.maxmin.pad_problem`,
* groups same-bucket problems into a ``(B, ...)`` stack (B itself padded
  to a power of two with all-dummy problems), and
* runs one ``jax.jit(jax.vmap(solve_waterfill))`` call per bucket.

Because the waterfilling ``while_loop`` body is idempotent once a
problem's ``active`` mask empties, vmap's run-until-all-done semantics
leave early-converging problems untouched while stragglers finish —
heterogeneous (flows, links) shapes cost only their bucket's padding.
The JIT cache therefore sees O(log² ) distinct shapes, not one per cell,
and a 200-cell sweep column is priced by a handful of device calls
(``stats["solve_calls"]``), which is what the sweep benchmark and the CI
regression gate assert.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .maxmin import _next_pow2, pad_problem, solve_waterfill

# One problem: (link_caps, flow_links, flow_caps) in the same layout as
# maxmin_rates_sparse — per-flow rows of link indices, per-flow caps.
Problem = Tuple[Sequence[float], Sequence[Sequence[int]], Sequence[float]]

_solve_batch = jax.jit(jax.vmap(solve_waterfill))


def _bucket_of(problem: Problem) -> Tuple[int, int, int]:
    link_caps, flow_links, _ = problem
    width = _next_pow2(max((len(ls) for ls in flow_links), default=1),
                       floor=4)
    return (_next_pow2(len(flow_links)),
            _next_pow2(len(link_caps) + 1),
            width)


def maxmin_rates_batch(problems: Sequence[Problem],
                       stats: Optional[Dict] = None) -> List[np.ndarray]:
    """Solve many independent max-min problems in few jitted calls.

    Returns one ``(F_i,)`` rate array per input problem, in input order
    — each equal (up to float association) to what
    ``maxmin_rates_sparse`` returns for that problem alone, including
    the loopback fixup: flows crossing no capacity-bearing link get
    their own cap, not the padding rows' zero.

    ``stats``, when given, is filled with telemetry: ``solve_calls``
    (jitted batch invocations), ``buckets`` (``(B, Fp, Lp, width)`` per
    call), ``problems`` and ``padded_problems`` (all-dummy batch
    filler).  The sweep report surfaces these so benches can assert
    "one call priced the whole column".
    """
    if stats is not None:
        stats.update(solve_calls=0, buckets=[], problems=len(problems),
                     padded_problems=0)
    out: List[Optional[np.ndarray]] = [None] * len(problems)
    by_bucket: Dict[Tuple[int, int, int], List[int]] = {}
    for i, p in enumerate(problems):
        by_bucket.setdefault(_bucket_of(p), []).append(i)
    for (Fp, Lp, width), idxs in sorted(by_bucket.items()):
        B = _next_pow2(len(idxs), floor=1)
        caps = np.full((B, Lp), np.inf, np.float32)
        ids = np.full((B, Fp, width), Lp - 1, np.int32)
        fcaps = np.zeros((B, Fp), np.float32)
        for bi, i in enumerate(idxs):
            caps[bi], ids[bi], fcaps[bi] = pad_problem(
                *problems[i], Fp=Fp, Lp=Lp, width=width)
        rates = np.asarray(_solve_batch(caps, ids, fcaps))
        if stats is not None:
            stats["solve_calls"] += 1
            stats["buckets"].append((B, Fp, Lp, width))
            stats["padded_problems"] += B - len(idxs)
        for bi, i in enumerate(idxs):
            link_caps_i, flow_links_i, flow_caps_i = problems[i]
            res = rates[bi, :len(flow_links_i)].astype(np.float64)
            # Same loopback parity fixup as maxmin_rates_sparse: an
            # all-dummy row is indistinguishable from padding inside the
            # solve but is a real flow bound only by its own cap.
            for fi, ls in enumerate(flow_links_i):
                if not ls:
                    res[fi] = flow_caps_i[fi]
            out[i] = res
    return [r if r is not None else np.zeros(0) for r in out]
