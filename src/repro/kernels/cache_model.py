"""Differentiable cache models: smoothed Mattson hit-rate curves.

:mod:`repro.kernels.stack_distance` answers *exact* hit/miss questions:
at capacity ``C``, reference ``i`` hits iff ``dist_i + size_i <= C``.
The distances are capacity-independent, so one kernel pass carries the
whole curve ``H(C)`` — but only as a step function, which autodiff
cannot use.  This module turns the same distances into *models*:

* :func:`reuse_histogram` — bucket the per-reference hit thresholds
  ``c_i = dist_i + size_i`` into log-spaced bins (reference counts and
  byte weights per bin, compulsory mass kept separate).  This is the
  per-cache ``reuse_histogram`` surfaced on sweep cells.
* ``kind="hist"`` models — the smoothed Mattson curve
  ``H(C) = Σ_b w_b · σ((ln C − ln d_b) / τ)`` over the histogram
  buckets: monotone non-decreasing in ``C``, bounded in ``[0, 1]``, and
  exact up to bucketing + smoothing error (τ → 0 recovers the step
  curve).  Differentiable in capacity everywhere.
* ``kind="mixture"`` models — a parametric mixture-of-lognormals CDF
  fitted to the empirical curve with a jitted Adam loop
  (:func:`fit_lognormal_mixture`): a compact per-workload signature
  that survives without the histogram.
* ``kind="interp"`` models — a monotone piecewise-linear spline in
  log-capacity through *exact* swept points
  (:func:`fit_interp_model`): the fallback for curves the LRU stack
  model does not express (FIFO victim order, admission-filtered
  residue), fitted at whatever level the caller measured.

Every model evaluates with plain ``jax.numpy`` — no host round-trips —
so hit rate, bytes-from-origin and per-tier egress are ``grad``-able in
capacity, which is what :mod:`repro.core.planner` differentiates
through.  :func:`stack_models` pads a fleet of per-cache models into
one ``(n_caches, B)`` problem so the planner's whole objective is a
single jitted expression.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

DEFAULT_BUCKETS = 64
# Smoothing temperature in log-capacity space: ~5% capacity error per
# bucket edge, far below the 2%-absolute-hit-rate acceptance band.
DEFAULT_TAU = 0.05


# ---------------------------------------------------------------------------
# Reuse-distance histograms


@dataclasses.dataclass(frozen=True)
class ReuseHistogram:
    """Log-spaced histogram of per-reference hit thresholds.

    A reference with byte-weighted stack distance ``d`` and size ``s``
    hits at any capacity ``C >= d + s``; its *threshold* is ``c = d +
    s``.  Buckets carry reference counts and reference bytes; the
    compulsory mass (``d = inf``: first touch, cold restart) can never
    hit and is kept out of the buckets.
    """

    edges: np.ndarray         # (B+1,) threshold-bucket edges, bytes
    log_centers: np.ndarray   # (B,) mean ln(threshold) of refs in bucket
    ref_weights: np.ndarray   # (B,) references per bucket
    byte_weights: np.ndarray  # (B,) reference bytes per bucket
    compulsory_refs: int
    compulsory_bytes: int
    total_refs: int
    total_bytes: int

    def to_dict(self) -> Dict:
        """JSON-safe form (what sweep cells carry)."""
        return {
            "edges": [float(e) for e in self.edges],
            "log_centers": [float(c) for c in self.log_centers],
            "ref_weights": [float(w) for w in self.ref_weights],
            "byte_weights": [float(w) for w in self.byte_weights],
            "compulsory_refs": int(self.compulsory_refs),
            "compulsory_bytes": int(self.compulsory_bytes),
            "total_refs": int(self.total_refs),
            "total_bytes": int(self.total_bytes),
        }

    @staticmethod
    def from_dict(d: Dict) -> "ReuseHistogram":
        return ReuseHistogram(
            edges=np.asarray(d["edges"], np.float64),
            log_centers=np.asarray(d["log_centers"], np.float64),
            ref_weights=np.asarray(d["ref_weights"], np.float64),
            byte_weights=np.asarray(d["byte_weights"], np.float64),
            compulsory_refs=int(d["compulsory_refs"]),
            compulsory_bytes=int(d["compulsory_bytes"]),
            total_refs=int(d["total_refs"]),
            total_bytes=int(d["total_bytes"]))


def reuse_histogram(distances: np.ndarray, ref_sizes: np.ndarray,
                    n_buckets: int = DEFAULT_BUCKETS) -> ReuseHistogram:
    """Bucket one stream's hit thresholds ``c_i = dist_i + size_i``.

    ``distances`` come straight from
    :func:`repro.kernels.stack_distance.stack_distances_batch`
    (``inf`` marking compulsory misses); ``ref_sizes`` are the matching
    per-reference chunk bytes.  Totals are conserved exactly:
    ``sum(ref_weights) + compulsory_refs == total_refs`` and likewise
    for bytes — the property suite checks both.
    """
    dist = np.asarray(distances, np.float64)
    sizes = np.asarray(ref_sizes, np.float64)
    c = dist + sizes
    finite = np.isfinite(c)
    total_refs = int(len(c))
    total_bytes = int(round(sizes.sum()))
    comp_refs = int((~finite).sum())
    comp_bytes = int(round(sizes[~finite].sum()))
    cf, sf = c[finite], sizes[finite]
    if not len(cf):
        edges = np.geomspace(1.0, 2.0, n_buckets + 1)
        zeros = np.zeros(n_buckets)
        return ReuseHistogram(
            edges=edges, log_centers=np.log(np.sqrt(edges[:-1] * edges[1:])),
            ref_weights=zeros, byte_weights=zeros.copy(),
            compulsory_refs=comp_refs, compulsory_bytes=comp_bytes,
            total_refs=total_refs, total_bytes=total_bytes)
    lo, hi = float(cf.min()), float(cf.max())
    if hi <= lo:
        hi = lo * (1.0 + 1e-9) + 1.0
    edges = np.geomspace(lo, hi, n_buckets + 1)
    b = np.clip(np.searchsorted(edges, cf, side="right") - 1,
                0, n_buckets - 1)
    refw = np.bincount(b, minlength=n_buckets).astype(np.float64)
    bytew = np.bincount(b, weights=sf, minlength=n_buckets)
    logsum = np.bincount(b, weights=np.log(np.maximum(cf, 1.0)),
                         minlength=n_buckets)
    centers = np.log(np.sqrt(edges[:-1] * edges[1:]))
    occupied = refw > 0
    centers[occupied] = logsum[occupied] / refw[occupied]
    return ReuseHistogram(
        edges=edges, log_centers=centers, ref_weights=refw,
        byte_weights=bytew, compulsory_refs=comp_refs,
        compulsory_bytes=comp_bytes, total_refs=total_refs,
        total_bytes=total_bytes)


# ---------------------------------------------------------------------------
# Models


@dataclasses.dataclass(frozen=True)
class CacheModel:
    """One cache's fitted hit-rate curve, evaluable under autodiff.

    Every kind answers :func:`predict_hit_rate` /
    :func:`predict_miss_bytes` with pure ``jax.numpy`` math.  ``hist``
    and ``mixture`` kinds keep the histogram arrays (the mixture uses
    them for the byte/egress curve, where its ref-count fit does not
    apply); ``interp`` kinds carry only their knots.

    ``origin_fraction`` is the share of this cache's missed bytes that
    pulls from the *origin* rather than a parent tier (1.0 for flat
    caches and merged parent streams) — the per-tier egress weighting
    the planner's egress constraint uses.
    """

    kind: str                   # "hist" | "mixture" | "interp"
    tau: float = DEFAULT_TAU
    log_centers: Optional[np.ndarray] = None   # (B,)
    ref_weights: Optional[np.ndarray] = None   # (B,)
    byte_weights: Optional[np.ndarray] = None  # (B,)
    total_refs: float = 0.0
    total_bytes: float = 0.0
    compulsory_refs: float = 0.0
    compulsory_bytes: float = 0.0
    origin_fraction: float = 1.0
    # mixture-of-lognormals parameters (kind == "mixture")
    mix_logits: Optional[np.ndarray] = None     # (K,)
    mix_mu: Optional[np.ndarray] = None         # (K,)
    mix_log_sigma: Optional[np.ndarray] = None  # (K,)
    # monotone log-capacity spline knots (kind == "interp")
    knots_logc: Optional[np.ndarray] = None     # (M,)
    knots_hit: Optional[np.ndarray] = None      # (M,)
    fit_loss: float = 0.0


def fit_histogram_model(hist: ReuseHistogram, tau: float = DEFAULT_TAU,
                        origin_fraction: float = 1.0) -> CacheModel:
    """The smoothed Mattson curve over ``hist``'s buckets (nonparametric:
    the histogram *is* the fit)."""
    return CacheModel(
        kind="hist", tau=float(tau),
        log_centers=np.asarray(hist.log_centers, np.float64),
        ref_weights=np.asarray(hist.ref_weights, np.float64),
        byte_weights=np.asarray(hist.byte_weights, np.float64),
        total_refs=float(hist.total_refs),
        total_bytes=float(hist.total_bytes),
        compulsory_refs=float(hist.compulsory_refs),
        compulsory_bytes=float(hist.compulsory_bytes),
        origin_fraction=float(origin_fraction))


def _smoothed_frac(logC: jnp.ndarray, centers: jnp.ndarray,
                   weights: jnp.ndarray, tau: float) -> jnp.ndarray:
    """``Σ_b w_b σ((ln C − m_b)/τ)`` — broadcast over leading axes of
    ``logC``; weights need not be normalized."""
    z = (jnp.asarray(logC)[..., None] - centers) / tau
    return (weights * jax.nn.sigmoid(z)).sum(axis=-1)


def _mixture_cdf(logC: jnp.ndarray, logits: jnp.ndarray, mu: jnp.ndarray,
                 log_sigma: jnp.ndarray) -> jnp.ndarray:
    pis = jax.nn.softmax(logits)
    sigma = jnp.exp(log_sigma)
    z = (jnp.asarray(logC)[..., None] - mu) / (sigma * np.sqrt(2.0))
    return (pis * 0.5 * (1.0 + jax.scipy.special.erf(z))).sum(axis=-1)


def predict_hit_rate(model: CacheModel, capacity) -> jnp.ndarray:
    """``H(C)`` for one cache — differentiable in ``capacity`` (scalar
    or array), monotone non-decreasing, bounded in ``[0, 1]``."""
    logC = jnp.log(jnp.maximum(jnp.asarray(capacity, jnp.result_type(float)), 1.0))
    if model.kind == "interp":
        return jnp.clip(jnp.interp(logC, jnp.asarray(model.knots_logc),
                                   jnp.asarray(model.knots_hit)), 0.0, 1.0)
    denom = max(model.total_refs, 1.0)
    if model.kind == "mixture":
        finite = model.total_refs - model.compulsory_refs
        return finite / denom * _mixture_cdf(
            logC, jnp.asarray(model.mix_logits),
            jnp.asarray(model.mix_mu), jnp.asarray(model.mix_log_sigma))
    return _smoothed_frac(logC, jnp.asarray(model.log_centers),
                          jnp.asarray(model.ref_weights),
                          model.tau) / denom


def predict_miss_bytes(model: CacheModel, capacity) -> jnp.ndarray:
    """Expected bytes this cache pulls from upstream at ``capacity`` —
    the byte-weighted miss curve (compulsory bytes always pull)."""
    logC = jnp.log(jnp.maximum(jnp.asarray(capacity, jnp.result_type(float)), 1.0))
    if model.kind == "interp":
        return model.total_bytes * (1.0 - predict_hit_rate(model, capacity))
    hit_bytes = _smoothed_frac(logC, jnp.asarray(model.log_centers),
                               jnp.asarray(model.byte_weights), model.tau)
    return model.total_bytes - hit_bytes


# ---------------------------------------------------------------------------
# Parametric fit: mixture of lognormals


def _quantiles(values: np.ndarray, weights: np.ndarray,
               qs: np.ndarray) -> np.ndarray:
    order = np.argsort(values)
    v, w = values[order], weights[order]
    cw = np.cumsum(w)
    if cw[-1] <= 0:
        return np.zeros_like(qs)
    cw = cw / cw[-1]
    return np.interp(qs, cw, v)


@functools.partial(jax.jit, static_argnames=("steps", "lr"))
def _mixture_fit_loop(params0, grid, target, steps: int, lr: float):
    """Jitted Adam over the mixture parameters — the whole fit is one
    ``lax.fori_loop``, shared across every stream of a sweep (fixed
    grid/component shapes mean one compile)."""

    def loss_fn(params):
        logits, mu, log_sigma = params
        pred = _mixture_cdf(grid, logits, mu, log_sigma)
        return ((pred - target) ** 2).mean()

    grad_fn = jax.value_and_grad(loss_fn)

    def step(i, carry):
        params, mom, vel, _ = carry
        loss, grads = grad_fn(params)
        mom = jax.tree_util.tree_map(
            lambda a, g: 0.9 * a + 0.1 * g, mom, grads)
        vel = jax.tree_util.tree_map(
            lambda a, g: 0.999 * a + 0.001 * g * g, vel, grads)
        t = i + 1.0
        params = jax.tree_util.tree_map(
            lambda p, a, v: p - lr * (a / (1 - 0.9 ** t))
            / (jnp.sqrt(v / (1 - 0.999 ** t)) + 1e-8),
            params, mom, vel)
        return params, mom, vel, loss

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params0)
    params, _, _, loss = jax.lax.fori_loop(
        0, steps, step, (params0, zeros, zeros,
                         jnp.zeros((), grid.dtype)))
    return params, loss


def fit_lognormal_mixture(hist: ReuseHistogram, components: int = 3,
                          steps: int = 400, lr: float = 0.08,
                          origin_fraction: float = 1.0,
                          stats: Optional[Dict] = None) -> CacheModel:
    """Fit ``H(C) = p · Σ_k π_k Φ((ln C − μ_k)/σ_k)`` to the empirical
    curve with a fully jitted Adam loop (``lax.fori_loop`` — zero host
    round-trips between steps).

    ``p`` is the pinned non-compulsory mass; the free parameters are
    the component logits, means and log-sigmas, initialised
    deterministically from weighted quantiles of the threshold
    distribution so the fit is reproducible run to run.
    """
    w = np.asarray(hist.ref_weights, np.float64)
    m = np.asarray(hist.log_centers, np.float64)
    mass = float(w.sum())
    if mass <= 0 or not np.isfinite(m).all():
        # no finite reuse: the curve is identically zero
        return CacheModel(
            kind="mixture", mix_logits=np.zeros(components),
            mix_mu=np.zeros(components), mix_log_sigma=np.zeros(components),
            total_refs=float(hist.total_refs),
            total_bytes=float(hist.total_bytes),
            compulsory_refs=float(hist.total_refs),
            compulsory_bytes=float(hist.compulsory_bytes),
            log_centers=m, ref_weights=w,
            byte_weights=np.asarray(hist.byte_weights, np.float64),
            origin_fraction=float(origin_fraction))
    # empirical CDF of the threshold distribution (normalized to the
    # finite mass — the compulsory scale factor is pinned, not fitted)
    grid = np.linspace(m.min() - 1.0, m.max() + 1.0, 129)
    target = np.array([(w * (m <= g)).sum() for g in grid]) / mass
    qs = (np.arange(components) + 0.5) / components
    mu0 = _quantiles(m, w, qs)
    spread = max(float(m.max() - m.min()), 0.1)
    with enable_x64():
        params0 = (jnp.zeros(components, jnp.float64),
                   jnp.asarray(mu0, jnp.float64),
                   jnp.full(components,
                            np.log(spread / (2.0 * components)),
                            jnp.float64))
        params, loss = _mixture_fit_loop(params0, jnp.asarray(grid),
                                         jnp.asarray(target), steps, lr)
        logits, mu, log_sigma = (np.asarray(p, np.float64)
                                 for p in params)
    if stats is not None:
        stats["fit_steps"] = steps
        stats["fit_loss"] = float(loss)
    return CacheModel(
        kind="mixture", mix_logits=logits, mix_mu=mu,
        mix_log_sigma=log_sigma,
        total_refs=float(hist.total_refs),
        total_bytes=float(hist.total_bytes),
        compulsory_refs=float(hist.compulsory_refs),
        compulsory_bytes=float(hist.compulsory_bytes),
        log_centers=m, ref_weights=w,
        byte_weights=np.asarray(hist.byte_weights, np.float64),
        origin_fraction=float(origin_fraction), fit_loss=float(loss))


def fit_interp_model(capacities: Sequence[float],
                     hit_rates: Sequence[float],
                     total_refs: float = 1.0,
                     total_bytes: float = 0.0,
                     origin_fraction: float = 1.0) -> CacheModel:
    """Monotone piecewise-linear spline in log-capacity through exact
    swept ``(capacity, hit_rate)`` points — the model for curves the
    LRU stack does not express (FIFO columns, filtered residue).
    Monotonicity is enforced by a running max over the sorted knots, so
    the fitted curve keeps the property suite's invariants even when
    measurement noise wiggles the inputs."""
    caps = np.asarray(capacities, np.float64)
    hits = np.asarray(hit_rates, np.float64)
    order = np.argsort(caps)
    knots_logc = np.log(np.maximum(caps[order], 1.0))
    knots_hit = np.maximum.accumulate(np.clip(hits[order], 0.0, 1.0))
    return CacheModel(kind="interp", knots_logc=knots_logc,
                      knots_hit=knots_hit, total_refs=float(total_refs),
                      total_bytes=float(total_bytes),
                      origin_fraction=float(origin_fraction))


# ---------------------------------------------------------------------------
# Fleet-stacked evaluation (the planner's objective terms)


@dataclasses.dataclass(frozen=True)
class StackedModels:
    """A fleet of histogram-backed models padded to one ``(N, B)``
    problem, so fleet hit rate / egress at a capacity vector is a
    single jitted expression (and its gradient one VJP)."""

    names: List[str]
    log_centers: np.ndarray    # (N, B)
    ref_weights: np.ndarray    # (N, B)
    byte_weights: np.ndarray   # (N, B)
    total_refs: np.ndarray     # (N,)
    total_bytes: np.ndarray    # (N,)
    compulsory_bytes: np.ndarray  # (N,)
    origin_fraction: np.ndarray   # (N,)
    tau: float


def stack_models(models: Dict[str, CacheModel],
                 tau: Optional[float] = None) -> StackedModels:
    """Pad per-cache histogram models to a common bucket count.

    Only histogram-backed kinds stack (``hist`` and ``mixture`` — both
    carry bucket arrays); ``interp`` models have no buckets and raise.
    Padding buckets carry zero weight, so they change nothing.
    """
    names = sorted(models)
    for n in names:
        if models[n].log_centers is None:
            raise ValueError(
                f"model {n!r} (kind={models[n].kind!r}) has no histogram "
                "buckets; the stacked planner needs hist/mixture models")
    B = max(len(models[n].log_centers) for n in names)
    N = len(names)
    centers = np.zeros((N, B))
    refw = np.zeros((N, B))
    bytew = np.zeros((N, B))
    tot_r = np.zeros(N)
    tot_b = np.zeros(N)
    comp_b = np.zeros(N)
    of = np.ones(N)
    for i, n in enumerate(names):
        mdl = models[n]
        b = len(mdl.log_centers)
        centers[i, :b] = mdl.log_centers
        refw[i, :b] = mdl.ref_weights
        bytew[i, :b] = mdl.byte_weights
        tot_r[i] = mdl.total_refs
        tot_b[i] = mdl.total_bytes
        comp_b[i] = mdl.compulsory_bytes
        of[i] = mdl.origin_fraction
    return StackedModels(
        names=names, log_centers=centers, ref_weights=refw,
        byte_weights=bytew, total_refs=tot_r, total_bytes=tot_b,
        compulsory_bytes=comp_b, origin_fraction=of,
        tau=float(tau if tau is not None
                  else max(m.tau for m in models.values())))


def fleet_hits(stacked: StackedModels, capacities) -> jnp.ndarray:
    """Expected hit *count* per cache at a per-cache capacity vector
    ``(N,)`` — pure jnp, differentiable."""
    logC = jnp.log(jnp.maximum(jnp.asarray(capacities, jnp.result_type(float)), 1.0))
    z = (logC[:, None] - jnp.asarray(stacked.log_centers)) / stacked.tau
    return (jnp.asarray(stacked.ref_weights) * jax.nn.sigmoid(z)).sum(axis=1)


def fleet_hit_rate(stacked: StackedModels, capacities) -> jnp.ndarray:
    """Chunk-level fleet hit rate ``Σ hits_c / Σ refs_c`` at a
    per-cache capacity vector — the quantity the planner constrains
    (matches ``cache_hits / (cache_hits + cache_misses)`` of an exact
    replay, up to bucketing + smoothing error)."""
    total = jnp.maximum(jnp.asarray(stacked.total_refs).sum(), 1.0)
    return fleet_hits(stacked, capacities).sum() / total


def fleet_origin_egress(stacked: StackedModels, capacities) -> jnp.ndarray:
    """Expected origin egress bytes at a per-cache capacity vector:
    each cache's missed bytes (reuse misses + compulsory), weighted by
    the share of its misses that pulls from the origin rather than a
    parent tier."""
    logC = jnp.log(jnp.maximum(jnp.asarray(capacities, jnp.result_type(float)), 1.0))
    z = (logC[:, None] - jnp.asarray(stacked.log_centers)) / stacked.tau
    hit_bytes = (jnp.asarray(stacked.byte_weights)
                 * jax.nn.sigmoid(z)).sum(axis=1)
    miss_bytes = jnp.asarray(stacked.total_bytes) - hit_bytes
    return (jnp.asarray(stacked.origin_fraction) * miss_bytes).sum()
