"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

FNV_PRIME = 0x01000193


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """O(S²) GQA attention. q: (B,S,H,hd); k/v: (B,S,KV,hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / hd ** 0.5
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def poly_digest_ref(data: jax.Array, block: int = 1024) -> jax.Array:
    """Blockwise degree-weighted polynomial hash (uint32 wraparound)."""
    flat = data.reshape(-1).astype(jnp.uint32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)

    def powers(n):
        def step(c, _):
            return c * jnp.uint32(FNV_PRIME), c
        _, ps = jax.lax.scan(step, jnp.uint32(1), None, length=n)
        return ps[::-1]

    w = powers(block)
    digests = jnp.sum(blocks * w[None, :], axis=1, dtype=jnp.uint32)
    wb = powers(digests.shape[0])
    return jnp.sum(digests * wb, dtype=jnp.uint32), digests


def ssd_intra_ref(x, dt, cum, b_in, c_in):
    """Intra-chunk SSD oracle.

    x: (B,NC,Q,H,P); dt/cum: (B,NC,Q,H); b_in/c_in: (B,NC,Q,N)."""
    q = x.shape[2]
    scores = jnp.einsum("bcqn,bckn->bcqk", c_in.astype(jnp.float32),
                        b_in.astype(jnp.float32))
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(mask[None, None, :, :, None],
                  scores[..., None] * decay, 0.0)
    m = m * dt[:, :, None, :, :]
    return jnp.einsum("bcqkh,bckhp->bcqhp", m,
                      x.astype(jnp.float32)).astype(x.dtype)


def maxmin_ref(link_caps, membership, flow_caps):
    """Scalar max-min waterfilling oracle (per-link greedy fixing).

    Port of the simulator's original dict-walking allocator to array
    inputs: link_caps (L,), membership (F, L) 0/1, flow_caps (F,).
    Ground truth for ``repro.kernels.maxmin.maxmin_rates``.
    """
    import numpy as np

    membership = np.asarray(membership, dtype=bool)
    num_flows, num_links = membership.shape
    cap_left = np.asarray(link_caps, dtype=np.float64).copy()
    flow_caps = np.asarray(flow_caps, dtype=np.float64)
    rates = np.zeros(num_flows)
    unfixed = set(range(num_flows))
    link_flows = [np.nonzero(membership[:, l])[0] for l in range(num_links)]
    while unfixed:
        best_share, best_lid = float("inf"), None
        for lid in range(num_links):
            n = sum(1 for fi in link_flows[lid] if fi in unfixed)
            if n == 0:
                continue
            share = cap_left[lid] / n
            if share < best_share:
                best_share, best_lid = share, lid
        capped = [fi for fi in unfixed if flow_caps[fi] < best_share]
        if capped:
            for fi in capped:
                rates[fi] = flow_caps[fi]
                unfixed.discard(fi)
                for lid in np.nonzero(membership[fi])[0]:
                    cap_left[lid] = max(0.0, cap_left[lid] - rates[fi])
            continue
        if best_lid is None:
            for fi in unfixed:
                rates[fi] = flow_caps[fi]
            break
        fixed_now = [fi for fi in link_flows[best_lid] if fi in unfixed]
        for fi in fixed_now:
            rates[fi] = best_share
            unfixed.discard(fi)
            for lid in np.nonzero(membership[fi])[0]:
                if lid != best_lid:
                    cap_left[lid] = max(0.0, cap_left[lid] - best_share)
        cap_left[best_lid] = 0.0
    return rates
