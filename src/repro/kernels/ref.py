"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

FNV_PRIME = 0x01000193


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """O(S²) GQA attention. q: (B,S,H,hd); k/v: (B,S,KV,hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / hd ** 0.5
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def poly_digest_ref(data: jax.Array, block: int = 1024) -> jax.Array:
    """Blockwise degree-weighted polynomial hash (uint32 wraparound)."""
    flat = data.reshape(-1).astype(jnp.uint32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)

    def powers(n):
        def step(c, _):
            return c * jnp.uint32(FNV_PRIME), c
        _, ps = jax.lax.scan(step, jnp.uint32(1), None, length=n)
        return ps[::-1]

    w = powers(block)
    digests = jnp.sum(blocks * w[None, :], axis=1, dtype=jnp.uint32)
    wb = powers(digests.shape[0])
    return jnp.sum(digests * wb, dtype=jnp.uint32), digests


def ssd_intra_ref(x, dt, cum, b_in, c_in):
    """Intra-chunk SSD oracle.

    x: (B,NC,Q,H,P); dt/cum: (B,NC,Q,H); b_in/c_in: (B,NC,Q,N)."""
    q = x.shape[2]
    scores = jnp.einsum("bcqn,bckn->bcqk", c_in.astype(jnp.float32),
                        b_in.astype(jnp.float32))
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(mask[None, None, :, :, None],
                  scores[..., None] * decay, 0.0)
    m = m * dt[:, :, None, :, :]
    return jnp.einsum("bcqkh,bckhp->bcqhp", m,
                      x.astype(jnp.float32)).astype(x.dtype)
