"""Blockwise chunk checksums as a Pallas TPU kernel.

CVMFS verifies "checksums of the data ... along the chunk boundaries"
(paper §3.1/§6) — on a TPU fleet, checksum validation of cache chunks
(dataset shards, checkpoint leaves) sits on the ingest path of every
worker, so it is worth a vectorised kernel.

Hardware adaptation (DESIGN.md §6): byte-serial FNV-1a does not map to a
vector unit, so the *fleet* digest is a SIMD-friendly degree-weighted
polynomial hash in uint32:

    digest(block) = Σ_i data[i] · P^(L−1−i)   (mod 2³²),  P = 0x01000193

computed per 128-lane block as a weighted reduction (one multiply-add per
element), then blocks are combined host-side with the same polynomial
fold.  ``repro.kernels.ref.poly_digest_ref`` is the jnp oracle;
``repro.core.chunk.fnv1a64`` remains the wire-format checksum of the
functional federation (both are tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FNV_PRIME = 0x01000193
MOD = jnp.uint32


def _powers(n: int) -> jax.Array:
    """[P^(n-1), ..., P^1, P^0] mod 2^32."""
    def step(carry, _):
        return (carry * jnp.uint32(FNV_PRIME)), carry
    _, ps = jax.lax.scan(step, jnp.uint32(1), None, length=n)
    return ps[::-1]


def _checksum_kernel(data_ref, w_ref, out_ref):
    d = data_ref[...].astype(jnp.uint32)
    w = w_ref[...].astype(jnp.uint32)
    out_ref[0] = jnp.sum(d * w, dtype=jnp.uint32)


def block_digests(data: jax.Array, block: int = 1024,
                  interpret: bool = False) -> jax.Array:
    """Per-block polynomial digests of a uint8/int32 buffer."""
    flat = data.reshape(-1).astype(jnp.uint32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    n_blocks = flat.size // block
    weights = _powers(block)
    out = pl.pallas_call(
        _checksum_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks,), jnp.uint32),
        interpret=interpret,
    )(flat, weights)
    return out


def combine_digests(digests: jax.Array, block: int = 1024) -> jax.Array:
    """Fold per-block digests into one uint32 (same polynomial weights)."""
    pblock = _powers(digests.shape[0])
    return jnp.sum(digests.astype(jnp.uint32) * pblock, dtype=jnp.uint32)


def chunk_checksum(data: jax.Array, block: int = 1024,
                   interpret: bool = False) -> jax.Array:
    return combine_digests(block_digests(data, block, interpret), block)
