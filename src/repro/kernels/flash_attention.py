"""Flash attention (causal / sliding-window GQA) as a Pallas TPU kernel.

Canonical online-softmax tiling: grid (batch, q_heads, n_q_blocks,
n_kv_blocks) with the innermost (kv) dimension executed sequentially per
core, carrying running max / denominator / accumulator in VMEM scratch.
BlockSpecs keep one (q_block × head_dim) query tile and one (kv_block ×
head_dim) KV tile resident; KV heads are indexed by ``h // group`` so GQA
never materialises repeated KV in HBM.  Block sizes default to MXU-aligned
(128) multiples.

This replaces the jnp blockwise path (``repro.models.attention``) on real
TPUs; correctness is validated in interpret mode against
``repro.kernels.ref.attention_ref`` across shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  q_block: int, kv_block: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (qb, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (kb, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    rows = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                   (q_block, kv_block), 0)
    cols = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 1)
    mask = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows (early q rows in windowed blocks): avoid inf-inf
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]) \
            .astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) → (B, S, H, hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    n_q = -(-s // q_block)
    n_kv = -(-s // kv_block)
    if s % q_block or s % kv_block:
        pad_to = max(n_q * q_block, n_kv * kv_block)
        q = jnp.pad(q, ((0, 0), (0, pad_to - s), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_to - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_to - s), (0, 0), (0, 0)))
        n_q = pad_to // q_block
        n_kv = pad_to // kv_block
    grid = (b, h, n_q, n_kv)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / hd ** 0.5, causal=causal, window=window,
        softcap=softcap, q_block=q_block, kv_block=kv_block, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda bi, hi, qi, ki, g=group:
                         (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda bi, hi, qi, ki, g=group:
                         (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
