"""Vectorized max-min fair-share waterfilling (batched JAX array ops).

The fluid-flow simulator re-solves the max-min bandwidth allocation on
every flow arrival/completion.  The scalar solver walks python dicts of
links and flows — O(rounds × links × flows) per reallocation — which caps
:class:`~repro.core.simulator.FluidFlowSim` at a few hundred sites.  This
module batches the whole waterfilling across flows as array ops.

Topology paths are short (NIC → uplink → WAN → uplink → NIC, ≤ 5 links),
so membership is kept *sparse*: each flow carries a fixed-width row of
link indices, and every waterfilling round is a segment-sum (active flows
per link), a gather (each flow's tightest link share) and a scatter-add
(retiring capacity) under one ``lax.while_loop``:

  share_l   = cap_left_l / active_flows_l          (segment-sum)
  bottleneck = min_f min_{l ∈ links(f)} share_l    (gather + min)
  → fix flows whose own TCP cap binds below the bottleneck, else
  → fix every flow whose tightest share equals the bottleneck

Each round retires at least one flow or saturates at least one link; with
fleet-uniform link classes the shares are massively tied, so rounds stay
near the number of *distinct* bottleneck levels, not the link count.
Shapes are padded to power-of-two buckets so JIT recompiles O(log) times,
not per event.  ``repro.kernels.ref.maxmin_ref`` is the scalar oracle;
parity is enforced by ``tests/test_maxmin.py``.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def solve_waterfill(link_caps: jax.Array, link_ids: jax.Array,
                    flow_caps: jax.Array) -> jax.Array:
    """The batchable waterfilling core (unjitted, vmappable).

    link_caps: (L,) with a trailing dummy-inf slot; link_ids: (F, K)
    int32 rows of link indices (padding points at the dummy slot);
    flow_caps: (F,) → per-flow rates (F,).

    Every op is shape-static and the while-loop body is idempotent once
    ``active`` empties, so ``jax.vmap(solve_waterfill)`` solves a whole
    batch of same-shaped problems in one call — that is what
    :mod:`repro.kernels.batched_maxmin` builds on for sweep pricing."""
    num_flows, width = link_ids.shape
    num_links = link_caps.shape[0]
    inf = jnp.float32(jnp.inf)
    flat_ids = link_ids.reshape(-1)

    def seg_sum(per_flow: jax.Array) -> jax.Array:
        """Scatter-add a per-flow value onto each of its links."""
        vals = jnp.broadcast_to(per_flow[:, None],
                                (num_flows, width)).reshape(-1)
        return jnp.zeros(num_links, per_flow.dtype).at[flat_ids].add(vals)

    def cond(state):
        _, active, _, it = state
        return jnp.logical_and(active.any(), it < num_flows + num_links + 2)

    def body(state):
        rates, active, cap_left, it = state
        n = seg_sum(active.astype(jnp.float32))
        share = jnp.where(n > 0, cap_left / jnp.maximum(n, 1.0), inf)
        flow_share = share[link_ids].min(axis=1)        # tightest link
        best = jnp.where(active, flow_share, inf).min()
        capped = active & (flow_caps < best)

        def fix(mask, rate):
            new_rates = jnp.where(mask, rate, rates)
            used = seg_sum(jnp.where(mask, rate, 0.0))
            return new_rates, active & ~mask, jnp.maximum(cap_left - used,
                                                          0.0)

        def fix_capped(_):
            return fix(capped, flow_caps)

        def fix_bottleneck(_):
            def no_links(_):
                # remaining flows cross no capacity-bearing link: their
                # own TCP cap is the only constraint (scalar fallback).
                return (jnp.where(active, flow_caps, rates),
                        jnp.zeros_like(active), cap_left)

            def waterfill(_):
                on_best = active & (flow_share <= best)
                new_rates, new_active, new_cap = fix(on_best, best)
                # float-safety: argmin links are saturated by construction
                return new_rates, new_active, jnp.where(share <= best, 0.0,
                                                        new_cap)

            return jax.lax.cond(jnp.isinf(best), no_links, waterfill, None)

        rates, active, cap_left = jax.lax.cond(
            capped.any(), fix_capped, fix_bottleneck, None)
        return rates, active, cap_left, it + 1

    rates0 = jnp.zeros_like(flow_caps)
    active0 = (link_ids < num_links - 1).any(axis=1)  # padded rows retired
    state = (rates0, active0, link_caps, jnp.int32(0))
    rates, _, _, _ = jax.lax.while_loop(cond, body, state)
    return rates


_solve = jax.jit(solve_waterfill)


def _next_pow2(n: int, floor: int = 8) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


def pad_problem(link_caps: Sequence[float],
                flow_links: Sequence[Sequence[int]],
                flow_caps: Sequence[float],
                Fp: int, Lp: int, width: int):
    """Pad one (flows, links) problem into the ``solve_waterfill`` layout.

    Returns ``(caps, ids, fcaps)`` numpy arrays of shapes (Lp,), (Fp,
    width), (Fp,): real link capacities followed by infinite-capacity
    slots (the last is the dummy every padding id points at), per-flow
    link-index rows, zero-capped padding flows.  Shared by the
    single-problem path below and the pow2-bucketed batch packer in
    :mod:`repro.kernels.batched_maxmin`."""
    F, L = len(flow_links), len(link_caps)
    if L + 1 > Lp or F > Fp:
        raise ValueError(f"problem ({F} flows, {L} links) exceeds "
                         f"bucket (Fp={Fp}, Lp={Lp})")
    dummy = Lp - 1
    ids = np.full((Fp, width), dummy, np.int32)
    for fi, ls in enumerate(flow_links):
        if len(ls) > width:
            raise ValueError(f"flow {fi} crosses {len(ls)} links > "
                             f"bucket width {width}")
        ids[fi, :len(ls)] = ls
    caps = np.full(Lp, np.inf, np.float32)
    caps[:L] = link_caps
    fcaps = np.zeros(Fp, np.float32)
    fcaps[:F] = flow_caps
    return caps, ids, fcaps


def maxmin_rates_sparse(link_caps: Sequence[float],
                        flow_links: Sequence[Sequence[int]],
                        flow_caps: Sequence[float]) -> np.ndarray:
    """Max-min fair rates with per-flow caps, batched across the fleet.

    ``link_caps``: (L,) bytes/s; ``flow_links``: per-flow link-index
    lists; ``flow_caps``: (F,) per-flow TCP ceiling.  Shapes are padded
    to power-of-two buckets (padding points at a dummy infinite-capacity
    link slot) so the JIT cache stays small.
    """
    F, L = len(flow_links), len(link_caps)
    width = _next_pow2(max((len(ls) for ls in flow_links), default=1),
                       floor=4)
    Fp, Lp = _next_pow2(F), _next_pow2(L + 1)
    caps, ids, fcaps = pad_problem(link_caps, flow_links, flow_caps,
                                   Fp, Lp, width)
    rates = _solve(jnp.asarray(caps), jnp.asarray(ids), jnp.asarray(fcaps))
    out = np.array(rates[:F])
    # Flows crossing no capacity-bearing link (loopback transfers) look
    # identical to padding inside ``_solve`` — all-dummy rows retired at
    # rate 0 — but are real flows bound only by their own TCP cap, which
    # is what the scalar solver assigns.  Restore parity here so
    # same-node ``sim.flow(src, src, ...)`` completes under both solvers.
    for fi, ls in enumerate(flow_links):
        if not ls:
            out[fi] = flow_caps[fi]
    return out


def maxmin_rates(link_caps: np.ndarray, membership: np.ndarray,
                 flow_caps: np.ndarray) -> np.ndarray:
    """Dense-membership convenience wrapper: ``membership`` is (F, L) 0/1."""
    membership = np.asarray(membership)
    flow_links: List[List[int]] = [list(np.nonzero(row)[0])
                                   for row in membership]
    return maxmin_rates_sparse(np.asarray(link_caps, np.float32), flow_links,
                               np.asarray(flow_caps, np.float32))
