"""Attention: GQA (full / sliding-window / cross), train + decode paths.

TPU/GSPMD-idiomatic choices:
  * the full-sequence path is *blockwise over query blocks* (lax.scan) so
    per-layer logit buffers stay O(S·q_block) instead of O(S²) — the jnp
    analogue of flash attention; the Pallas kernel
    (``repro.kernels.flash_attention``) replaces it on real TPUs;
  * KV heads are **repeated to the query-head count** for the train path:
    the grouped-GQA reshape (H → KV×G) defeats GSPMD sharding propagation
    whenever KV doesn't divide the model axis (true for most assigned
    archs, kv=8 on a 16-wide axis), while a repeat of replicated KV onto
    the sharded H dim is a local slice.  The KV *cache* still stores
    unrepeated heads — the GQA memory saving is preserved where it
    matters;
  * sliding-window layers slice a static ``window + q_block`` KV span per
    query block, so SWA costs O(S·W) not O(S²) — this is what makes
    mixtral/gemma2 ``long_500k``-capable;
  * decode uses a ring-buffer KV cache for windowed layers (cache size
    min(S, window)) and dense caches for global layers, sharded over the
    sequence dim so arbitrary head counts distribute (softmax over the
    sharded seq dim becomes an XLA-managed cross-shard reduction).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import apply_rope, dense_init, softcap, zeros_init

NEG_INF = -2.0 ** 30


def init_attention(key: jax.Array, cfg: ArchConfig, cross: bool = False,
                   dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.resolved_num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["wq"], s["wq"] = dense_init(ks[0], (d, h, hd),
                                  ("embed", "q_heads", "head_dim"),
                                  dtype=dtype)
    if cfg.padded_heads:
        # zero the pad rows: structurally inactive heads at init
        mask = (jnp.arange(h) < cfg.num_heads).astype(p["wq"].dtype)
        p["wq"] = p["wq"] * mask[None, :, None]
    p["wk"], s["wk"] = dense_init(ks[1], (d, kv, hd),
                                  ("embed", "kv_heads", "head_dim"),
                                  dtype=dtype)
    p["wv"], s["wv"] = dense_init(ks[2], (d, kv, hd),
                                  ("embed", "kv_heads", "head_dim"),
                                  dtype=dtype)
    p["wo"], s["wo"] = dense_init(ks[3], (h, hd, d),
                                  ("q_heads", "head_dim", "embed"),
                                  dtype=dtype)
    if cfg.padded_heads:
        mask = (jnp.arange(h) < cfg.num_heads).astype(p["wo"].dtype)
        p["wo"] = p["wo"] * mask[:, None, None]
    if cfg.qkv_bias:
        p["bq"], s["bq"] = zeros_init((h, hd), ("q_heads", "head_dim"), dtype)
        p["bk"], s["bk"] = zeros_init((kv, hd), ("kv_heads", "head_dim"), dtype)
        p["bv"], s["bv"] = zeros_init((kv, hd), ("kv_heads", "head_dim"), dtype)
    if cross:
        # Llama-3.2-Vision style gated cross-attention.
        p["gate"], s["gate"] = zeros_init((), (), dtype)
    return p, s


def _project_qkv(p, x, kv_src, cfg: ArchConfig, positions, kv_positions,
                 rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    return jnp.repeat(k, groups, axis=2) if groups > 1 else k


# ---------------------------------------------------------------------------
# Grouped (unrepeated) score helpers — decode path
# ---------------------------------------------------------------------------
def _gqa_scores(q, k, softcap_val: float):
    """q: (B,S,H,hd), k: (B,T,KV,hd) → scores (B, KV, G, S, T)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / (hd ** 0.5)
    return softcap(scores, softcap_val)


def _gqa_out(probs, v):
    """probs: (B,KV,G,S,T), v: (B,T,KV,hd) → (B,S,H,hd)."""
    b, kvh, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, kvh * g, v.shape[-1])


# ---------------------------------------------------------------------------
# Full-sequence (train / prefill) path
# ---------------------------------------------------------------------------
def attention_forward(p, x: jax.Array, cfg: ArchConfig,
                      positions: jax.Array,
                      window: int = 0,
                      cross_states: Optional[jax.Array] = None,
                      q_block: int = 512) -> jax.Array:
    """Blockwise causal (optionally windowed) self-attention, or full
    cross-attention when ``cross_states`` is given."""
    hd = cfg.resolved_head_dim
    if cross_states is not None:
        t = cross_states.shape[1]
        kv_pos = jnp.arange(t)[None, :]
        q, k, v = _project_qkv(p, x, cross_states.astype(x.dtype), cfg,
                               positions, kv_pos, rope=False)
        g = q.shape[2] // k.shape[2]
        k, v = _repeat_kv(k, g), _repeat_kv(v, g)
        scores = softcap(jnp.einsum("bshd,bthd->bhst", q, k) / hd ** 0.5,
                         cfg.attn_logit_softcap)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return jnp.tanh(p["gate"]) * out if "gate" in p else out

    q, k, v = _project_qkv(p, x, x, cfg, positions, positions, rope=True)
    b, s, h, _ = q.shape
    g = h // k.shape[2]
    k, v = _repeat_kv(k, g), _repeat_kv(v, g)
    qb = min(q_block, s)
    n_blocks = -(-s // qb)
    pad = n_blocks * qb - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = q.reshape(b, n_blocks, qb, h, hd).transpose(1, 0, 2, 3, 4)

    if window and window < s:
        span = min(window + qb, s)   # static KV span per query block

        def qblock(carry, inp):
            blk_idx, qblk = inp
            start = jnp.maximum(blk_idx * qb + qb - span, 0)
            kslc = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
            vslc = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
            qpos = blk_idx * qb + jnp.arange(qb)
            kpos = start + jnp.arange(span)
            scores = softcap(
                jnp.einsum("bqhd,bthd->bhqt", qblk, kslc) / hd ** 0.5,
                cfg.attn_logit_softcap)
            valid = (kpos[None, :] <= qpos[:, None]) & \
                    (kpos[None, :] > qpos[:, None] - window) & \
                    (kpos[None, :] < s)
            scores = jnp.where(valid[None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
            return carry, jnp.einsum("bhqt,bthd->bqhd",
                                     probs.astype(v.dtype), vslc)

        _, outs = jax.lax.scan(qblock, None, (jnp.arange(n_blocks), qs))
    else:
        kpos = jnp.arange(s)

        def qblock(carry, inp):
            blk_idx, qblk = inp
            qpos = blk_idx * qb + jnp.arange(qb)
            scores = softcap(
                jnp.einsum("bqhd,bthd->bhqt", qblk, k) / hd ** 0.5,
                cfg.attn_logit_softcap)
            valid = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(valid[None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
            return carry, jnp.einsum("bhqt,bthd->bqhd",
                                     probs.astype(v.dtype), v)

        _, outs = jax.lax.scan(qblock, None, (jnp.arange(n_blocks), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_blocks * qb, h, hd)
    if pad:
        out = out[:, :s]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def prefill_attention(p, x: jax.Array, cfg: ArchConfig, positions, window,
                      max_seq: int, cache_dtype=None):
    """Full-sequence attention that also emits the populated KV cache
    (ring-buffer layout for windowed layers, matching decode_attention)."""
    cache_dtype = cache_dtype or x.dtype
    out = attention_forward(p, x, cfg, positions, window=window)
    _, k, v = _project_qkv(p, x, x, cfg, positions, positions, rope=True)
    b, s, kvh, hd = k.shape
    size = min(max_seq, window) if window else max_seq
    take = min(s, size)
    slots = jnp.arange(s - take, s) % size
    kc = jnp.zeros((b, size, kvh, hd), cache_dtype)
    vc = jnp.zeros((b, size, kvh, hd), cache_dtype)
    kc = kc.at[:, slots].set(k[:, s - take:].astype(cache_dtype))
    vc = vc.at[:, slots].set(v[:, s - take:].astype(cache_dtype))
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Decode path (KV cache, one token)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, window: int,
                  dtype=jnp.bfloat16) -> Tuple[Dict[str, jax.Array], Dict]:
    """Dense cache for global layers; ring buffer (size=window) for SWA."""
    size = min(max_seq, window) if window else max_seq
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
    }
    specs = {
        "k": ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
    }
    return cache, specs


def decode_attention(p, x: jax.Array, cache: Dict[str, jax.Array],
                     pos: jax.Array, cfg: ArchConfig,
                     window: int = 0,
                     cross_states: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode: x (B, 1, D), pos scalar int32."""
    if cross_states is not None:
        out = attention_forward(p, x, cfg, jnp.full((1, 1), 0),
                                cross_states=cross_states)
        return out, cache
    positions = jnp.reshape(pos, (1, 1))
    q, k_new, v_new = _project_qkv(p, x, x, cfg, positions, positions,
                                   rope=True)
    size = cache["k"].shape[1]
    ring = bool(window) and window < 10 ** 9
    slot = pos % size if ring else jnp.minimum(pos, size - 1)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    idx = jnp.arange(size)
    if ring:
        # Ring buffer: entry idx holds absolute position
        # pos − ((slot − idx) mod size); valid once actually written.
        age = (slot - idx) % size
        valid = age <= pos
    else:
        valid = idx <= pos
    scores = _gqa_scores(q, k, cfg.attn_logit_softcap)    # (B,KV,G,1,size)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = _gqa_out(probs.astype(v.dtype), v)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": k, "v": v}
