"""Mamba-2 SSD (state-space duality) — chunked, MXU-friendly.

The SSD algorithm [arXiv:2405.21060] computes the selective-SSM recurrence
as (a) quadratic attention-like matmuls *within* chunks of length Q and
(b) a linear recurrence *between* chunk states — exactly the decomposition
that maps onto the TPU MXU (the intra-chunk part is batched matmuls) with
an O(L/Q) sequential scan between chunks.  This is the hardware adaptation
of Mamba2's CUDA kernel noted in DESIGN.md: same math, tiled for systolic
matmul rather than warp-level scans.

Projections are kept *separate* (z, x, B, C, dt) rather than fused as in
the CUDA reference: a fused projection's output dim mixes tensor-parallel
(d_inner) and replicated (state/dt) segments, and slicing a sharded dim at
non-shard-aligned offsets forces all-gathers under GSPMD.  Separate
weights shard cleanly (d_inner → model axis, small B/C/dt replicated).

Shapes follow the paper: heads H with head dim P (d_inner = H·P), state
size N, scalar decay a_t = exp(Δ_t·A_h) per head/step, shared B/C
(ngroups=1).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init, ones_init, rms_norm, zeros_init


def init_ssm(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["in_z"], s["in_z"] = dense_init(ks[0], (d, di), ("embed", "ssm_inner"),
                                      dtype=dtype)
    p["in_x"], s["in_x"] = dense_init(ks[1], (d, di), ("embed", "ssm_inner"),
                                      dtype=dtype)
    p["in_b"], s["in_b"] = dense_init(ks[2], (d, n), ("embed", "ssm_state"),
                                      dtype=dtype)
    p["in_c"], s["in_c"] = dense_init(ks[3], (d, n), ("embed", "ssm_state"),
                                      dtype=dtype)
    p["in_dt"], s["in_dt"] = dense_init(ks[4], (d, h), ("embed", "ssm_heads"),
                                        dtype=dtype)
    p["conv_x"], s["conv_x"] = dense_init(
        ks[5], (cw, di), ("conv", "ssm_inner"), scale=cw ** 0.5, dtype=dtype)
    p["conv_b"], s["conv_b"] = dense_init(
        ks[6], (cw, n), ("conv", "ssm_state"), scale=cw ** 0.5, dtype=dtype)
    p["conv_c"], s["conv_c"] = dense_init(
        ks[7], (cw, n), ("conv", "ssm_state"), scale=cw ** 0.5, dtype=dtype)
    p["a_log"], s["a_log"] = zeros_init((h,), ("ssm_heads",), jnp.float32)
    p["dt_bias"], s["dt_bias"] = zeros_init((h,), ("ssm_heads",), jnp.float32)
    p["d_skip"], s["d_skip"] = ones_init((h,), ("ssm_heads",), jnp.float32)
    p["gate_norm"], s["gate_norm"] = zeros_init((di,), ("ssm_inner",), dtype)
    p["out_proj"], s["out_proj"] = dense_init(
        ks[4], (di, d), ("ssm_inner", "embed"), dtype=dtype)
    return p, s


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over the sequence dim.  u: (B, L, C)."""
    cw = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(cw))
    return jax.nn.silu(out)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array,
                b_in: jax.Array, c_in: jax.Array, chunk: int,
                h0: jax.Array = None):
    """Core SSD scan.

    x: (B, L, H, P)   dt: (B, L, H)   a: (H,) (negative)
    b_in, c_in: (B, L, N)             chunk: Q
    Returns (y (B,L,H,P), h_final (B,H,N,P)).
    """
    bsz, l, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b_in.reshape(bsz, nc, q, n)
    cc = c_in.reshape(bsz, nc, q, n)

    la = dtc * a[None, None, None, :]            # log-decay per step (B,NC,Q,H)
    cum = jnp.cumsum(la, axis=2)                 # inclusive cumsum
    seg_end = cum[:, :, -1:, :]                  # total chunk decay

    # Intra-chunk: Y[i] = Σ_{j<=i} C_i·B_j exp(cum_i − cum_j) Δ_j x_j
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)           # (B,NC,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(mask[None, None, :, :, None],
                  scores[..., None] * decay, 0.0)            # (B,NC,Q,Q,H)
    m = m * dtc[:, :, None, :, :]                            # Δ_j
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xc)

    # Chunk states: S_c = Σ_j exp(seg_end − cum_j) Δ_j B_j ⊗ x_j
    w = jnp.exp(seg_end - cum) * dtc                         # (B,NC,Q,H)
    s_c = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", w, bc, xc)    # (B,NC,H,N,P)

    # Inter-chunk recurrence (sequential over NC chunks).
    seg = jnp.exp(seg_end[:, :, 0, :])                       # (B,NC,H)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), x.dtype)

    def step(hprev, inp):
        seg_c, s_cc = inp
        hnew = seg_c[:, :, None, None] * hprev + s_cc
        return hnew, hprev

    hT, h_starts = jax.lax.scan(
        step, h0, (seg.transpose(1, 0, 2), s_c.transpose(1, 0, 2, 3, 4)))
    h_starts = h_starts.transpose(1, 0, 2, 3, 4)             # (B,NC,H,N,P)

    # Inter-chunk contribution: Y[i] += exp(cum_i) C_i · h_chunk_start
    y_inter = jnp.einsum("bcqh,bcqn,bchnp->bcqhp",
                         jnp.exp(cum), cc, h_starts)
    y = (y_intra + y_inter).reshape(bsz, nc * q, h, p)
    if pad:
        y = y[:, :l]
    return y, hT


def _project(p, x, cfg: ArchConfig):
    """x (B,L,D) → z, x_conv, b_conv, c_conv, dt (pre-softplus)."""
    di, h = cfg.d_inner, cfg.ssm_heads
    z = x @ p["in_z"]
    xr = x @ p["in_x"]
    br = x @ p["in_b"]
    cr = x @ p["in_c"]
    dt = x @ p["in_dt"]
    return z, xr, br, cr, dt


def ssm_forward(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    out, _ = _ssm_seq(p, x, cfg, want_cache=False)
    return out


def prefill_ssm(p, x: jax.Array, cfg: ArchConfig):
    """Full-sequence SSM that also emits the decode cache (final SSD state
    + causal-conv history, matching ssm_decode's expectations)."""
    return _ssm_seq(p, x, cfg, want_cache=True)


def _ssm_seq(p, x: jax.Array, cfg: ArchConfig, want_cache: bool):
    bsz, l, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_headdim
    cw = cfg.ssm_conv_width
    z, xr, br, cr, dt = _project(p, x, cfg)
    xi = _causal_conv(xr, p["conv_x"]).reshape(bsz, l, h, hp)
    b_in = _causal_conv(br, p["conv_b"])
    c_in = _causal_conv(cr, p["conv_c"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])
    y, h_final = ssd_chunked(xi.astype(jnp.float32), dt, a,
                             b_in.astype(jnp.float32),
                             c_in.astype(jnp.float32), cfg.ssm_chunk)
    y = y + p["d_skip"][None, None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(bsz, l, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if not want_cache:
        return out, None
    # Conv history = last (cw−1) *raw* projected rows (pre-activation).
    def tail(u):
        return jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))[:, l:, :]
    cache = {"h": h_final.astype(jnp.float32),
             "conv_x": tail(xr).astype(x.dtype),
             "conv_b": tail(br).astype(x.dtype),
             "conv_c": tail(cr).astype(x.dtype)}
    return out, cache


# ---------------------------------------------------------------------------
# Decode path: O(1) state update per token
# ---------------------------------------------------------------------------
def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv_width
    cache = {
        "h": jnp.zeros((batch, h, n, cfg.ssm_headdim), jnp.float32),
        "conv_x": jnp.zeros((batch, cw - 1, di), dtype),
        "conv_b": jnp.zeros((batch, cw - 1, n), dtype),
        "conv_c": jnp.zeros((batch, cw - 1, n), dtype),
    }
    specs = {
        "h": ("cache_batch", "ssm_heads", None, None),
        "conv_x": ("cache_batch", None, "ssm_inner"),
        "conv_b": ("cache_batch", None, "ssm_state"),
        "conv_c": ("cache_batch", None, "ssm_state"),
    }
    return cache, specs


def _conv_step(hist: jax.Array, new: jax.Array, w: jax.Array):
    """hist (B, cw−1, C), new (B, C) → (activated output (B,C), new hist)."""
    seq = jnp.concatenate([hist, new[:, None, :].astype(hist.dtype)], axis=1)
    out = jax.nn.silu(jnp.einsum("bkc,kc->bc", seq.astype(w.dtype), w))
    return out, seq[:, 1:]


def ssm_decode(p, x: jax.Array, cache: Dict[str, jax.Array],
               cfg: ArchConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, D) one token; updates (h, conv_*) state."""
    bsz = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_headdim
    z, xr, br, cr, dt = _project(p, x[:, 0:1], cfg)
    z, xr, br, cr, dt = z[:, 0], xr[:, 0], br[:, 0], cr[:, 0], dt[:, 0]
    xi, new_cx = _conv_step(cache["conv_x"], xr, p["conv_x"])
    b_in, new_cb = _conv_step(cache["conv_b"], br, p["conv_b"])
    c_in, new_cc = _conv_step(cache["conv_c"], cr, p["conv_c"])
    xi = xi.reshape(bsz, h, hp)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None, :])                  # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, b_in.astype(jnp.float32),
                     xi.astype(jnp.float32))
    hnew = decay[:, :, None, None] * cache["h"] + upd
    y = jnp.einsum("bn,bhnp->bhp", c_in.astype(jnp.float32), hnew)
    y = y + p["d_skip"][None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(bsz, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"h": hnew, "conv_x": new_cx, "conv_b": new_cb,
                 "conv_c": new_cc}
