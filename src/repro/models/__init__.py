"""Model zoo: config-driven decoder LMs (dense / MoE / SSM / hybrid /
cross-attention) with grouped-scan stacks and KV/SSM decode caches."""
from .model import (decode_step, forward, forward_with_cache,
                    init_decode_cache, init_lm, init_lm_abstract, lm_loss)

__all__ = ["decode_step", "forward", "forward_with_cache",
           "init_decode_cache", "init_lm", "init_lm_abstract", "lm_loss"]
