"""Top-k Mixture-of-Experts with capacity-based GShard dispatch.

The dispatch/combine einsum formulation is used because it partitions
cleanly under GSPMD: with groups sharded over the data axes and experts
over the model axis, the dispatch einsums are local and the only
communication is the small router-logit all-gather — the TPU-idiomatic
analogue of the all-to-all in GPU MoE stacks.  For architectures whose
expert count does not divide the model axis (mixtral: 8e on a 16-wide
axis) the sharding rules fall back to expert-internal ``d_ff`` tensor
parallelism (DESIGN.md §5).

Tokens beyond an expert's capacity ``C = ceil(k·S·cf/E)`` are dropped
(their residual passes through) — standard GShard semantics; the aux
load-balancing loss keeps drops rare.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init


def init_moe(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["router"], s["router"] = dense_init(ks[0], (d, e), ("embed", "experts"),
                                          dtype=jnp.float32)
    p["w1"], s["w1"] = dense_init(ks[1], (e, d, f),
                                  ("experts", "embed", "expert_mlp"),
                                  dtype=dtype)
    p["w3"], s["w3"] = dense_init(ks[2], (e, d, f),
                                  ("experts", "embed", "expert_mlp"),
                                  dtype=dtype)
    p["w2"], s["w2"] = dense_init(ks[3], (e, f, d),
                                  ("experts", "expert_mlp", "embed"),
                                  dtype=dtype)
    return p, s


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = math.ceil(cfg.experts_per_token * tokens_per_group
                  * cfg.capacity_factor / cfg.num_experts)
    return max(4, min(c, tokens_per_group))


def moe_forward(p, x: jax.Array, cfg: ArchConfig, rules=None
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) — B doubles as the GShard group dimension.

    ``rules`` (ShardingRules, optional): when the strategy table maps
    ``moe_cap`` to a mesh axis, the capacity dimension of the dispatched
    tensors is sharded there — "capacity sharding", the §Perf fix for
    expert counts that do not divide the model axis (mixtral): expert
    compute splits 16-way over capacity slots and the only model-axis
    collective left is the small (B,S,D) combine all-reduce, instead of
    per-layer fp32 (B,E,C,D) partial-sum all-reduces.

    Returns (output, aux_loss).
    """
    def _c(t, *axes):
        if rules is not None:
            return rules.constrain(t, *axes)
        return t

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = capacity(cfg, s)
    router_logits = (x.astype(jnp.float32) @ p["router"])        # (B,S,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                   # renorm

    # Build dispatch/combine over capacity slots, processing the k choices
    # in priority order so earlier choices consume capacity first.
    dispatch = jnp.zeros((b, s, e, c), dtype=x.dtype)
    combine = jnp.zeros((b, s, e, c), dtype=jnp.float32)
    used = jnp.zeros((b, e), dtype=jnp.int32)
    for choice in range(k):
        idx_e = expert_idx[..., choice]                           # (B,S)
        onehot = jax.nn.one_hot(idx_e, e, dtype=jnp.int32)        # (B,S,E)
        pos_in_e = jnp.cumsum(onehot, axis=1) - onehot            # (B,S,E)
        pos = pos_in_e + used[:, None, :]                         # offset
        # One-hot contraction instead of take_along_axis: data-dependent
        # gathers force GSPMD to replicate the batch dim (§Perf iteration 2).
        pos_tok = jnp.sum(pos * onehot, axis=-1)                  # (B,S)
        fits = pos_tok < c
        slot = jax.nn.one_hot(jnp.where(fits, pos_tok, c), c + 1,
                              dtype=x.dtype)[..., :c]             # (B,S,C)
        sel = onehot.astype(x.dtype)[..., None] * slot[..., None, :]
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) * \
            gate_vals[..., choice][..., None, None]
        used = used + onehot.sum(axis=1)

    dispatch = _c(dispatch, "act_batch", None, "experts", "moe_cap")
    xe = jnp.einsum("bsec,bsd->becd", dispatch, x)                # (B,E,C,D)
    xe = _c(xe, "act_batch", "experts", "moe_cap", None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w1"])) * \
        jnp.einsum("becd,edf->becf", xe, p["w3"])
    h = _c(h, "act_batch", "experts", "moe_cap", None)
    ye = jnp.einsum("becf,efd->becd", h, p["w2"])                 # (B,E,C,D)
    ye = _c(ye, "act_batch", "experts", "moe_cap", None)
    y = jnp.einsum("bsec,becd->bsd", combine.astype(ye.dtype), ye)

    # GShard load-balancing auxiliary loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y.astype(x.dtype), aux
