"""Config-driven decoder LM: heterogeneous stacks under a grouped scan.

The layer stack is ``num_groups`` repetitions of the config's block
*pattern* (DESIGN.md §4): parameters are stacked per pattern position with
a leading ``layers`` axis and the stack executes as one ``lax.scan`` over
groups — keeping HLO size O(pattern) instead of O(num_layers), which is
what makes 100-layer dry-run compiles tractable and is the idiomatic TPU
training structure (MaxText-style).

Three entry points:
  * :func:`forward`      — full-sequence logits (train / prefill),
  * :func:`forward_with_cache` — prefill that also returns a decode cache,
  * :func:`decode_step`  — one-token decode against the cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import (FFN_DENSE, FFN_MOE, FFN_NONE, MIXER_ATTN,
                            MIXER_ATTN_LOCAL, MIXER_SSM, MIXER_XATTN,
                            ArchConfig)
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (embed_tokens, init_embed, init_mlp, lm_logits,
                     mlp_forward, rms_norm, zeros_init)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _window_for(cfg: ArchConfig, mixer: str) -> int:
    if mixer == MIXER_ATTN_LOCAL:
        return cfg.sliding_window
    return 0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(key: jax.Array, cfg: ArchConfig, spec) -> Tuple[Dict, Dict]:
    dt = _dtype(cfg)
    km, kf = jax.random.split(key)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["norm1"], s["norm1"] = zeros_init((cfg.d_model,), ("embed",), dt)
    if spec.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL):
        p["mixer"], s["mixer"] = attn.init_attention(km, cfg, dtype=dt)
    elif spec.mixer == MIXER_XATTN:
        p["mixer"], s["mixer"] = attn.init_attention(km, cfg, cross=True,
                                                     dtype=dt)
    elif spec.mixer == MIXER_SSM:
        p["mixer"], s["mixer"] = ssm_mod.init_ssm(km, cfg, dtype=dt)
    if spec.ffn != FFN_NONE:
        p["norm2"], s["norm2"] = zeros_init((cfg.d_model,), ("embed",), dt)
        if spec.ffn == FFN_MOE:
            p["ffn"], s["ffn"] = moe_mod.init_moe(kf, cfg, dtype=dt)
        else:
            p["ffn"], s["ffn"] = init_mlp(kf, cfg.d_model, cfg.d_ff, dtype=dt)
    return p, s


def init_lm(key: jax.Array, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    """Returns (params, logical-axis specs)."""
    dt = _dtype(cfg)
    pattern = cfg.pattern()
    g = cfg.num_groups()
    k_embed, k_blocks, k_norm = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = init_embed(
        k_embed, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings, dt)
    params["final_norm"], specs["final_norm"] = zeros_init(
        (cfg.d_model,), ("embed",), dt)
    blocks: List[Dict] = []
    bspecs: List[Dict] = []
    for i, spec in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(k_blocks, i), g)
        stacked = jax.vmap(lambda k, s=spec: _init_block(k, cfg, s)[0])(keys)
        _, sp = _init_block(keys[0], cfg, spec)
        from ..sharding.rules import is_logical_axes
        sp = jax.tree.map(lambda axes: ("layers",) + tuple(axes),
                          sp, is_leaf=is_logical_axes)
        blocks.append(stacked)
        bspecs.append(sp)
    params["blocks"] = tuple(blocks)
    specs["blocks"] = tuple(bspecs)
    return params, specs


def init_lm_abstract(key: jax.Array, cfg: ArchConfig):
    """Shape-only init (no allocation) — used by the dry-run."""
    return jax.eval_shape(lambda k: init_lm(k, cfg)[0], key)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _block_forward(bp, x, spec, cfg: ArchConfig, positions, image_embeds,
                   collect_cache: bool, max_seq: int, rules=None):
    aux = jnp.zeros((), jnp.float32)
    cache_out = {}
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    window = _window_for(cfg, spec.mixer)
    if spec.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL):
        if collect_cache:
            mix, cache_out = attn.prefill_attention(
                bp["mixer"], h, cfg, positions, window, max_seq)
        else:
            mix = attn.attention_forward(bp["mixer"], h, cfg, positions,
                                         window=window)
    elif spec.mixer == MIXER_XATTN:
        mix = attn.attention_forward(bp["mixer"], h, cfg, positions,
                                     cross_states=image_embeds)
    else:  # SSM
        if collect_cache:
            mix, cache_out = ssm_mod.prefill_ssm(bp["mixer"], h, cfg)
        else:
            mix = ssm_mod.ssm_forward(bp["mixer"], h, cfg)
    x = x + mix
    if spec.ffn != FFN_NONE:
        h2 = rms_norm(x, bp["norm2"], cfg.norm_eps)
        if spec.ffn == FFN_MOE:
            out, aux = moe_mod.moe_forward(bp["ffn"], h2, cfg, rules)
        else:
            out = mlp_forward(bp["ffn"], h2)
        x = x + out
    if rules is not None:
        x = rules.constrain(x, "act_batch", "act_seq", "act_embed")
    return x, aux, cache_out


def forward(params, tokens: jax.Array, cfg: ArchConfig,
            image_embeds: Optional[jax.Array] = None,
            remat: bool = True, rules=None) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) → (logits (B, S, V) fp32, aux loss).

    ``rules``: optional ShardingRules; when given, activations carry
    with_sharding_constraint at block boundaries (sequence parallelism and
    MoE capacity sharding are expressed this way — §Perf)."""
    pattern = cfg.pattern()
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    positions = jnp.arange(tokens.shape[1])[None, :]
    if rules is not None:
        x = rules.constrain(x, "act_batch", "act_seq", "act_embed")

    def group_fn(carry, group_params):
        x, aux = carry
        for i, spec in enumerate(pattern):
            x, a, _ = _block_forward(group_params[i], x, spec, cfg,
                                     positions, image_embeds, False, 0,
                                     rules=rules)
            aux = aux + a
        return (x, aux), None

    scan_fn = jax.checkpoint(
        group_fn, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False) if remat else group_fn
    (x, aux), _ = jax.lax.scan(scan_fn,
                               (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg.final_logit_softcap)
    return logits, aux


def lm_loss(params, tokens, labels, cfg: ArchConfig,
            image_embeds=None, aux_weight: float = 0.01,
            remat: bool = True, rules=None):
    logits, aux = forward(params, tokens, cfg, image_embeds, remat,
                          rules=rules)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # One-hot contraction, not take_along_axis: vocab is model-sharded and
    # data-dependent gathers de-shard the batch under GSPMD (§Perf it. 2).
    onehot = jax.nn.one_hot(labels.astype(jnp.int32), logits.shape[-1],
                            dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return ce + aux_weight * aux, (ce, aux)


# ---------------------------------------------------------------------------
# Decode (one token with cache)
# ---------------------------------------------------------------------------
def init_decode_cache(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16):
    """Cache pytree: tuple per pattern position, each stacked over groups."""
    pattern = cfg.pattern()
    g = cfg.num_groups()
    caches, specs = [], []
    for spec in pattern:
        if spec.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL):
            window = _window_for(cfg, spec.mixer)
            c, s = attn.init_kv_cache(cfg, batch, max_seq, window, dtype)
        elif spec.mixer == MIXER_SSM:
            c, s = ssm_mod.init_ssm_cache(cfg, batch)
        else:  # cross-attn: static image KV recomputed per step
            c, s = {"unused": jnp.zeros((1,), dtype)}, {"unused": (None,)}
        c = jax.tree.map(lambda a: jnp.broadcast_to(a, (g,) + a.shape), c)
        from ..sharding.rules import is_logical_axes
        s = jax.tree.map(lambda axes: ("layers",) + tuple(axes), s,
                         is_leaf=is_logical_axes)
        caches.append(c)
        specs.append(s)
    return tuple(caches), tuple(specs)


def decode_step(params, cache, token: jax.Array, pos: jax.Array,
                cfg: ArchConfig,
                image_embeds: Optional[jax.Array] = None):
    """token (B,) int32, pos () int32 → (logits (B, V), new cache)."""
    pattern = cfg.pattern()
    x = embed_tokens(params["embed"], token[:, None], cfg.d_model)

    def group_fn(x, inp):
        group_params, group_cache = inp
        new_cache = []
        for i, spec in enumerate(pattern):
            bp, c = group_params[i], group_cache[i]
            h = rms_norm(x, bp["norm1"], cfg.norm_eps)
            window = _window_for(cfg, spec.mixer)
            if spec.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL):
                mix, c = attn.decode_attention(bp["mixer"], h, c, pos, cfg,
                                               window)
            elif spec.mixer == MIXER_XATTN:
                mix = attn.attention_forward(
                    bp["mixer"], h, cfg, jnp.reshape(pos, (1, 1)),
                    cross_states=image_embeds)
            else:
                mix, c = ssm_mod.ssm_decode(bp["mixer"], h, c, cfg)
            x = x + mix
            if spec.ffn != FFN_NONE:
                h2 = rms_norm(x, bp["norm2"], cfg.norm_eps)
                if spec.ffn == FFN_MOE:
                    out, _ = moe_mod.moe_forward(bp["ffn"], h2, cfg)
                else:
                    out = mlp_forward(bp["ffn"], h2)
                x = x + out
            new_cache.append(c)
        return x, tuple(new_cache)

    x, new_cache = jax.lax.scan(group_fn, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, 0], cfg.final_logit_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill with cache collection
# ---------------------------------------------------------------------------
def forward_with_cache(params, tokens: jax.Array, cfg: ArchConfig,
                       max_seq: int,
                       image_embeds: Optional[jax.Array] = None):
    """Full-sequence forward that also returns the populated decode cache."""
    pattern = cfg.pattern()
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def group_fn(carry, group_params):
        x, aux = carry
        caches = []
        for i, spec in enumerate(pattern):
            x, a, c = _block_forward(group_params[i], x, spec, cfg,
                                     positions, image_embeds, True, max_seq)
            if not c:
                c = {"unused": jnp.zeros((1,), jnp.bfloat16)}
            aux = aux + a
            caches.append(c)
        return (x, aux), tuple(caches)

    (x, aux), cache = jax.lax.scan(group_fn,
                                   (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg.final_logit_softcap)
    return logits, cache, aux
