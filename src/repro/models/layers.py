"""Shared neural-net layers + the logical-axis parameter convention.

Every ``init_*`` function returns ``(params, specs)`` where ``specs``
mirrors the params pytree with tuples of *logical axis names* per leaf
(MaxText-style).  ``repro.sharding.rules`` maps logical names → mesh axes
per architecture (handling divisibility fallbacks), which is what makes
sharding strategies swappable during §Perf hillclimbing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Specs = Dict[str, Any]


def dense_init(key: jax.Array, shape: Tuple[int, ...], axes: Tuple,
               scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal dense init with fan-in scaling."""
    fan_in = shape[0] if len(shape) <= 2 else shape[-2]
    std = scale / max(fan_in, 1) ** 0.5
    arr = std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                            dtype=jnp.float32)
    return arr.astype(dtype), axes


def zeros_init(shape, axes, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), axes


def ones_init(shape, axes, dtype=jnp.float32):
    return jnp.ones(shape, dtype), axes


# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array,
           w2: jax.Array) -> jax.Array:
    """SwiGLU MLP: (silu(x·w1) ⊙ x·w3) · w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------
def init_mlp(key: jax.Array, d_model: int, d_ff: int,
             dtype=jnp.float32) -> Tuple[Params, Specs]:
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["w1"], s["w1"] = dense_init(k1, (d_model, d_ff), ("embed", "mlp"),
                                  dtype=dtype)
    p["w3"], s["w3"] = dense_init(k2, (d_model, d_ff), ("embed", "mlp"),
                                  dtype=dtype)
    p["w2"], s["w2"] = dense_init(k3, (d_ff, d_model), ("mlp", "embed"),
                                  dtype=dtype)
    return p, s


def mlp_forward(p: Params, x: jax.Array) -> jax.Array:
    return swiglu(x, p["w1"], p["w3"], p["w2"])


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def init_embed(key: jax.Array, vocab: int, d_model: int, tie: bool,
               dtype=jnp.float32) -> Tuple[Params, Specs]:
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["embedding"], s["embedding"] = dense_init(
        k1, (vocab, d_model), ("vocab", "embed"), scale=1.0, dtype=dtype)
    if not tie:
        p["head"], s["head"] = dense_init(
            k2, (d_model, vocab), ("embed", "vocab"), dtype=dtype)
    return p, s


def embed_tokens(p: Params, tokens: jax.Array, d_model: int) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def lm_logits(p: Params, x: jax.Array, cap: float = 0.0) -> jax.Array:
    if "head" in p:
        logits = x @ p["head"]
    else:
        logits = x @ p["embedding"].T
    return softcap(logits.astype(jnp.float32), cap)
