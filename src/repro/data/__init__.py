"""Data pipeline: federation-backed token shards + loader."""
from .dataset import DatasetSpec, SyntheticTokens, decode_tokens
from .loader import FederatedDataLoader, LoaderStats

__all__ = ["DatasetSpec", "SyntheticTokens", "decode_tokens",
           "FederatedDataLoader", "LoaderStats"]
