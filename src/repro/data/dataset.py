"""Token datasets as federation objects.

Training data lives at the origin as fixed-size *shard files* of packed
token ids under ``/datasets/<name>/shard_XXXXX.bin`` — each shard is an
ordinary federation object, chunked and checksummed like everything else
(CVMFS chunk semantics give the loader partial reads: a worker fetches
only the 24 MB chunks covering its slice of a shard).

``SyntheticTokens`` generates deterministic shards (seeded per shard) so
examples/tests run without external data while exercising the full
origin→cache→client byte path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.origin import Origin

TOKEN_DTYPE = np.int32


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    vocab_size: int
    tokens_per_shard: int = 1 << 20          # 4 MiB per shard at int32
    num_shards: int = 64
    seed: int = 1234

    @property
    def prefix(self) -> str:
        return f"/datasets/{self.name}"

    def shard_path(self, idx: int) -> str:
        return f"{self.prefix}/shard_{idx:05d}.bin"

    @property
    def shard_bytes(self) -> int:
        return self.tokens_per_shard * TOKEN_DTYPE().itemsize


class SyntheticTokens:
    """Deterministic synthetic token shards (a Zipf-ish unigram stream)."""

    def __init__(self, spec: DatasetSpec) -> None:
        self.spec = spec

    def shard_array(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng(self.spec.seed + idx)
        # Zipf-like marginal over the vocab, cheap to sample.
        u = rng.random(self.spec.tokens_per_shard)
        toks = (self.spec.vocab_size *
                (u ** 2.2)).astype(TOKEN_DTYPE) % self.spec.vocab_size
        return toks

    def shard_bytes(self, idx: int) -> bytes:
        return self.shard_array(idx).tobytes()

    def publish(self, origin: Origin, shards: Optional[int] = None,
                mtime: float = 0.0) -> List[str]:
        """Upload shards to the origin (the researcher's data staging)."""
        paths = []
        for i in range(shards if shards is not None else self.spec.num_shards):
            path = self.spec.shard_path(i)
            origin.put_object(path, self.shard_bytes(i), mtime=mtime)
            paths.append(path)
        return paths


def decode_tokens(raw: bytes) -> np.ndarray:
    return np.frombuffer(raw, dtype=TOKEN_DTYPE)
