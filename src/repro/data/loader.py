"""FederatedDataLoader — the paper's data path feeding a JAX train loop.

Each training step needs ``(global_batch × seq_len)`` tokens.  The loader
maps ``step → (shard, offset)`` deterministically (restart-safe: resuming
at step k re-reads exactly the right slice), issues ranged ``cvmfs``
:class:`~repro.core.api.FetchRequest`s against the federation's
:class:`~repro.core.api.DataPlane` (partial reads — only the chunks
overlapping the slice move), and assembles the batch.

Fleet behaviours layered on the paper's data plane:
  * **prefetch** — a sliding window of future steps is fetched eagerly so
    the accelerator never waits on the federation (double buffering);
  * **straggler mitigation / hedging** — if a fetch is a straggler vs the
    recent median (``hedge_after``×), it is re-issued with
    ``FetchRequest.avoid`` naming the cache that served it, racing the
    next-nearest replica;
  * **locality accounting** — every :class:`~repro.core.api.FetchResult`
    folds into a :class:`~repro.core.monitoring.FetchRollup`, the unified
    per-consumer stats model the monitoring pipeline aggregates (paper
    Fig. 4 / Table 1, but for training traffic).

Migration from the pre-DataPlane API:

    ===============================  =====================================
    before (deprecated)              after
    ===============================  =====================================
    ``FederatedDataLoader(          ``plane = AnalyticPlane(fed)``
    client, spec, ...)``             ``FederatedDataLoader(plane, spec,
                                     ..., site="pod0", worker=0)``
    ``loader.stats`` (LoaderStats)   ``loader.stats`` (FetchRollup —
                                     same field names plus per-method
                                     breakdown)
    ===============================  =====================================

Passing a bare ``StashClient`` still works — it is wrapped in a
:class:`~repro.core.api.ClientPlane` with a ``DeprecationWarning``.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Deque, Dict, Iterator, List, Tuple

import numpy as np

from ..core.api import ClientPlane, DataPlane, FetchRequest
from ..core.monitoring import FetchRollup
from .dataset import DatasetSpec, TOKEN_DTYPE, decode_tokens

# The loader's stats *are* the unified rollup now; the old name stays
# importable for pre-redesign call sites.
LoaderStats = FetchRollup


class FederatedDataLoader:
    """Deterministic step→tokens mapping over federation shard objects."""

    def __init__(self, plane: DataPlane, spec: DatasetSpec,
                 global_batch: int, seq_len: int,
                 rank: int = 0, world: int = 1,
                 prefetch: int = 2,
                 hedge_after: float = 4.0,
                 site: str = "", worker: int = 0) -> None:
        if not hasattr(plane, "fetch"):
            # Legacy call site: first argument was a bare StashClient.
            warnings.warn(
                "FederatedDataLoader(client=...) is deprecated; pass a "
                "DataPlane (e.g. AnalyticPlane(fed)) and site/worker",
                DeprecationWarning, stacklevel=2)
            plane = ClientPlane(client=plane)
        self.plane = plane
        self.spec = spec
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.rank = rank
        self.world = world
        self.prefetch_depth = prefetch
        self.hedge_after = hedge_after
        self.site = site
        self.worker = worker
        self.stats = FetchRollup("loader")
        self._buffer: Dict[int, np.ndarray] = {}
        self._fetch_times: Deque[float] = collections.deque(maxlen=32)

    # -- step → data mapping -------------------------------------------------
    @property
    def tokens_per_step(self) -> int:
        # +1 token so labels are inputs shifted by one.
        per_rank_rows = self.global_batch // self.world
        return per_rank_rows * (self.seq_len + 1)

    def slices_for_step(self, step: int) -> List[Tuple[int, int, int]]:
        """[(shard_idx, token_offset, token_count)] covering this step's
        slice for this rank (deterministic, restart-safe)."""
        need = self.tokens_per_step
        start_tok = (step * self.global_batch // self.world
                     * (self.seq_len + 1)
                     + self.rank * need)
        out = []
        while need > 0:
            pos = start_tok % (self.spec.tokens_per_shard
                               * self.spec.num_shards)
            shard = pos // self.spec.tokens_per_shard
            off = pos % self.spec.tokens_per_shard
            take = min(need, self.spec.tokens_per_shard - off)
            out.append((shard, off, take))
            start_tok += take
            need -= take
        return out

    # -- fetching -----------------------------------------------------------
    def _fetch_slice(self, shard: int, tok_off: int,
                     tok_count: int) -> np.ndarray:
        itemsize = TOKEN_DTYPE().itemsize
        req = FetchRequest(
            path=self.spec.shard_path(shard), site=self.site,
            worker=self.worker, method="cvmfs",
            offset=tok_off * itemsize, length=tok_count * itemsize,
            want_data=True, tenant="loader")
        res = self.plane.fetch(req)
        self.stats.add(res)
        if not res.ok:
            raise RuntimeError(f"shard fetch failed: {res.error}")
        # Hedge: if this fetch is a straggler vs the recent median,
        # re-issue avoiding the cache that served it and take the fast
        # copy (the next-nearest replica races the straggler).
        if self._fetch_times and res.source and res.seconds > \
                self.hedge_after * float(np.median(self._fetch_times)):
            self.stats.hedged += 1
            res2 = self.plane.fetch(
                dataclasses.replace(req, avoid=res.source))
            self.stats.add(res2)
            if res2.ok and res2.seconds < res.seconds and \
                    res2.data is not None:
                res = res2
        self._fetch_times.append(res.seconds)
        if res.data is None:
            raise RuntimeError(
                f"plane {self.plane.name!r} returned no bytes for "
                f"{req.path!r}; the loader needs a byte-bearing plane "
                f"(analytic)")
        return decode_tokens(res.data)

    def fetch_step(self, step: int) -> np.ndarray:
        if step in self._buffer:
            return self._buffer.pop(step)
        parts = [self._fetch_slice(*s) for s in self.slices_for_step(step)]
        flat = np.concatenate(parts)
        rows = self.global_batch // self.world
        return flat.reshape(rows, self.seq_len + 1)

    def prefetch(self, next_step: int) -> None:
        for s in range(next_step, next_step + self.prefetch_depth):
            if s not in self._buffer:
                parts = [self._fetch_slice(*sl)
                         for sl in self.slices_for_step(s)]
                rows = self.global_batch // self.world
                self._buffer[s] = np.concatenate(parts).reshape(
                    rows, self.seq_len + 1)

    # -- the train-loop interface ----------------------------------------------
    def batch(self, step: int) -> Dict[str, np.ndarray]:
        arr = self.fetch_step(step)
        self.stats.tick()
        self.prefetch(step + 1)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
