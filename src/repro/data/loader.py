"""FederatedDataLoader — the paper's data path feeding a JAX train loop.

Each training step needs ``(global_batch × seq_len)`` tokens.  The loader
maps ``step → (shard, offset)`` deterministically (restart-safe: resuming
at step k re-reads exactly the right slice), fetches the covering chunks
from the *nearest pod cache* via the CVMFS-style client (partial reads —
only the chunks overlapping the slice move), and assembles the batch.

Fleet behaviours layered on the paper's client:
  * **prefetch** — a sliding window of future steps is fetched eagerly so
    the accelerator never waits on the federation (double buffering);
  * **straggler mitigation / hedging** — if the nearest cache is down or
    a fetch estimate exceeds ``hedge_after`` × the median, the fetch is
    retried against the next-nearest cache (the client's failover chain);
  * **locality accounting** — per-step TransferStats feed the monitoring
    pipeline, so cache hit rates during training are observable exactly
    like paper Fig. 4.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core.client import StashClient
from ..core.transfer import TransferStats
from .dataset import DatasetSpec, TOKEN_DTYPE, decode_tokens


@dataclasses.dataclass
class LoaderStats:
    steps: int = 0
    bytes_fetched: int = 0
    fetch_seconds: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    hedged: int = 0

    @property
    def hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0


class FederatedDataLoader:
    """Deterministic step→tokens mapping over federation shard objects."""

    def __init__(self, client: StashClient, spec: DatasetSpec,
                 global_batch: int, seq_len: int,
                 rank: int = 0, world: int = 1,
                 prefetch: int = 2,
                 hedge_after: float = 4.0) -> None:
        self.client = client
        self.spec = spec
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.rank = rank
        self.world = world
        self.prefetch_depth = prefetch
        self.hedge_after = hedge_after
        self.stats = LoaderStats()
        self._buffer: Dict[int, np.ndarray] = {}
        self._fetch_times: Deque[float] = collections.deque(maxlen=32)

    # -- step → data mapping -------------------------------------------------
    @property
    def tokens_per_step(self) -> int:
        # +1 token so labels are inputs shifted by one.
        per_rank_rows = self.global_batch // self.world
        return per_rank_rows * (self.seq_len + 1)

    def slices_for_step(self, step: int) -> List[Tuple[int, int, int]]:
        """[(shard_idx, token_offset, token_count)] covering this step's
        slice for this rank (deterministic, restart-safe)."""
        need = self.tokens_per_step
        start_tok = (step * self.global_batch // self.world
                     * (self.seq_len + 1)
                     + self.rank * need)
        out = []
        while need > 0:
            pos = start_tok % (self.spec.tokens_per_shard
                               * self.spec.num_shards)
            shard = pos // self.spec.tokens_per_shard
            off = pos % self.spec.tokens_per_shard
            take = min(need, self.spec.tokens_per_shard - off)
            out.append((shard, off, take))
            start_tok += take
            need -= take
        return out

    # -- fetching -----------------------------------------------------------
    def _fetch_slice(self, shard: int, tok_off: int,
                     tok_count: int) -> np.ndarray:
        path = self.spec.shard_path(shard)
        byte_off = tok_off * TOKEN_DTYPE().itemsize
        byte_len = tok_count * TOKEN_DTYPE().itemsize
        local_before = self.client.stats.local_hits
        raw, st = self.client.read(path, offset=byte_off, length=byte_len)
        self._account(st)
        # the worker-local (CVMFS) cache is the best hit of all
        self.stats.cache_hits += self.client.stats.local_hits - local_before
        # Hedge: if this fetch is a straggler vs the recent median,
        # retry against the next-nearest cache and take the fast copy.
        if self._fetch_times and st.seconds > self.hedge_after * \
                float(np.median(self._fetch_times)):
            self.stats.hedged += 1
            self.client.stats.hedged_fetches = getattr(
                self.client.stats, "hedged_fetches", 0) + 1
            primary = self.client.geoip.nearest(
                self.client.node.name, list(self.client.caches))[0]
            backup = self.client.caches.get(primary)
            if backup is not None:
                backup_was = backup.available
                backup.available = False       # force next-nearest
                try:
                    raw2, st2 = self.client.read(path, offset=byte_off,
                                                 length=byte_len)
                    self._account(st2)
                    if st2.seconds < st.seconds and raw2 is not None:
                        raw = raw2
                finally:
                    backup.available = backup_was
        self._fetch_times.append(st.seconds)
        return decode_tokens(raw)

    def _account(self, st: TransferStats) -> None:
        self.stats.bytes_fetched += st.bytes
        self.stats.fetch_seconds += st.seconds
        self.stats.cache_hits += st.cache_hits
        self.stats.cache_misses += st.cache_misses

    def fetch_step(self, step: int) -> np.ndarray:
        if step in self._buffer:
            return self._buffer.pop(step)
        parts = [self._fetch_slice(*s) for s in self.slices_for_step(step)]
        flat = np.concatenate(parts)
        rows = self.global_batch // self.world
        return flat.reshape(rows, self.seq_len + 1)

    def prefetch(self, next_step: int) -> None:
        for s in range(next_step, next_step + self.prefetch_depth):
            if s not in self._buffer:
                parts = [self._fetch_slice(*sl)
                         for sl in self.slices_for_step(s)]
                rows = self.global_batch // self.world
                self._buffer[s] = np.concatenate(parts).reshape(
                    rows, self.seq_len + 1)

    # -- the train-loop interface ----------------------------------------------
    def batch(self, step: int) -> Dict[str, np.ndarray]:
        arr = self.fetch_step(step)
        self.stats.steps += 1
        self.prefetch(step + 1)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
