"""Logical-axis → mesh-axis rules (the sharding strategy layer).

Axis roles over the production mesh (DESIGN.md §5):
  * ``pod``   — pure data parallelism.  Cross-pod traffic is one gradient
    all-reduce per step; everything else stays inside a pod.  This is the
    StashCache principle applied to the compute plane: the DCN/WAN carries
    each byte once.
  * ``data``  — FSDP: parameters/optimizer sharded on a weight dim,
    re-gathered per layer under the scan; batch also sharded here.
  * ``model`` — tensor parallelism (heads / d_ff / experts / d_inner) and
    sequence sharding for decode KV caches.

Rules are *resolved per architecture*: a logical axis maps to a mesh axis
only when the dimension divides the axis size; otherwise it falls back to
replication (e.g. gemma2's 8 heads on a 16-wide model axis, mixtral's 8
experts → expert-internal d_ff TP instead).  Strategy overrides are how
§Perf hillclimbing swaps sharding schemes without touching model code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


MeshAxes = Optional[Tuple[str, ...]]


def is_logical_axes(x) -> bool:
    """Leaf predicate for spec trees: a tuple of axis names (str|None).
    Structural tuples (e.g. the per-position blocks tuple) contain dicts
    and must NOT be treated as leaves."""
    return isinstance(x, tuple) and \
        all(e is None or isinstance(e, str) for e in x)


@dataclasses.dataclass
class ShardingRules:
    """Resolved logical-axis table for one (arch, mesh, shape) cell."""

    mesh: Mesh
    table: Dict[str, Any]

    def mesh_axes(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.table.get(logical)

    def spec(self, logical_axes: Tuple) -> P:
        used = set()
        out = []
        for ax in logical_axes:
            m = self.mesh_axes(ax)
            if m is None:
                out.append(None)
                continue
            axes = (m,) if isinstance(m, str) else tuple(m)
            axes = tuple(a for a in axes if a not in used)
            used.update(axes)
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(axes)
        return P(*out)

    def sharding(self, logical_axes: Tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes))

    def tree_shardings(self, spec_tree):
        return jax.tree.map(
            lambda axes: self.sharding(axes),
            spec_tree, is_leaf=is_logical_axes)

    def constrain(self, x, *logical_axes):
        """with_sharding_constraint helper for activations."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(tuple(logical_axes)))


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _fits(dim: int, mesh: Mesh, name) -> bool:
    return dim % _axis_size(mesh, name) == 0


def make_rules(cfg: ArchConfig, mesh: Mesh,
               global_batch: int = 0,
               overrides: Optional[Dict[str, Any]] = None) -> ShardingRules:
    """Resolve the logical table for an architecture on a mesh.

    ``overrides`` (logical → mesh axes or None) implement alternative
    strategies during perf iteration.
    """
    has_pod = "pod" in mesh.shape
    dp = ("pod", "data") if has_pod else ("data",)
    model = "model"

    t: Dict[str, Any] = {}
    # --- parameters -------------------------------------------------------
    t["layers"] = None
    t["vocab"] = model if _fits(cfg.vocab_size, mesh, model) else None
    t["embed"] = "data" if _fits(cfg.d_model, mesh, "data") else None
    t["q_heads"] = model if cfg.num_heads and _fits(
        cfg.resolved_num_heads, mesh, model) else None
    t["kv_heads"] = model if cfg.num_kv_heads and _fits(
        cfg.num_kv_heads, mesh, model) else None
    t["head_dim"] = None
    t["mlp"] = model if cfg.d_ff and _fits(cfg.d_ff, mesh, model) else None
    if cfg.num_experts:
        if _fits(cfg.num_experts, mesh, model):
            t["experts"] = model
            t["expert_mlp"] = None
        else:
            t["experts"] = None
            t["expert_mlp"] = model if _fits(cfg.d_ff, mesh, model) else None
    else:
        t["experts"] = t["expert_mlp"] = None
    t["moe_cap"] = None
    if cfg.ssm_state:
        t["ssm_inner"] = model if _fits(cfg.d_inner, mesh, model) else None
        t["ssm_state"] = None
        t["ssm_heads"] = None
        t["conv"] = None
    # --- activations --------------------------------------------------------
    if global_batch and global_batch % _axis_size(mesh, dp) == 0:
        t["act_batch"] = dp
    elif global_batch and global_batch % mesh.shape["data"] == 0:
        t["act_batch"] = ("data",)
    else:
        t["act_batch"] = None
    t["act_seq"] = None
    t["act_embed"] = None
    t["act_vocab"] = t["vocab"]
    t["img"] = None
    # --- decode caches -------------------------------------------------------
    if global_batch and global_batch % _axis_size(mesh, dp) == 0:
        t["cache_batch"] = dp
        t["cache_seq"] = model
    else:
        # tiny-batch long-context: spread the sequence everywhere
        t["cache_batch"] = None
        t["cache_seq"] = ("data", "model") if not has_pod else \
            ("pod", "data", "model")
    if overrides:
        t.update(overrides)
    return ShardingRules(mesh=mesh, table=t)


def batch_specs(rules: ShardingRules, kind: str) -> Dict[str, P]:
    """PartitionSpecs for step inputs by shape kind."""
    if kind == "train":
        return {"tokens": rules.spec(("act_batch", "act_seq")),
                "labels": rules.spec(("act_batch", "act_seq")),
                "image_embeds": rules.spec(("act_batch", "img", "act_embed"))}
    if kind == "prefill":
        return {"tokens": rules.spec(("act_batch", "act_seq")),
                "image_embeds": rules.spec(("act_batch", "img", "act_embed"))}
    return {"token": rules.spec(("act_batch",)),
            "pos": P(),
            "image_embeds": rules.spec(("act_batch", "img", "act_embed"))}
