"""Gradient compression for the cross-pod (DCN) all-reduce.

The multi-pod mesh's only WAN-class traffic is the per-step gradient
all-reduce over the ``pod`` axis (DESIGN.md §5) — the compute-plane twin
of the origin traffic StashCache exists to kill.  Blockwise int8
quantisation with **error feedback** cuts those bytes 2× vs bf16 / 4× vs
fp32: the quantisation residual is carried to the next step instead of
being dropped, which preserves convergence (EF-SGD family).

Two entry points:
  * :func:`quantize` / :func:`dequantize` — the codec (blockwise absmax);
  * :class:`ErrorFeedback` — residual-carrying compressor for a gradient
    pytree, used by the Trainer's ``grad_compression="int8_ef"`` mode and
    available to a shard_map'd psum for explicit wire compression.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x: jax.Array, block: int = BLOCK) -> Dict[str, jax.Array]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize(enc: Dict[str, jax.Array], shape) -> jax.Array:
    flat = (enc["q"].astype(jnp.float32) * enc["scale"]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def wire_bytes(shape, dtype_bytes: int = 4, block: int = BLOCK) -> Tuple[int, int]:
    """(uncompressed, compressed) bytes for a tensor of ``shape``."""
    n = 1
    for d in shape:
        n *= d
    blocks = -(-n // block)
    return n * dtype_bytes, n * 1 + blocks * 4


class ErrorFeedback:
    """Residual-carrying int8 compressor over a gradient pytree."""

    def __init__(self) -> None:
        self.residual = None

    def init(self, grads):
        self.residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        return self.residual

    @staticmethod
    def compress(grads, residual):
        """Returns (decompressed grads as transmitted, new residual)."""
        def one(g, r):
            target = g.astype(jnp.float32) + r
            enc = quantize(target)
            sent = dequantize(enc, g.shape)
            return sent.astype(g.dtype), target - sent

        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = tdef.flatten_up_to(residual)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return tdef.unflatten([o[0] for o in out]), \
            tdef.unflatten([o[1] for o in out])
