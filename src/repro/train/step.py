"""Train / prefill / decode step builders with explicit shardings.

These are the functions the multi-pod dry-run lowers and the trainer runs:
  * ``make_train_step``  — loss → grads → AdamW update, donated state;
  * ``make_prefill_step`` — full-sequence logits (serving prefill);
  * ``make_decode_step`` — one token against the KV/SSM cache, donated.

All shardings come from :mod:`repro.sharding.rules`; microbatch gradient
accumulation (for memory-constrained cells) is a ``lax.scan`` over the
leading microbatch split.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import decode_step as model_decode_step
from ..models import forward, init_decode_cache, init_lm, lm_loss
from ..models.model import init_lm_abstract
from ..sharding.rules import ShardingRules, batch_specs, make_rules
from .optimizer import AdamWConfig, adamw_update, init_opt_state, \
    opt_state_specs


@dataclasses.dataclass
class StepArtifacts:
    """Everything needed to lower/compile one step for one cell."""

    fn: Any                      # the jit-able python callable
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: Tuple      # ShapeDtypeStructs for .lower()
    donate_argnums: Tuple = ()


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


# ---------------------------------------------------------------------------
def build_train_step(cfg: ArchConfig, rules: ShardingRules,
                     opt_cfg: AdamWConfig,
                     global_batch: int, seq_len: int,
                     microbatches: int = 1,
                     aux_weight: float = 0.01):
    """Returns StepArtifacts for the training step."""
    # --- abstract state -------------------------------------------------------
    abs_params = init_lm_abstract(jax.random.PRNGKey(0), cfg)
    specs = spec_tree(cfg)
    p_shard = rules.tree_shardings(specs)
    abs_opt = jax.eval_shape(
        functools.partial(init_opt_state, cfg=opt_cfg), abs_params)
    o_specs = opt_state_specs(specs, opt_cfg)
    o_shard = opt_shardings(o_specs, rules)
    state_shardings = {"params": p_shard, "opt": o_shard}

    bspecs = batch_specs(rules, "train")
    tok_shard = NamedSharding(rules.mesh, bspecs["tokens"])
    batch_in = {
        "tokens": _sds((global_batch, seq_len), jnp.int32, tok_shard),
        "labels": _sds((global_batch, seq_len), jnp.int32, tok_shard),
    }
    if cfg.num_image_tokens:
        batch_in["image_embeds"] = _sds(
            (global_batch, cfg.num_image_tokens, cfg.d_model),
            jnp.bfloat16,
            NamedSharding(rules.mesh, bspecs["image_embeds"]))

    use_rules = rules

    def loss_fn(params, batch):
        img = batch.get("image_embeds")
        return lm_loss(params, batch["tokens"], batch["labels"], cfg,
                       image_embeds=img, aux_weight=aux_weight,
                       rules=use_rules)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if microbatches > 1:
            def micro(gsum, mb):
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return jax.tree.map(jnp.add, gsum, g), l
            split = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, zeros, split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = losses.mean()
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        new_params, new_opt, metrics = adamw_update(grads, opt, params,
                                                    opt_cfg)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    abs_state = {"params": abs_params, "opt": abs_opt}
    abs_state = attach_shardings(abs_state, state_shardings)
    metric_shard = NamedSharding(rules.mesh, P())
    out_shardings = (state_shardings,
                     {"loss": metric_shard, "grad_norm": metric_shard,
                      "lr": metric_shard})
    return StepArtifacts(
        fn=train_step,
        in_shardings=(state_shardings,
                      {k: v.sharding for k, v in batch_in.items()}),
        out_shardings=out_shardings,
        abstract_inputs=(abs_state, batch_in),
        donate_argnums=(0,),
    )


def build_prefill_step(cfg: ArchConfig, rules: ShardingRules,
                       global_batch: int, seq_len: int):
    abs_params = init_lm_abstract(jax.random.PRNGKey(0), cfg)
    specs = spec_tree(cfg)
    p_shard = rules.tree_shardings(specs)
    bspecs = batch_specs(rules, "prefill")
    tok_shard = NamedSharding(rules.mesh, bspecs["tokens"])
    inputs = {"tokens": _sds((global_batch, seq_len), jnp.int32, tok_shard)}
    if cfg.num_image_tokens:
        inputs["image_embeds"] = _sds(
            (global_batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16,
            NamedSharding(rules.mesh, bspecs["image_embeds"]))

    # §Perf: activation pinning helps diseased train cells but measurably
    # hurts prefill (feature-sharded activations are the better layout
    # there) — prefill keeps GSPMD's own propagation.
    use_rules = None

    def prefill(params, batch):
        logits, _ = forward(params, batch["tokens"], cfg,
                            image_embeds=batch.get("image_embeds"),
                            rules=use_rules)
        # Serving prefill only needs the last-position logits.
        return logits[:, -1, :]

    logits_shard = NamedSharding(
        rules.mesh, rules.spec(("act_batch", "act_vocab")))
    return StepArtifacts(
        fn=prefill,
        in_shardings=(p_shard, {k: v.sharding for k, v in inputs.items()}),
        out_shardings=logits_shard,
        abstract_inputs=(attach_shardings(abs_params, p_shard), inputs),
    )


def build_decode_step(cfg: ArchConfig, rules: ShardingRules,
                      global_batch: int, max_seq: int):
    abs_params = init_lm_abstract(jax.random.PRNGKey(0), cfg)
    specs = spec_tree(cfg)
    p_shard = rules.tree_shardings(specs)
    abs_cache, cspecs = eval_cache(cfg, global_batch, max_seq)
    c_shard = rules.tree_shardings(cspecs)

    bspecs = batch_specs(rules, "decode")
    tok_shard = NamedSharding(rules.mesh, bspecs["token"])
    inputs = {
        "token": _sds((global_batch,), jnp.int32, tok_shard),
        "pos": _sds((), jnp.int32, NamedSharding(rules.mesh, P())),
    }
    img_shard = None
    if cfg.num_image_tokens:
        img_shard = NamedSharding(rules.mesh, bspecs["image_embeds"])
        inputs["image_embeds"] = _sds(
            (global_batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16,
            img_shard)

    def decode(params, cache, batch):
        logits, new_cache = model_decode_step(
            params, cache, batch["token"], batch["pos"], cfg,
            image_embeds=batch.get("image_embeds"))
        return logits, new_cache

    logits_shard = NamedSharding(
        rules.mesh, rules.spec(("act_batch", "act_vocab")))
    return StepArtifacts(
        fn=decode,
        in_shardings=(p_shard, c_shard,
                      {k: v.sharding for k, v in inputs.items()}),
        out_shardings=(logits_shard, c_shard),
        abstract_inputs=(attach_shardings(abs_params, p_shard),
                         attach_shardings(abs_cache, c_shard), inputs),
        donate_argnums=(1,),
    )


# ---------------------------------------------------------------------------
# Spec plumbing helpers
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _spec_tree_cached(cfg: ArchConfig):
    _, specs = init_lm(jax.random.PRNGKey(0), _tiny_like(cfg))
    return specs


def spec_tree(cfg: ArchConfig):
    """Logical-axis specs for params (structure-identical to init_lm)."""
    return _spec_tree_cached(cfg)


def _tiny_like(cfg: ArchConfig) -> ArchConfig:
    """A minimum-size config with identical *structure* (same pattern,
    same param tree) so spec trees can be built without big allocs."""
    period = len(cfg.pattern())
    return dataclasses.replace(
        cfg,
        num_layers=period,
        d_model=16,
        num_heads=min(cfg.num_heads, 2) if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=8 if cfg.num_heads else 0,
        d_ff=32 if cfg.d_ff else 0,
        vocab_size=64,
        num_experts=min(cfg.num_experts, 2) if cfg.num_experts else 0,
        ssm_state=8 if cfg.ssm_state else 0,
        ssm_headdim=8 if cfg.ssm_state else cfg.ssm_headdim,
        num_image_tokens=4 if cfg.num_image_tokens else 0,
    )


def eval_cache(cfg: ArchConfig, batch: int, max_seq: int):
    abs_cache = jax.eval_shape(
        lambda: init_decode_cache(cfg, batch, max_seq)[0])
    _, cspecs = init_decode_cache(_tiny_like(cfg), 1, 8)
    return abs_cache, cspecs


def attach_shardings(abs_tree, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_tree, shardings)


def opt_shardings(o_specs, rules: ShardingRules):
    from ..sharding.rules import is_logical_axes

    def one(axes):
        if isinstance(axes, dict):  # int8 moment codec
            return {k: NamedSharding(rules.mesh, P())
                    for k in ("q", "scale")}
        return rules.sharding(tuple(axes))

    is_leaf = lambda x: is_logical_axes(x) or (  # noqa: E731
        isinstance(x, dict) and "q" in x)
    return jax.tree.map(one, o_specs, is_leaf=is_leaf)
