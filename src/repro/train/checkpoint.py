"""Federation-backed checkpointing — restart storms through pod caches.

Saves go through the data plane's **write path** (``DataPlane.store``,
the paper's §6 write-back future work): the training job acks as soon as
bytes land in the pod cache; ``DataPlane.drain`` pushes dirty objects to
the origin under a rate limit so a 512-host synchronous save cannot melt
the storage fabric.

Restores are the paper's headline scenario inverted onto the fleet: after
a preemption, every host of a pod re-reads the same checkpoint objects —
the first reader warms the pod cache and the other N−1 hit it, so the
origin sees each byte once per pod instead of once per host (measured in
``benchmarks/bench_restart_storm.py``).

Layout: one federation object per parameter leaf (so a host restoring a
*shard* fetches only the leaves it owns) plus a JSON manifest:

    /ckpt/<run>/step_<k>/manifest.json
    /ckpt/<run>/step_<k>/<leaf.path>.npy

Migration from the pre-DataPlane API:

    ===================================  =================================
    before (deprecated)                  after
    ===================================  =================================
    ``FederatedCheckpointer(run,         ``plane = AnalyticPlane(fed)``
    writeback, client)``                 ``FederatedCheckpointer(run,
                                         plane, site="pod0", worker=0)``
    ``save(...) -> TransferStats``       ``save(...) -> FetchResult``
    ``restore(...) ->                    ``restore(...) ->
    (tree, TransferStats)``              (tree, FetchResult)``
    ``ck.stats`` (CheckpointStats)       ``ck.stats`` (FetchRollup:
                                         ``bytes_stored``/``bytes_fetched``
                                         replace ``save_bytes``/
                                         ``restore_bytes``)
    ===================================  =================================

The legacy ``(run, writeback, client)`` form still works — the pair is
wrapped in a :class:`~repro.core.api.ClientPlane` with a
``DeprecationWarning``.
"""
from __future__ import annotations

import io
import json
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.api import ClientPlane, DataPlane, FetchRequest, FetchResult
from ..core.monitoring import FetchRollup


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _encode_array(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _decode_array(raw: bytes) -> np.ndarray:
    return np.load(io.BytesIO(raw), allow_pickle=False)


def _fold(agg: FetchResult, res: FetchResult) -> None:
    agg.seconds += res.seconds
    agg.bytes += res.bytes
    agg.chunks += res.chunks
    agg.cache_hits += res.cache_hits
    agg.cache_misses += res.cache_misses
    agg.local_hits += res.local_hits
    agg.size = agg.bytes


class FederatedCheckpointer:
    """Checkpoint save/restore through a :class:`DataPlane`."""

    def __init__(self, run: str, plane: DataPlane, client=None, *,
                 site: str = "", worker: int = 0) -> None:
        if not hasattr(plane, "fetch"):
            # Legacy call site: (run, writeback, client).
            warnings.warn(
                "FederatedCheckpointer(run, writeback, client) is "
                "deprecated; pass a DataPlane (e.g. AnalyticPlane(fed)) "
                "and site/worker", DeprecationWarning, stacklevel=2)
            plane = ClientPlane(client=client, writeback=plane)
        self.run = run
        self.plane = plane
        self.site = site
        self.worker = worker
        self.stats = FetchRollup("checkpointer")
        self.leaves = 0

    def prefix(self, step: int) -> str:
        return f"/ckpt/{self.run}/step_{step:08d}"

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, drain: bool = True) -> FetchResult:
        """Write state through the plane's write-back path; optionally
        drain to the origin now.  Returns the aggregate store result
        (drain time is accounted in ``stats``, not the return — acks
        happen at cache residency)."""
        agg = FetchResult(path=self.prefix(step), method="checkpoint-save",
                          plane=getattr(self.plane, "name", ""))
        manifest = {"step": step, "leaves": []}
        for name, leaf in _leaf_paths(state):
            arr = np.asarray(leaf)
            if arr.dtype == jax.numpy.bfloat16:
                arr = arr.astype(np.float32)  # npy-portable
                stored_dtype = "bfloat16"
            else:
                stored_dtype = str(arr.dtype)
            path = f"{self.prefix(step)}/{name}.npy"
            res = self.plane.store(path, _encode_array(arr),
                                   site=self.site, worker=self.worker)
            self.stats.add(res)
            _fold(agg, res)
            manifest["leaves"].append(
                {"name": name, "path": path, "dtype": stored_dtype,
                 "shape": list(arr.shape)})
        res = self.plane.store(f"{self.prefix(step)}/manifest.json",
                               json.dumps(manifest).encode(),
                               site=self.site, worker=self.worker)
        self.stats.add(res)
        _fold(agg, res)
        if drain:
            self.stats.add(self.plane.drain())
        self.leaves = len(manifest["leaves"])
        return agg

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        """Newest checkpoint the plane can see (origin catalogs plus
        not-yet-drained write-back objects — read-your-writes)."""
        best = None
        for p in self.plane.paths(f"/ckpt/{self.run}/"):
            if p.endswith("manifest.json"):
                step = int(p.split("step_")[1].split("/")[0])
                best = step if best is None else max(best, step)
        return best

    def _fetch(self, path: str) -> FetchResult:
        res = self.plane.fetch(FetchRequest(
            path=path, site=self.site, worker=self.worker,
            method="cvmfs", want_data=True, tenant="checkpoint"))
        self.stats.add(res)
        if not res.ok or res.data is None:
            raise FileNotFoundError(res.error or path)
        return res

    def restore(self, step: int, like=None) -> Tuple[Any, FetchResult]:
        """Fetch a checkpoint through the nearest cache."""
        agg = FetchResult(path=self.prefix(step),
                          method="checkpoint-restore",
                          plane=getattr(self.plane, "name", ""))
        res = self._fetch(f"{self.prefix(step)}/manifest.json")
        _fold(agg, res)
        manifest = json.loads(res.data.decode())
        leaves: Dict[str, np.ndarray] = {}
        for entry in manifest["leaves"]:
            res = self._fetch(entry["path"])
            _fold(agg, res)
            arr = _decode_array(res.data)
            if entry["dtype"] == "bfloat16":
                arr = arr.astype(jax.numpy.bfloat16)
            leaves[entry["name"]] = arr
        if like is None:
            return leaves, agg
        named = _leaf_paths(like)
        flat = [leaves[name] for name, _ in named]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), flat)
        return tree, agg
