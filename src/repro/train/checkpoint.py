"""Federation-backed checkpointing — restart storms through pod caches.

Saves go through the **write-back cache** (the paper's §6 future work):
the training job acks as soon as bytes land in the pod cache; the drain to
the origin is rate-limited so a 512-host synchronous save cannot melt the
storage fabric.

Restores are the paper's headline scenario inverted onto the fleet: after
a preemption, every host of a pod re-reads the same checkpoint objects —
the first reader warms the pod cache and the other N−1 hit it, so the
origin sees each byte once per pod instead of once per host (measured in
``benchmarks/bench_restart_storm.py``).

Layout: one federation object per parameter leaf (so a host restoring a
*shard* fetches only the leaves it owns) plus a JSON manifest:

    /ckpt/<run>/step_<k>/manifest.json
    /ckpt/<run>/step_<k>/<leaf.path>.npy
"""
from __future__ import annotations

import dataclasses
import io
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.client import StashClient
from ..core.transfer import TransferStats
from ..core.writeback import WritebackCache


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _encode_array(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _decode_array(raw: bytes) -> np.ndarray:
    return np.load(io.BytesIO(raw), allow_pickle=False)


@dataclasses.dataclass
class CheckpointStats:
    save_bytes: int = 0
    save_seconds: float = 0.0
    restore_bytes: int = 0
    restore_seconds: float = 0.0
    leaves: int = 0


class FederatedCheckpointer:
    def __init__(self, run: str, writeback: WritebackCache,
                 client: StashClient) -> None:
        self.run = run
        self.writeback = writeback
        self.client = client
        self.stats = CheckpointStats()

    def prefix(self, step: int) -> str:
        return f"/ckpt/{self.run}/step_{step:08d}"

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, drain: bool = True) -> TransferStats:
        """Write state via the write-back cache; optionally drain now."""
        agg = TransferStats(method="checkpoint-save")
        manifest = {"step": step, "leaves": []}
        node = self.client.node.name
        for name, leaf in _leaf_paths(state):
            arr = np.asarray(leaf)
            if arr.dtype == jax.numpy.bfloat16:
                arr = arr.astype(np.float32)  # npy-portable
                stored_dtype = "bfloat16"
            else:
                stored_dtype = str(arr.dtype)
            raw = _encode_array(arr)
            path = f"{self.prefix(step)}/{name}.npy"
            _, st = self.writeback.write(node, path, raw)
            agg.add(st)
            manifest["leaves"].append(
                {"name": name, "path": path, "dtype": stored_dtype,
                 "shape": list(arr.shape)})
        _, st = self.writeback.write(
            node, f"{self.prefix(step)}/manifest.json",
            json.dumps(manifest).encode())
        agg.add(st)
        if drain:
            self.writeback.drain()
        self.stats.save_bytes += agg.bytes
        self.stats.save_seconds += agg.seconds
        self.stats.leaves = len(manifest["leaves"])
        return agg

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        """Scan the origin catalog for the newest complete checkpoint."""
        best = None
        for origin in self.writeback.redirectors.members[0].origins.values():
            for meta in origin.list_objects():
                p = meta.path
                if p.startswith(f"/ckpt/{self.run}/") and \
                        p.endswith("manifest.json"):
                    step = int(p.split("step_")[1].split("/")[0])
                    best = step if best is None else max(best, step)
        return best

    def restore(self, step: int, like=None) -> Tuple[Any, TransferStats]:
        """Fetch a checkpoint through the nearest cache."""
        agg = TransferStats(method="checkpoint-restore")
        raw, st = self.client.read(f"{self.prefix(step)}/manifest.json")
        agg.add(st)
        manifest = json.loads(raw.decode())
        leaves: Dict[str, np.ndarray] = {}
        for entry in manifest["leaves"]:
            raw, st = self.client.read(entry["path"])
            agg.add(st)
            arr = _decode_array(raw)
            if entry["dtype"] == "bfloat16":
                arr = arr.astype(jax.numpy.bfloat16)
            leaves[entry["name"]] = arr
        self.stats.restore_bytes += agg.bytes
        self.stats.restore_seconds += agg.seconds
        if like is None:
            return leaves, agg
        named = _leaf_paths(like)
        flat = [leaves[name] for name, _ in named]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), flat)
        return tree, agg
