"""AdamW with configurable moment precision (fp32 / bf16 / int8-blockwise).

No optax in this environment, and large-scale training wants control over
optimizer-state memory anyway: for the ≥90 B-parameter assigned archs the
dry-run budget requires sub-fp32 moments (DESIGN.md §5).  The int8 mode is
blockwise-quantized (per-256-element absmax scales) with the same update
math in fp32 — a standard 8-bit-Adam construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"       # float32 | bfloat16 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


# ---------------------------------------------------------------------------
# Blockwise int8 moment codec
# ---------------------------------------------------------------------------
def _q8_encode(x: jax.Array) -> Dict[str, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _q8_decode(enc: Dict[str, jax.Array], shape) -> jax.Array:
    flat = (enc["q"].astype(jnp.float32) * enc["scale"]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def _encode_moment(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _q8_encode(x)
    return x.astype(jnp.dtype(dtype))


def _decode_moment(m, shape, dtype: str) -> jax.Array:
    if dtype == "int8":
        return _q8_decode(m, shape)
    return m.astype(jnp.float32)


# ---------------------------------------------------------------------------
def init_opt_state(params, cfg: AdamWConfig):
    def one(p):
        z = jnp.zeros_like(p, dtype=jnp.float32)
        return _encode_moment(z, cfg.moment_dtype)

    return {
        "mu": jax.tree.map(one, params),
        "nu": jax.tree.map(one, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs, cfg: AdamWConfig):
    """Logical-axis specs for the optimizer state (mirror params)."""
    def one(axes):
        if cfg.moment_dtype == "int8":
            # Quantized blocks lose tensor structure → replicate scales,
            # shard q on its (flattened) leading dim over data.
            return {"q": ("opt_blocks", None), "scale": ("opt_blocks", None)}
        return tuple(axes)

    from ..sharding.rules import is_logical_axes
    return {
        "mu": jax.tree.map(one, param_specs, is_leaf=is_logical_axes),
        "nu": jax.tree.map(one, param_specs, is_leaf=is_logical_axes),
        "step": (),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        m = _decode_moment(mu, p.shape, cfg.moment_dtype)
        v = _decode_moment(nu, p.shape, cfg.moment_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, _encode_moment(m, cfg.moment_dtype), \
            _encode_moment(v, cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    is_enc = lambda x: isinstance(x, dict) and "q" in x  # noqa: E731
    flat_mu = jax.tree.flatten(opt_state["mu"], is_leaf=is_enc)[0]
    flat_nu = jax.tree.flatten(opt_state["nu"], is_leaf=is_enc)[0]
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
